"""MoE dispatch unit + property tests: capacity law, group geometry,
dispatch/combine invariants (the tensors GSPMD turns into the all-to-alls)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.core.policy import FT_OFF
from repro.models import moe as moe_lib
from repro.models.blocks import Ctx

CTX = Ctx(ft=FT_OFF, key=None, dtype=jnp.float32)


def test_capacity_law():
    mc = MoEConfig(n_experts=128, top_k=2, expert_d_ff=64,
                   capacity_factor=1.25)
    assert moe_lib.capacity(512, mc) == 12       # ceil(512·2·1.25/128)=10→12
    assert moe_lib.capacity(128, mc) == 3        # small groups: no 4-floor
    assert moe_lib.capacity(8, mc) == 1


def test_group_geometry_aligns_to_mesh():
    mc = MoEConfig(n_experts=8, top_k=2, expert_d_ff=16, group_size=512)
    # train_4k-like: prefers ≥16 groups along seq
    assert moe_lib._group_geometry(256, 4096, mc) == 256
    # prefill-like: group_size already gives ≥16 seq groups
    assert moe_lib._group_geometry(32, 32768, mc) == 512
    # decode: groups along batch
    assert moe_lib._group_geometry(128, 1, mc) == 128
    # ragged smoke shape: one group per row
    assert moe_lib._group_geometry(2, 37, mc) == 37


def _moe(e=8, k=2, d=16, f=32, seed=0):
    mc = MoEConfig(n_experts=e, top_k=k, expert_d_ff=f, group_size=64)
    p = moe_lib.init_moe(jax.random.PRNGKey(seed), d, mc, 2, jnp.float32)
    return mc, p


def test_moe_output_shape_and_finite():
    mc, p = _moe()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    y, aux = moe_lib.apply_moe(p, x, mc, CTX)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.0          # balance loss strictly positive


def test_moe_is_permutation_equivariant_over_batch():
    """Routing is per-token: permuting batch rows permutes outputs."""
    mc, p = _moe()
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, 16))
    y, _ = moe_lib.apply_moe(p, x, mc, CTX)
    perm = jnp.array([2, 0, 3, 1])
    y_p, _ = moe_lib.apply_moe(p, x[perm], mc, CTX)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y[perm]),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), e=st.sampled_from([4, 8]),
       k=st.integers(1, 3))
def test_property_dispatch_tensor_invariants(seed, e, k):
    """For every token: ≤ k expert slots used; combine weights ∈ (0, 1] and
    sum ≤ 1; no expert queue exceeds capacity."""
    mc = MoEConfig(n_experts=e, top_k=k, expert_d_ff=8, group_size=32)
    d = 8
    p = moe_lib.init_moe(jax.random.PRNGKey(seed), d, mc, 2, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32, d))
    # rebuild the dispatch tensors the way apply_moe does
    g = moe_lib._group_geometry(1, 32, mc)
    c = moe_lib.capacity(g, mc)
    xg = x.reshape(-1, g, d)
    logits = jnp.einsum("ngd,de->nge", xg, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    combine = jnp.zeros(xg.shape[:2] + (e, c), jnp.float32)
    fill = jnp.zeros((xg.shape[0], e), jnp.int32)
    for kk in range(k):
        oh = jax.nn.one_hot(idx[..., kk], e, dtype=jnp.int32)
        pos = fill[:, None, :] + jnp.cumsum(oh, axis=1) - oh
        keep = (pos < c) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, c), c,
                                dtype=jnp.float32)
        combine = combine + pos_oh * oh[..., None] \
            * gate_vals[..., kk][..., None, None]
        fill = fill + jnp.sum(oh, axis=1)
    cb = np.asarray(combine)
    # per-token total weight ≤ 1 (+eps), per-token slots ≤ k
    per_tok = cb.reshape(cb.shape[0], cb.shape[1], -1)
    assert (per_tok.sum(-1) <= 1.0 + 1e-5).all()
    assert ((per_tok > 0).sum(-1) <= k).all()
    # no slot double-booked: each (expert, slot) holds ≤ 1 token
    occupancy = (cb > 0).sum(axis=1)              # (n, e, c)
    assert (occupancy <= 1).all()
