"""Launch-path coverage: the dry-run machinery (abstract params/opt-state,
cache specs, lowering builders, roofline parsing) exercised on a small
8-device mesh in subprocesses (mirrors launch/dryrun.py on the production
512-device mesh, which runs outside pytest)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 560) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_small_mesh_train_lowering_compiles_with_shardings():
    out = run_sub("""
        import jax, jax.numpy as jnp, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.core.policy import ONLINE_BLOCK
        from repro.distributed import sharding as shd
        from repro.models import model_zoo
        from repro.optim import adamw
        from repro.tools import roofline
        from repro.train import train_loop

        cfg = dataclasses.replace(registry.get_smoke("qwen2-7b"),
                                  n_heads=8, n_kv_heads=4)
        shape = ShapeConfig("t", 64, 8, "train")
        run = RunConfig(model=cfg, ft=ONLINE_BLOCK, attn_chunk=32)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.use_mesh(mesh):
            mod = model_zoo.module_for(cfg)
            p_struct = jax.eval_shape(
                lambda: mod.init(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
            specs = shd.param_specs(p_struct)
            p_struct = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                p_struct, specs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            opt_cfg = adamw.AdamWConfig()
            tc = train_loop.TrainConfig()
            o_struct = jax.eval_shape(
                lambda p: train_loop.init_opt_state(p, opt_cfg, tc),
                p_struct)
            b = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32,
                                                sharding=NamedSharding(
                                                    mesh, P("data"))),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32,
                                                sharding=NamedSharding(
                                                    mesh, P("data")))}
            step = train_loop.make_train_step(cfg, run, opt_cfg, tc)
            lowered = jax.jit(lambda p, o, bb, s: step(p, o, bb, s, None)
                              ).lower(p_struct, o_struct, b,
                                      jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = roofline.cost_dict(compiled)
            cb, per = roofline.collective_bytes(compiled.as_text())
        assert cost.get("flops", 0) > 0
        assert cb > 0, "sharded train step must contain collectives"
        print("OK flops", cost["flops"], "coll", cb, sorted(per))
    """)
    assert "OK" in out


def test_small_mesh_decode_lowering_compiles():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.distributed import sharding as shd
        from repro.models import model_zoo
        from repro.models.blocks import Ctx
        from repro.core.policy import ONLINE_BLOCK

        cfg = registry.get_smoke("zamba2-2.7b")
        mod = model_zoo.module_for(cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.use_mesh(mesh, {"seq": None}):
            p_struct = jax.eval_shape(
                lambda: mod.init(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
            c_struct = jax.eval_shape(
                lambda: mod.init_cache(cfg, 8, 64, jnp.bfloat16))
            t = jax.ShapeDtypeStruct((8, 1), jnp.int32,
                                     sharding=NamedSharding(mesh,
                                                            P("data")))
            ctx = Ctx(ft=ONLINE_BLOCK, key=None, dtype=jnp.bfloat16)
            lowered = jax.jit(
                lambda p, tok, c: mod.decode_step(p, tok, c, cfg, ctx)
            ).lower(p_struct, t, c_struct)
            compiled = lowered.compile()
        from repro.tools import roofline
        print("OK", roofline.cost_dict(compiled).get("flops"))
    """)
    assert "OK" in out


def test_roofline_collective_parser():
    from repro.tools import roofline
    hlo = """
      %ag = bf16[16,512,128]{2,1,0} all-gather(%x), dimensions={0}
      %ar = f32[256,64]{1,0} all-reduce(%y), to_apply=%sum
      %rs = (f32[4,8]{1,0}, f32[4,8]{1,0}) reduce-scatter(%a, %b)
      %cp = u8[1024]{0} collective-permute(%z)
    """
    total, per = roofline.collective_bytes(hlo)
    assert per["all-gather"] == 16 * 512 * 128 * 2
    assert per["all-reduce"] == 256 * 64 * 4
    assert per["reduce-scatter"] == 2 * 4 * 8 * 4
    assert per["collective-permute"] == 1024
    assert total == sum(per.values())


def test_roofline_terms_and_bottleneck():
    from repro.tools import roofline
    rl = roofline.analyze({"flops": 197e12, "bytes accessed": 819e9 * 2},
                          "", model_flops_per_device=100e12)
    assert abs(rl.compute_s - 1.0) < 1e-6
    assert abs(rl.memory_s - 2.0) < 1e-6
    assert rl.bottleneck == "memory"
    assert abs(rl.useful_ratio - 100e12 / 197e12) < 1e-6
