"""Batched & grouped FT-GEMM subsystem validation (PR 3).

Covers: group-layout invariants, the uniform batched kernel vs the jnp
oracle (aligned + ragged, per-batch injection isolation), the ragged
grouped kernel vs a per-row oracle (skewed/empty/ragged-last groups,
per-group injection round-trips at every FT level without contaminating
neighboring groups), the core `ft_batched_dot`/`ft_grouped_matmul` fronts
on both backends (single-kernel property, gradients), batched-aware tuning
keys, and the grouped MoE layer against a dense per-expert reference."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import ft_batched_dot, ft_grouped_matmul
from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK, FT_OFF
from repro.kernels import autotune, ops, tune_cache
from repro.kernels import grouped as kgrouped
from repro.kernels.grouped import layout as glayout
from repro.kernels.templates import BatchedKernelSpec, KernelSpec


def _rand(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


def _grouped_oracle(x, w, gids):
    return jnp.einsum("tk,tkn->tn", x.astype(jnp.float32),
                      w.astype(jnp.float32)[gids]).astype(x.dtype)


# ---------------------------------------------------------------------------
# group layout invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), t=st.integers(1, 200),
       g=st.sampled_from([1, 3, 8]), bm=st.sampled_from([8, 16, 128]))
def test_layout_invariants(seed, t, g, bm):
    rng = np.random.default_rng(seed)
    gids = jnp.asarray(rng.integers(0, g, size=(t,)), jnp.int32)
    lay = glayout.make_layout(gids, g, bm)
    counts = np.asarray(lay.counts)
    base = np.asarray(lay.base)
    row_end = np.asarray(lay.row_end)
    pos = np.asarray(lay.positions)
    assert lay.t_buf % bm == 0 and lay.t_buf >= int(counts.sum())
    assert counts.sum() == t
    # groups start on bm boundaries, live rows inside [base, row_end)
    assert (base % bm == 0).all()
    assert (row_end == base + counts).all()
    for r in range(t):
        e = int(np.asarray(gids)[r])
        assert base[e] <= pos[r] < row_end[e]
    # positions are a bijection into the live rows
    assert len(set(pos.tolist())) == t
    # every row tile is wholly owned by one group
    gid = np.asarray(lay.gid)
    for tile, e in enumerate(gid):
        lo, hi = tile * bm, (tile + 1) * bm
        live = (pos >= lo) & (pos < hi)
        assert (np.asarray(gids)[live] == e).all()


def test_layout_scatter_gather_roundtrip():
    gids = jnp.asarray([2, 0, 2, 1, 2, 0], jnp.int32)
    x = _rand((6, 16), seed=3)
    lay = glayout.make_layout(gids, 3, 8)
    buf = glayout.scatter_rows(x, lay)
    assert buf.shape[0] == lay.t_buf
    np.testing.assert_array_equal(np.asarray(glayout.gather_rows(buf, lay)),
                                  np.asarray(x))
    # dead rows are exactly zero (checksum-neutral padding)
    live = np.zeros(lay.t_buf, bool)
    live[np.asarray(lay.positions)] = True
    assert not np.asarray(buf)[~live].any()


# ---------------------------------------------------------------------------
# uniform batched kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(3, 128, 128, 256), (2, 100, 77, 300)])
def test_batched_matches_oracle(shape, dtype):
    b, m, n, k = shape
    a = _rand((b, m, k), dtype, seed=5)
    w = _rand((b, k, n), dtype, seed=6)
    out, rep = ops.grouped_gemm_call(BatchedKernelSpec(), a, w,
                                     interpret=True)
    assert rep is None and out.shape == (b, m, n)
    want = jnp.matmul(a, w, preferred_element_type=jnp.float32)
    tol = (1e-5, 1e-3) if dtype == jnp.float32 else (2e-2, 2e-1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol[0], atol=tol[1])


def test_batched_shared_b_operand():
    a = _rand((4, 64, 96), seed=7)
    w = _rand((96, 40), seed=8)
    out, _ = ops.grouped_gemm_call(BatchedKernelSpec(), a, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ w),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("level", ["block", "tile", "inner"])
def test_batched_injection_isolated_per_batch(level):
    """An SEU in one batch slice is detected/corrected there and ONLY
    there — the per-slice checksums cannot cross the batch axis."""
    b, m, n, k = 3, 256, 128, 256
    a = _rand((b, m, k), seed=9)
    w = _rand((b, k, n), seed=10)
    inj = InjectionSpec(row=130, col=40, magnitude=333.0, k_step=0)
    out, rep = ops.grouped_gemm_call(
        BatchedKernelSpec(ft_level=level), a, w, ft=FTConfig(level=level),
        inject=inj, inj_batch=1, interpret=True)
    want = jnp.matmul(a, w, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-3)
    per_batch = [float(rep[i, ..., 0].sum()) for i in range(b)]
    assert per_batch == [0.0, 1.0, 0.0]
    assert float(rep[..., 1].sum()) == 1.0


def test_batched_ft_clean_no_false_positives_ragged():
    a = _rand((2, 100, 300), seed=11)
    w = _rand((2, 300, 77), seed=12)
    for level in ("block", "inner"):
        out, rep = ops.grouped_gemm_call(
            BatchedKernelSpec(ft_level=level), a, w,
            ft=FTConfig(level=level), interpret=True)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(jnp.matmul(a, w, preferred_element_type=jnp.float32)),
            rtol=1e-5, atol=1e-3)
        assert float(rep[..., 0].sum()) == 0.0


# ---------------------------------------------------------------------------
# grouped kernel: ragged groups, per-group injection round-trips
# ---------------------------------------------------------------------------

def _skewed_gids(t, g, seed):
    """Routing with skew, at least one empty group when g > 2, ragged
    (non-tile-multiple) last group."""
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, g + 1)
    if g > 2:
        probs[g // 2] = 0.0              # empty group in the middle
    probs /= probs.sum()
    return jnp.asarray(rng.choice(g, size=t, p=probs), jnp.int32)


@pytest.mark.parametrize("tg", [(61, 3), (50, 4), (33, 8), (7, 2)])
def test_grouped_matches_oracle(tg):
    t, g = tg
    gids = _skewed_gids(t, g, seed=13)
    x = _rand((t, 96), seed=14)
    w = _rand((g, 96, 40), seed=15)
    out, rep = ops.grouped_gemm_call(BatchedKernelSpec(), x, w,
                                     group_ids=gids, interpret=True)
    assert rep is None and out.shape == (t, 40)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_grouped_oracle(x, w, gids)),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("level", ["block", "tile", "inner"])
def test_grouped_injection_per_group_no_contamination(level):
    """The satellite criterion: a per-group SEU must be detected AND
    corrected without contaminating neighboring groups — including when it
    lands in the ragged LAST group. Verified by comparing every group's
    rows against the clean oracle and checking the report localizes the
    error to the injected group's row tiles."""
    t, g, k, n = 70, 3, 256, 128
    gids = jnp.asarray([0] * 30 + [1] * 25 + [2] * 15, jnp.int32)
    x = _rand((t, k), seed=16)
    w = _rand((g, k, n), seed=17)
    want = _grouped_oracle(x, w, gids)
    spec = BatchedKernelSpec(ft_level=level, grouped=True)
    p = kgrouped.plan_grouped(t, n, k, jnp.float32, n_groups=g,
                              ft_level=level, spec=spec)
    lay = glayout.make_layout(gids, g, p.bm)
    buf = glayout.scatter_rows(x, lay)
    for target in (1, g - 1):            # middle group and the ragged last
        # first live buffer row of the target group
        row = int(lay.base[target])
        inj = InjectionSpec(row=row, col=7, magnitude=444.0, k_step=0)
        y_buf, rep = kgrouped.grouped_buffer_call(
            spec, buf, w, lay, params=p, ft=FTConfig(level=level),
            inject=inj, interpret=True)
        y = glayout.gather_rows(y_buf, lay)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)
        assert float(rep[..., 0].sum()) == 1.0
        assert float(rep[..., 1].sum()) == 1.0
        # detection localized to the target group's row tiles
        det_tiles = np.nonzero(np.asarray(rep[..., 0]).sum(axis=1))[0]
        assert (np.asarray(lay.gid)[det_tiles] == target).all()


def test_grouped_detect_only_leaves_error_in_group():
    t, g, k, n = 40, 2, 128, 128
    gids = jnp.asarray([0] * 24 + [1] * 16, jnp.int32)
    x = _rand((t, k), seed=18)
    w = _rand((g, k, n), seed=19)
    want = _grouped_oracle(x, w, gids)
    spec = BatchedKernelSpec(ft_level="block", grouped=True)
    p = kgrouped.plan_grouped(t, n, k, jnp.float32, n_groups=g,
                              ft_level="block", spec=spec)
    lay = glayout.make_layout(gids, g, p.bm)
    buf = glayout.scatter_rows(x, lay)
    row = int(lay.base[1])
    inj = InjectionSpec(row=row, col=3, magnitude=99.0, k_step=0)
    y_buf, rep = kgrouped.grouped_buffer_call(
        spec, buf, w, lay, params=p,
        ft=FTConfig(level="block", action="detect"), inject=inj,
        interpret=True)
    y = np.asarray(glayout.gather_rows(y_buf, lay))
    err = y - np.asarray(want)
    # error left in place, confined to group 1's injected element
    assert abs(err[24, 3] - 99.0) < 1e-3
    err[24, 3] = 0.0
    np.testing.assert_allclose(err, 0.0, atol=1e-3)
    assert float(rep[..., 0].sum()) >= 1.0
    assert float(rep[..., 1].sum()) == 0.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500), target=st.integers(0, 2),
       col=st.integers(0, 39), mag=st.floats(10.0, 1e4),
       sign=st.sampled_from([-1.0, 1.0]))
def test_property_grouped_seu_corrected(seed, target, col, mag, sign):
    t, g, k, n = 45, 3, 96, 40
    rng = np.random.default_rng(seed)
    gids = jnp.asarray(np.sort(rng.integers(0, g, size=t)), jnp.int32)
    x = _rand((t, k), seed=seed + 1)
    w = _rand((g, k, n), seed=seed + 2)
    spec = BatchedKernelSpec(ft_level="block", grouped=True)
    p = kgrouped.plan_grouped(t, n, k, jnp.float32, n_groups=g,
                              ft_level="block", spec=spec)
    lay = glayout.make_layout(gids, g, p.bm)
    if int(lay.counts[target]) == 0:
        return                           # nothing to inject into
    buf = glayout.scatter_rows(x, lay)
    inj = InjectionSpec(row=int(lay.base[target]), col=col,
                        magnitude=sign * mag, k_step=0)
    y_buf, rep = kgrouped.grouped_buffer_call(
        spec, buf, w, lay, params=p, ft=FTConfig(level="block"),
        inject=inj, interpret=True)
    y = glayout.gather_rows(y_buf, lay)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_grouped_oracle(x, w, gids)),
                               rtol=1e-4, atol=max(1e-3, 4e-7 * mag))
    assert float(rep[..., 0].sum()) >= 1.0


# ---------------------------------------------------------------------------
# core fronts: ft_batched_dot / ft_grouped_matmul on both backends
# ---------------------------------------------------------------------------

def test_ft_batched_dot_pallas_single_kernel():
    """The acceptance criterion: the pallas backend emits ONE batched
    Pallas kernel — no per-slice Python loop, no jnp matmul fallback."""
    a = _rand((4, 64, 96), seed=20)
    b = _rand((4, 96, 40), seed=21)
    ftc = FTConfig(level="block", backend="pallas")
    jaxpr = str(jax.make_jaxpr(
        lambda a, b: ft_batched_dot(a, b, ft=ftc))(a, b))
    assert jaxpr.count("pallas_call") == 1, "expected exactly one kernel"
    assert "dot_general" not in jaxpr.split("pallas_call")[0], \
        "no jnp matmul outside the kernel"
    y = ft_batched_dot(a, b, ft=ftc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ft_batched_dot_4d_leading_dims(backend):
    a = _rand((2, 3, 40, 96), seed=22)
    b = _rand((2, 3, 96, 50), seed=23)
    y = ft_batched_dot(a, b, ft=FTConfig(level="block", backend=backend))
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.matmul(a, b)),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("level", ["block", "inner"])
def test_ft_grouped_matmul_backends_and_levels(backend, level):
    t, g = 61, 4
    gids = _skewed_gids(t, g, seed=24)
    x = _rand((t, 96), seed=25)
    w = _rand((g, 96, 40), seed=26)
    want = _grouped_oracle(x, w, gids)
    ftc = FTConfig(level=level, backend=backend)
    y = ft_grouped_matmul(x, w, gids, ft=ftc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
    # injected SEU round-trip (global row coords on the pallas path; the
    # jnp path injects into the buffer accumulator at the same coords)
    y = ft_grouped_matmul(x, w, gids, ft=ftc,
                          spec=InjectionSpec(row=1, col=2, magnitude=600.0))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ft_grouped_matmul_grads(backend):
    t, g = 37, 3
    gids = _skewed_gids(t, g, seed=27)
    x = _rand((t, 64), seed=28)
    w = _rand((g, 64, 32), seed=29)
    ftc = FTConfig(level="block", backend=backend)

    def loss(x, w):
        return jnp.sum(jnp.sin(ft_grouped_matmul(x, w, gids, ft=ftc)))

    def loss_ref(x, w):
        return jnp.sum(jnp.sin(jnp.einsum("tk,tkn->tn", x, w[gids])))

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               rtol=1e-4, atol=1e-4)


def test_ft_grouped_matmul_fast_path_no_capacity():
    """FT-off fast path: exact, and the buffer holds ≤ G·(bm-1) padding
    rows — zero capacity geometry anywhere."""
    t, g = 100, 5
    gids = _skewed_gids(t, g, seed=30)
    x = _rand((t, 48), seed=31)
    w = _rand((g, 48, 24), seed=32)
    y = ft_grouped_matmul(x, w, gids)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_grouped_oracle(x, w, gids)),
                               rtol=1e-4, atol=1e-3)
    lay = glayout.make_layout(gids, g, 8)
    assert lay.t_buf <= t + g * 8


# ---------------------------------------------------------------------------
# batched-aware tuning keys
# ---------------------------------------------------------------------------

@pytest.fixture()
def fresh_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    tune_cache.reset()
    yield path
    tune_cache.reset()


def test_batched_cache_key_components(fresh_cache):
    m, n, k = 300, 300, 600
    autotune.best_params(m, n, k, measure=False)
    autotune.best_params(m, n, k, measure=False,
                         spec=BatchedKernelSpec(), batch=12)
    autotune.best_params(m, n, k, measure=False,
                         spec=BatchedKernelSpec(grouped=True), groups=5)
    keys = tune_cache.TuneCache(fresh_cache).keys()
    assert any(k.endswith("/v_batched/b_16") for k in keys)   # pow2 bucket
    assert any(k.endswith("/v_grouped/g_8") for k in keys)
    # plain 2-D key unchanged — PR-1/2 caches stay valid
    assert any("/v_" not in k and "/b_" not in k and "/g_" not in k
               for k in keys)
    assert len(keys) == 3


def test_group_count_steers_search_away_from_deep_row_tiles():
    """The grouped roofline charges G·(bm-1) padding rows per group, so a
    high group count must never pick a deeper bm than the group-free
    search would."""
    from repro.kernels import search
    free = search.select_best(4096, 512, 512, measure=False)
    packed = search.select_best(4096, 512, 512, measure=False, groups=128)
    assert packed.bm <= free.bm
    t128 = search.predicted_time_s(4096, 512, 512,
                                   autotune.KernelParams(128, 512, 512),
                                   groups=128)
    t512 = search.predicted_time_s(4096, 512, 512,
                                   autotune.KernelParams(512, 512, 512),
                                   groups=128)
    assert t128 < t512


def test_batched_spec_validation():
    with pytest.raises(ValueError):
        BatchedKernelSpec(epilogue=("bias",))          # aux-free chains only
    with pytest.raises(ValueError):
        BatchedKernelSpec(grouped=True, shared_b=True)
    s = BatchedKernelSpec(grouped=True)
    assert s.masked and s.batched and s.grouped
    assert BatchedKernelSpec().variant_key() == "batched"
    assert BatchedKernelSpec(grouped=True).variant_key() == "grouped"
    assert KernelSpec().variant_key() == ""            # 2-D keys unchanged


# ---------------------------------------------------------------------------
# grouped MoE layer vs dense per-expert reference
# ---------------------------------------------------------------------------

def test_moe_grouped_matches_dense_reference():
    from repro.configs.base import MoEConfig
    from repro.models import moe as moe_lib
    from repro.models.blocks import Ctx
    mc = MoEConfig(n_experts=8, top_k=2, expert_d_ff=32, dispatch="grouped")
    d = 16
    p = moe_lib.init_moe(jax.random.PRNGKey(0), d, mc, 2, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, d), jnp.float32)
    for ftc in (FT_OFF, ONLINE_BLOCK,
                FTConfig(level="block", backend="pallas")):
        ctx = Ctx(ft=ftc, key=None, dtype=jnp.float32)
        y, aux = moe_lib.apply_moe(p, x, mc, ctx)
        assert y.shape == x.shape and float(aux) > 0.0
        # dense per-expert oracle: every token goes to its experts, no
        # capacity, no drops
        xt = x.reshape(-1, d)
        gate_vals, idx, _ = moe_lib._routing(xt, p["router"], mc)
        h = jnp.stack([
            (jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e]))
            @ p["w_down"][e] for e in range(mc.n_experts)])
        want = sum(gate_vals[:, kk:kk + 1] * jnp.take_along_axis(
            h, idx[None, :, kk:kk + 1], axis=0)[0]
            for kk in range(mc.top_k))
        np.testing.assert_allclose(np.asarray(y.reshape(-1, d)),
                                   np.asarray(want), rtol=1e-4, atol=1e-4)


def test_moe_grouped_drops_nothing_vs_padded_drops():
    """Skewed routing: the padded path drops overflow tokens (their output
    contribution is zero), the grouped path serves every assignment."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as moe_lib
    from repro.models.blocks import Ctx
    import dataclasses
    mc = MoEConfig(n_experts=4, top_k=1, expert_d_ff=16, group_size=32,
                   capacity_factor=1.0)
    d = 8
    p = moe_lib.init_moe(jax.random.PRNGKey(2), d, mc, 2, jnp.float32)
    # steer the router hard toward expert 0 → guaranteed overflow
    p = dict(p, router=p["router"] * 0.0
             + jnp.eye(d, mc.n_experts, dtype=jnp.float32) * 50.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (1, 32, d),
                                  jnp.float32))
    ctx = Ctx(ft=FT_OFF, key=None, dtype=jnp.float32)
    y_grouped, _ = moe_lib.apply_moe(
        p, x, dataclasses.replace(mc, dispatch="grouped"), ctx)
    y_padded, _ = moe_lib.apply_moe(
        p, x, dataclasses.replace(mc, dispatch="padded"), ctx)
    zero_rows = lambda y: int((np.abs(np.asarray(y)).max(-1) < 1e-9).sum())
    assert zero_rows(y_grouped) == 0
    assert zero_rows(y_padded) > 0        # capacity overflow dropped tokens
