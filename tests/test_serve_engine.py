"""FT serving engine conformance suite (PR 9).

Three layers, matching the serving stack's three layers:

  * kernel — the per-row ragged paged flash decode kernel vs a float64
    softmax oracle over the gathered pages, across GQA group sizes and
    per-row lengths including 0 and exact page boundaries; deterministic
    in-kernel SEU corrected bit-for-bit on exactly-representable operands;
    detect-only leaves the fault in place but reports it;
  * model — `transformer.paged_decode_step` ≡ the dense `decode_step`
    (logits and post-step cache contents), with a jaxpr audit proving zero
    unprotected dot_generals and the paged decode kernel in the trace;
  * engine — continuous batching conserves outputs: every request decodes
    to exactly its solo-greedy tokens, no request starves, every page
    returns to the free list, and decode-path detections are attributed to
    the `dec_flash` site in the metrics stream.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core.policy import FTConfig, InjectionSpec
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.models.blocks import Ctx
from repro.tools.metrics import MetricsSink, MemoryEmitter
from repro.train import kv_cache as kvc
from repro.train.engine import EngineConfig, ServeEngine

FT_PALLAS = FTConfig(action="correct", level="block", backend="pallas")
TINY = ModelConfig(arch_id="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=128)


@pytest.fixture(scope="module")
def tiny_params():
    return tfm.init(TINY, jax.random.PRNGKey(0), jnp.float32)


# ---------------------------------------------------------------------------
# kernel: paged ragged decode vs dense oracle
# ---------------------------------------------------------------------------

def _paged_kv(lengths, kvh, dh, page, mp, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    b = len(lengths)
    n_pages = 1 + b * mp
    cache = kvc.init_paged_cache(1, n_pages, b, mp, kvh, page, dh, dtype)
    alloc = kvc.PageAllocator(n_pages, b, mp, page)
    for length in lengths:
        if length == 0:
            # keep the slot order: claim it with zero pages (all-NULL row)
            alloc.alloc_slot(0)
            continue
        s, _ = alloc.alloc_slot(length)
        ks = jnp.asarray(rng.standard_normal((1, length, kvh, dh)), dtype)
        vs = jnp.asarray(rng.standard_normal((1, length, kvh, dh)), dtype)
        cache = kvc.write_prefill(cache, s, jnp.asarray(alloc.page_table[s]),
                                  ks, vs, length)
    alloc.check_invariants()
    return cache, alloc, rng


def _oracle_row(q_row, kd, vd, length, dh):
    if length == 0:
        return np.zeros(dh)
    kk = kd[:length].astype(np.float64)
    vv = vd[:length].astype(np.float64)
    sc = kk @ q_row.astype(np.float64) * dh ** -0.5
    p = np.exp(sc - sc.max())
    p /= p.sum()
    return p @ vv


@pytest.mark.parametrize("kvh,nrep", [(2, 2), (1, 4), (4, 1)])
@pytest.mark.parametrize("lengths", [[17, 64, 0], [16, 1, 33]])
def test_paged_ragged_decode_matches_oracle(kvh, nrep, lengths):
    """Per-row ragged lengths — including a dead row (0), one token, an
    exact page boundary (16) and full capacity (64) — across GQA group
    sizes, vs the float64 softmax oracle."""
    dh, page, mp = 128, 16, 4
    h = kvh * nrep
    cache, alloc, rng = _paged_kv(lengths, kvh, dh, page, mp,
                                  seed=kvh * 10 + nrep)
    q = jnp.asarray(rng.standard_normal((len(lengths), h, dh)), jnp.float32)
    out, rep = ops.flash_ft_decode(
        q, cache["k_pages"][0], cache["v_pages"][0],
        jnp.asarray(alloc.lengths), jnp.asarray(alloc.page_table),
        ft=FTConfig(level="block", action="correct"), interpret=True)
    out = np.asarray(out)
    assert float(np.asarray(rep)[..., 0].sum()) == 0.0, "false positive"
    kd, vd = kvc.gather_dense(cache)
    kd, vd = np.asarray(kd[0]), np.asarray(vd[0])     # (B, S, KVH, dh)
    for slot, length in enumerate(lengths):
        for hh in range(h):
            ref = _oracle_row(np.asarray(q[slot, hh]),
                              kd[slot, :, hh // nrep],
                              vd[slot, :, hh // nrep], length, dh)
            np.testing.assert_allclose(out[slot, hh], ref, atol=2e-5,
                                       rtol=2e-5)


def _exact_paged_kv(lengths, kvh, dh, page, seed=0):
    """Exactly-representable operands: one-hot 64·e_t queries/keys (matched
    score 256 → softmax weights in {1, 1/2} exactly, dh=256 scale is 2^-4),
    small-integer V — the paged decode output is exact in f32, so a
    corrected SEU must be bit-for-bit identical to the clean run."""
    rng = np.random.default_rng(seed)
    b = len(lengths)
    mp = 512 // page
    n_pages = 1 + b * mp
    cache = kvc.init_paged_cache(1, n_pages, b, mp, kvh, page, dh,
                                 jnp.float32)
    alloc = kvc.PageAllocator(n_pages, b, mp, page)
    for length in lengths:
        s, _ = alloc.alloc_slot(length)
        karr = 64.0 * np.eye(dh, dtype=np.float32)[np.arange(length) % dh]
        ks = jnp.asarray(np.broadcast_to(karr[None, :, None],
                                         (1, length, kvh, dh)).copy())
        vs = jnp.asarray(rng.integers(-2, 3, (1, length, kvh, dh)),
                         jnp.float32)
        cache = kvc.write_prefill(cache, s, jnp.asarray(alloc.page_table[s]),
                                  ks, vs, length)
    tq = rng.integers(0, dh, (b, kvh * 2))
    q = jnp.asarray(64.0 * np.eye(dh, dtype=np.float32)[tq])
    return q, cache, alloc


def test_paged_decode_seu_corrected_bitexact():
    kvh, dh, page = 2, 256, 16
    q, cache, alloc = _exact_paged_kv([272, 320], kvh, dh, page)
    ft = FTConfig(level="block", action="correct")
    args = (q, cache["k_pages"][0], cache["v_pages"][0],
            jnp.asarray(alloc.lengths), jnp.asarray(alloc.page_table))
    clean, _ = ops.flash_ft_decode(*args, ft=ft, interpret=True)
    spec = InjectionSpec(row=1, col=7, k_step=1, magnitude=777.0)
    g = 1 * kvh + 0                       # grid row: slot 1, kv head 0
    dirty, rep = ops.flash_ft_decode(*args, ft=ft, spec=spec, inj_g=g,
                                     interpret=True)
    rep = np.asarray(rep)
    assert rep[g, 0, 0] >= 1              # detected on the right grid row
    assert rep[g, 0, 2] == spec.row and rep[g, 0, 3] == spec.col
    assert abs(rep[g, 0, 4] - 777.0) < 1.0
    # off-row report rows stay silent
    assert float(np.delete(rep[..., 0], g, axis=0).sum()) == 0.0
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


def test_paged_decode_seu_detect_only_leaves_error():
    kvh, dh, page = 2, 256, 16
    q, cache, alloc = _exact_paged_kv([272, 320], kvh, dh, page)
    args = (q, cache["k_pages"][0], cache["v_pages"][0],
            jnp.asarray(alloc.lengths), jnp.asarray(alloc.page_table))
    clean, _ = ops.flash_ft_decode(
        *args, ft=FTConfig(level="block", action="correct"), interpret=True)
    # inject at the LAST live kv step of slot 1 (len 320 → 20 pages) so the
    # online-softmax rescale can't annihilate the uncorrected SEU
    spec = InjectionSpec(row=1, col=7, k_step=320 // page - 1,
                         magnitude=777.0)
    g = 1 * kvh + 0
    dirty, rep = ops.flash_ft_decode(
        *args, ft=FTConfig(level="block", action="detect"), spec=spec,
        inj_g=g, interpret=True)
    assert np.asarray(rep)[g, 0, 0] >= 1
    diff = np.abs(np.asarray(clean) - np.asarray(dirty)).max()
    assert diff > 1.0, "detect-only must leave the fault in the output"


def test_flash_ft_decode_rejects_unaligned_head_dim():
    with pytest.raises(ValueError):
        ops.flash_ft_decode(jnp.zeros((1, 2, 64)),
                            jnp.zeros((2, 1, 16, 64)),
                            jnp.zeros((2, 1, 16, 64)),
                            jnp.zeros((1,), jnp.int32),
                            jnp.zeros((1, 1), jnp.int32),
                            ft=FT_PALLAS)


# ---------------------------------------------------------------------------
# model: paged_decode_step ≡ dense decode_step + jaxpr audit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged_vs_dense(tiny_params):
    """Build matching dense and paged caches (per-row lengths incl. a cold
    slot and a page-boundary length) and run one step of each path."""
    cfg = TINY
    b, page, mp = 3, 8, 4
    smax = page * mp
    lengths = [9, 24, 0]                  # 24 = 3 full pages exactly
    ctx = Ctx(ft=FT_PALLAS, dtype=jnp.float32, attn_shard="none")
    rng = np.random.default_rng(0)

    dense = tfm.init_cache(cfg, b, smax, jnp.float32)
    for slot, length in enumerate(lengths):
        if length == 0:
            continue
        toks = jnp.asarray(rng.integers(1, 200, (1, length)), jnp.int32)
        _, c1 = tfm.prefill(tiny_params, toks,
                            tfm.init_cache(cfg, 1, smax, jnp.float32),
                            cfg, ctx)
        dense["k"] = dense["k"].at[:, slot].set(c1["k"][:, 0])
        dense["v"] = dense["v"].at[:, slot].set(c1["v"][:, 0])
        dense["length"] = dense["length"].at[slot].set(length)

    n_pages = 1 + b * mp
    alloc = kvc.PageAllocator(n_pages, b, mp, page)
    paged = kvc.init_paged_cache(cfg.n_layers, n_pages, b, mp,
                                 cfg.n_kv_heads, page, cfg.head_dim,
                                 jnp.float32)
    for slot, length in enumerate(lengths):
        if length == 0:
            continue
        s, _ = alloc.alloc_slot(length)
        assert s == slot
        paged = kvc.write_prefill(paged, s,
                                  jnp.asarray(alloc.page_table[s]),
                                  dense["k"][:, slot, :length],
                                  dense["v"][:, slot, :length], length)
    # engine protocol: ensure() reserves *capacity* for the next token; the
    # device-visible length stays the decoded-so-far count
    s, _ = alloc.alloc_slot(0)
    for slot in range(b):
        alloc.ensure(slot, lengths[slot] + 1)
    paged["page_table"] = jnp.asarray(alloc.page_table)
    paged["length"] = jnp.asarray(lengths, jnp.int32)

    tok = jnp.asarray(rng.integers(1, 200, (b, 1)), jnp.int32)
    ld, cd = tfm.decode_step(tiny_params, tok, dense, cfg, ctx)
    lp, cp = tfm.paged_decode_step(tiny_params, tok, paged, cfg, ctx)
    return dict(cfg=cfg, ctx=ctx, lengths=lengths, tok=tok, paged=paged,
                ld=ld, cd=cd, lp=lp, cp=cp)


def test_paged_decode_step_matches_dense_logits(paged_vs_dense):
    err = np.abs(np.asarray(paged_vs_dense["ld"])
                 - np.asarray(paged_vs_dense["lp"])).max()
    assert err < 2e-4, err


def test_paged_decode_step_matches_dense_cache(paged_vs_dense):
    d = paged_vs_dense
    kd, vd = kvc.gather_dense(d["cp"])
    for slot, length in enumerate(d["lengths"]):
        np.testing.assert_allclose(
            np.asarray(kd[:, slot, :length + 1]),
            np.asarray(d["cd"]["k"][:, slot, :length + 1]), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(vd[:, slot, :length + 1]),
            np.asarray(d["cd"]["v"][:, slot, :length + 1]), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(d["cp"]["length"]),
                                  np.asarray(d["paged"]["length"]) + 1)


def test_paged_decode_step_audit(paged_vs_dense, tiny_params):
    """The engine's decode step lowers with zero unprotected dot_generals
    and the paged flash decode kernel in the trace."""
    from repro.tools.audit import unprotected_dots, pallas_call_names
    d = paged_vs_dense
    fn = lambda p, t, c: tfm.paged_decode_step(p, t, c, d["cfg"],
                                               d["ctx"])[0]
    bad = unprotected_dots(fn, tiny_params, d["tok"], d["paged"])
    assert not bad, bad
    names = pallas_call_names(fn, tiny_params, d["tok"], d["paged"])
    assert any("flash_decode" in n for n in names), names


# ---------------------------------------------------------------------------
# engine: continuous batching conservation + telemetry attribution
# ---------------------------------------------------------------------------

_PROMPT_LENS = [5, 13, 9, 21]
_MAX_NEW = [6, 3, 8, 4]


@pytest.fixture(scope="module")
def engine_run(tiny_params):
    """One multi-slot engine run over 4 requests on 2 slots (forces
    queueing + slot reuse), plus per-request solo-greedy references."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, 200, (length,)) for length in _PROMPT_LENS]
    run = RunConfig(model=TINY, ft=FT_PALLAS, dtype="float32")
    em = MemoryEmitter()
    sink = MetricsSink(emitters=[em])
    eng = ServeEngine(tiny_params, TINY, run,
                      EngineConfig(max_len=64, n_slots=2, page_size=8,
                                   max_new_tokens=8), sink=sink)
    for p, m in zip(prompts, _MAX_NEW):
        eng.submit(p, max_new_tokens=m)
    res = eng.run()
    solo = []
    for p, m in zip(prompts, _MAX_NEW):
        one = ServeEngine(tiny_params, TINY, run,
                          EngineConfig(max_len=64, n_slots=1, page_size=8))
        one.submit(p, max_new_tokens=m)
        solo.append(one.run()[0])
    return dict(prompts=prompts, eng=eng, res=res, solo=solo,
                records=em.records)


def test_engine_no_starvation(engine_run):
    """Every submitted request completes with exactly its token budget."""
    res = engine_run["res"]
    assert len(res) == len(_PROMPT_LENS)
    for i, r in enumerate(res):
        assert r.rid == i
        assert r.prompt_len == _PROMPT_LENS[i]
        assert len(r.tokens) == _MAX_NEW[i]
        assert r.ttft_s >= 0.0


def test_engine_conserves_solo_greedy_tokens(engine_run):
    """Continuous batching is invisible to outputs: each request decodes to
    exactly the tokens a dedicated single-slot engine produces."""
    for r, s in zip(engine_run["res"], engine_run["solo"]):
        assert r.tokens == s.tokens, (r.rid, r.tokens, s.tokens)


def test_engine_returns_all_pages(engine_run):
    eng = engine_run["eng"]
    assert eng.alloc.n_free == eng.plan.n_pages - 1
    eng.alloc.check_invariants()
    assert not eng.alloc.live.any()


def test_engine_telemetry_attributes_decode_sites(engine_run):
    """Sink records cover both phases; decode detections land on the
    `dec_flash` site; decoded-token and TTFT accounting is exact."""
    recs = engine_run["records"]
    phases = {r["gauges"].get("phase") for r in recs}
    assert phases == {"prefill", "decode"}
    dec = [r for r in recs if r["gauges"]["phase"] == "decode"]
    sites = {row["site"] for r in dec for row in r.get("ft_sites") or ()}
    assert "dec_flash" in sites, sites
    assert all(r["ft"]["detected"] == 0.0 for r in recs)  # clean run
    dec_toks = max(r["counters"].get("decoded_tokens", 0) for r in recs)
    assert dec_toks == sum(m - 1 for m in _MAX_NEW)   # 1st tok = prefill
    n_req = max(r["counters"].get("requests", 0) for r in recs)
    assert n_req == len(_PROMPT_LENS)
    assert any("ttft_s" in r.get("hists", {}) for r in recs)


def test_engine_rejects_bad_requests(tiny_params):
    run = RunConfig(model=TINY, ft=FT_PALLAS, dtype="float32")
    eng = ServeEngine(tiny_params, TINY, run,
                      EngineConfig(max_len=32, n_slots=1, page_size=8))
    with pytest.raises(ValueError):
        eng.submit(np.arange(1, 40), max_new_tokens=4)   # > max_len
    with pytest.raises(ValueError):
        eng.submit(np.asarray([], np.int64))             # empty prompt
    with pytest.raises(ValueError):
        eng.submit(np.asarray([1, 2]), max_new_tokens=0)


def test_engine_unsupported_family_raises(tiny_params):
    from repro.configs import registry
    cfg = registry.get_smoke("mamba2-780m")
    run = RunConfig(model=cfg, ft=FT_PALLAS, dtype="float32")
    with pytest.raises(NotImplementedError):
        ServeEngine(tiny_params, cfg, run, EngineConfig())
