"""Per-site FT policy (PR 10): FTPolicy resolution semantics, the
uniform-policy ≡ legacy-FTConfig bit-identity (outputs AND tune-cache
keys), the roofline planner's budget monotonicity, the storm-escalation
promote/cool-down loop through a MemoryEmitter sink, and the in-kernel
stochastic SEU hook on the 2-D / batched / grouped / tgmm template
bodies."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import policy, telemetry
from repro.core.ft_gemm import ft_dot
from repro.core.policy import (FTConfig, FTPolicy, FT_OFF, OFFLINE_DETECT,
                               ONLINE_BLOCK, EscalationController, SiteCost,
                               plan_ft, promote, resolve_ft)
from repro.kernels import ops as kops, tune_cache
from repro.kernels.grouped import dispatch as gdisp
from repro.kernels.templates.spec import BatchedKernelSpec
from repro.models.blocks import Ctx
from repro.tools import metrics as metrics_lib

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# FTPolicy resolution: precedence, fallthrough, override
# ---------------------------------------------------------------------------


def test_policy_first_match_wins_and_fallthrough():
    p = FTPolicy(rules=(("moe_gate", FT_OFF),
                        ("moe_*", OFFLINE_DETECT),
                        ("attn_*", ONLINE_BLOCK.replace(verify="final"))),
                 default=ONLINE_BLOCK)
    assert p.resolve("moe_gate") is FT_OFF            # exact beats later glob
    assert p.resolve("moe_up") is OFFLINE_DETECT
    assert p.resolve("attn_qk").verify == "final"
    assert p.resolve("wq") is ONLINE_BLOCK            # fallthrough
    assert p.resolve(None) is ONLINE_BLOCK            # unlabelled call


def test_policy_glob_classes_match_fnmatch():
    p = FTPolicy(rules=(("dec_?k", OFFLINE_DETECT),), default=FT_OFF)
    assert p.resolve("dec_qk") is OFFLINE_DETECT
    assert p.resolve("dec_page_qk") is FT_OFF         # ? is single-char


def test_policy_override_prepends_and_wins():
    p = FTPolicy(rules=(("wq", OFFLINE_DETECT),), default=FT_OFF)
    q = p.override(("wq", ONLINE_BLOCK))
    assert q.resolve("wq") is ONLINE_BLOCK
    assert p.resolve("wq") is OFFLINE_DETECT          # original untouched
    assert q.default is FT_OFF


def test_policy_is_hashable_and_validates_rules():
    p = FTPolicy(rules=[("a", FT_OFF)], default=ONLINE_BLOCK)   # list coerced
    assert isinstance(p.rules, tuple)
    hash(p)                                           # jit-static-arg ready
    with pytest.raises(TypeError):
        FTPolicy(rules=(("a", "correct"),))
    with pytest.raises(TypeError):
        FTPolicy(default=None)


def test_resolve_ft_identity_on_bare_config():
    ft = ONLINE_BLOCK
    # The legacy bit-identity guarantee: a bare FTConfig is returned AS-IS,
    # so every downstream spec/params/cache-key derivation sees the same
    # object it always did.
    assert resolve_ft(ft, "anything") is ft
    assert resolve_ft(ft, None) is ft
    assert resolve_ft(FTPolicy.uniform(ft), "anything") is ft


def test_promote_semantics():
    assert promote(OFFLINE_DETECT) == OFFLINE_DETECT.replace(
        action="correct", verify="step")
    assert promote(FT_OFF) is FT_OFF                  # off cannot storm
    strongest = ONLINE_BLOCK.replace(verify="step")
    assert promote(strongest) == strongest


# ---------------------------------------------------------------------------
# uniform policy ≡ legacy FTConfig: outputs and tune-cache keys
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_uniform_policy_bit_identical(backend):
    ft = ONLINE_BLOCK.replace(backend=backend)
    x = _rand((4, 96, 128), seed=1)
    w = _rand((128, 80), seed=2)
    legacy = ft_dot(x, w, ft=ft, site="wq")
    keys_after_legacy = set(tune_cache.default_cache().keys())
    uniform = ft_dot(x, w, ft=FTPolicy.uniform(ft), site="wq")
    assert (np.asarray(legacy) == np.asarray(uniform)).all()
    # the policy wrapper must not mint ANY new autotune cache entries
    assert set(tune_cache.default_cache().keys()) == keys_after_legacy


def test_mixed_policy_switches_level_per_site():
    x = _rand((64, 128), seed=3)
    w = _rand((128, 64), seed=4)
    pol = FTPolicy(rules=(("wq", OFFLINE_DETECT),), default=ONLINE_BLOCK)
    spec = policy.InjectionSpec(row=3, col=5, magnitude=50.0)
    hit = ft_dot(x, w, ft=pol, spec=spec, site="wk")     # default: corrected
    np.testing.assert_allclose(np.asarray(hit), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-4)
    # detect-only rule: the SEU is flagged but NOT corrected — it survives
    missed = ft_dot(x, w, ft=pol, spec=spec, site="wq")
    assert float(jnp.abs(missed - x @ w).max()) > 1.0


# ---------------------------------------------------------------------------
# planner: cost recording + budget monotonicity
# ---------------------------------------------------------------------------


def _toy_costs():
    # one fat compute-bound projection, one medium, one memory-bound sliver
    return [SiteCost("big", "2d", 4096, 4096, 4096, in_bytes=2, count=4),
            SiteCost("mid", "2d", 1024, 1024, 1024, in_bytes=2, count=4),
            SiteCost("thin", "batched", 128, 128, 64, batch=32, in_bytes=2)]


def test_record_site_costs_via_eval_shape():
    with policy.record_site_costs() as costs:
        jax.eval_shape(lambda x, w: ft_dot(x, w, ft=ONLINE_BLOCK, site="wq"),
                       jax.ShapeDtypeStruct((32, 64), jnp.float32),
                       jax.ShapeDtypeStruct((64, 16), jnp.float32))
    assert policy._SITE_COSTS is None                 # closed cleanly
    [c] = costs.values()
    assert (c.site, c.kind, c.m, c.n, c.k) == ("wq", "2d", 32, 16, 64)
    assert c.flops > 0


def test_note_site_noop_outside_recorder():
    policy.note_site("wq", "2d", 8, 8, 8)             # must not raise


def test_plan_budget_monotone():
    costs = _toy_costs()
    rung = {("off", "final"): -1, ("off", "step"): -1}
    rung.update({r: i for i, r in enumerate(policy.LADDER)})
    prev = None
    for plan in policy.pareto_curve(costs, (0.0, 1e-4, 1e-3, 1e-2, 0.1, 1.0)):
        cur = {s.site: rung[(s.action, s.verify)] for s in plan.sites}
        if prev is not None:
            for site, lvl in cur.items():
                assert lvl >= prev["levels"][site], (site, plan.budget_frac)
            assert plan.coverage >= prev["coverage"] - 1e-12
        assert plan.overhead_s <= plan.budget_frac * plan.base_time_s + 1e-12
        prev = {"levels": cur, "coverage": plan.coverage}


def test_plan_off_sites_fall_through_to_off():
    # compute-bound sites only: their overhead is strictly positive, so a
    # zero budget covers nothing (memory-bound sites would ride in free)
    plan = plan_ft(_toy_costs()[:2], budget_frac=0.0)
    assert plan.coverage == 0.0
    assert not plan.policy.resolve("big").enabled
    assert not plan.policy.resolve("never_seen").enabled  # honest default


def test_plan_generous_budget_covers_everything_and_empty_costs_ok():
    plan = plan_ft(_toy_costs(), budget_frac=10.0)
    assert plan.coverage == 1.0
    for s in plan.sites:
        assert (s.action, s.verify) == ("correct", "step")
    assert plan_ft([], budget_frac=0.1).sites == ()


def test_plan_json_round_trips():
    import json
    plan = plan_ft(_toy_costs(), budget_frac=0.05)
    d = json.loads(plan.to_json())
    assert d["coverage"] == plan.coverage
    assert {s["site"] for s in d["sites"]} == {"big", "mid", "thin"}


# ---------------------------------------------------------------------------
# storm escalation: promote / cool-down through the MemoryEmitter sink
# ---------------------------------------------------------------------------


def _mk_report(site, det, cor=0.0, mr=1.0):
    sid = telemetry.site_id(site)
    z = jnp.zeros((1, telemetry.site_width()), jnp.float32)
    return telemetry.FTReport(
        detected=jnp.float32(det), corrected=jnp.float32(cor),
        max_residual=jnp.float32(mr),
        site_detected=z.at[0, sid].add(det),
        site_corrected=z.at[0, sid].add(cor),
        site_max_residual=z.at[0, sid].max(mr))


def test_escalation_promote_and_cooldown_via_memory_emitter():
    base = FTPolicy(rules=(("stormy", OFFLINE_DETECT),), default=ONLINE_BLOCK)
    mem = metrics_lib.MemoryEmitter()
    sink = metrics_lib.MetricsSink(
        emitters=[mem],
        detector=telemetry.StormDetector(window=4, min_detections=3.0))
    esc = EscalationController(base, cooldown_steps=3).attach(sink)
    v0 = esc.version

    promoted_step = None
    for step in range(12):
        det = 4.0 if step < 3 else 0.0                # burst, then quiet
        sink.record_ft(_mk_report("stormy", det), step=step)
        rec = sink.step_end(step)
        if promoted_step is None and "stormy" in esc.promoted_sites:
            promoted_step = step
            assert rec.get("alerts"), "alert must land in this step's record"
            assert rec["alerts"][0]["site"] == "stormy"
            lvl = esc.current_policy().resolve("stormy")
            assert lvl.corrects and lvl.verify == "step"
            assert esc.version > v0
        esc.step_end(step)

    assert promoted_step is not None
    # cool-down expired: the resolved level is back to the base rule
    assert esc.promoted_sites == {}
    assert esc.current_policy().resolve("stormy") is OFFLINE_DETECT
    assert any(r.get("alerts") for r in mem.records)


def test_escalation_ignores_unpromotable_sites():
    base = FTPolicy(rules=(("dark", FT_OFF),), default=ONLINE_BLOCK)
    esc = EscalationController(base, cooldown_steps=8)
    alert = telemetry.StormAlert(site="dark", step=0, window_steps=4,
                                 detections=9.0, rate=2.0,
                                 background_rate=0.0, threshold_rate=0.5)
    esc.handle_alert(alert)
    assert esc.promoted_sites == {}                   # off stays off
    assert esc.current_policy() is base               # no needless retrace


def test_escalation_attach_rejects_non_detector():
    with pytest.raises(TypeError):
        EscalationController(ONLINE_BLOCK).attach(object())


# ---------------------------------------------------------------------------
# in-kernel stochastic SEU hook: 2-D / batched / grouped / tgmm bodies
# ---------------------------------------------------------------------------

_FT_HOT = FTConfig(action="correct", level="block", verify="step",
                   inject_rate=0.9)


def test_stochastic_hook_2d_detects_and_corrects():
    a, b = _rand((256, 256), seed=5), _rand((256, 256), seed=6)
    out, rep = kops.ft_matmul_report(a, b, ft=_FT_HOT, key=KEY)
    assert float(rep[..., 0].sum()) > 0
    # corrected elements are reconstructed from checksums: ~eps*K residual
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=5e-3)


def test_stochastic_hook_rate_zero_bit_identical():
    a, b = _rand((256, 256), seed=5), _rand((256, 256), seed=6)
    ft = _FT_HOT.replace(inject_rate=0.0)
    out0, rep0 = kops.ft_matmul_report(a, b, ft=ft)
    out1, rep1 = kops.ft_matmul_report(a, b, ft=ft, key=KEY)
    assert (np.asarray(out0) == np.asarray(out1)).all()
    assert (np.asarray(rep0) == np.asarray(rep1)).all()


def test_stochastic_hook_batched():
    a = _rand((4, 128, 128), seed=7)
    b = _rand((4, 128, 128), seed=8)
    out, rep = gdisp.batched_gemm_call(BatchedKernelSpec(ft_level="block"),
                                       a, b, ft=_FT_HOT, key=KEY)
    assert float(rep[..., 0].sum()) > 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=5e-3)


def test_stochastic_hook_grouped_and_tgmm():
    x = _rand((512, 128), seed=9)
    w = _rand((4, 128, 128), seed=10)
    g = _rand((512, 128), seed=11)
    gids = jnp.sort(jax.random.randint(jax.random.PRNGKey(4), (512,), 0, 4))
    _, repg = gdisp.grouped_matmul_rows(
        BatchedKernelSpec(ft_level="block", grouped=True), x, w, gids,
        ft=_FT_HOT, key=KEY)
    assert float(repg[..., 0].sum()) > 0
    _, rept = gdisp.tgmm_matmul_rows(
        BatchedKernelSpec(ft_level="block", tgmm=True), x, g, gids,
        n_groups=4, ft=_FT_HOT, key=KEY)
    assert float(rept[..., 0].sum()) > 0


# ---------------------------------------------------------------------------
# Ctx.inject_sites validation
# ---------------------------------------------------------------------------


def test_ctx_rejects_unknown_inject_sites():
    telemetry.site_id("wq")                           # ensure one known label
    Ctx(ft=ONLINE_BLOCK, key=KEY, dtype=jnp.float32,
        inject_sites=("wq",)).check_inject_sites()    # known: fine
    with pytest.raises(ValueError, match="unknown"):
        Ctx(ft=ONLINE_BLOCK, key=KEY, dtype=jnp.float32,
            inject_sites=("wq", "definitely_not_a_site")
            ).check_inject_sites()
