"""Property tests for the search-based autotuner: candidate legality,
scoring determinism, and persistent-cache behaviour (kernels.search +
kernels.tune_cache + autotune.best_params)."""
import json
import os

import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import autotune, search, tune_cache
from repro.kernels.autotune import MXU, VMEM_BUDGET, KernelParams


@pytest.fixture()
def fresh_cache(tmp_path, monkeypatch):
    """Re-point the default cache at an empty per-test file."""
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    tune_cache.reset()
    yield path
    tune_cache.reset()


# ---------------------------------------------------------------------------
# Candidate enumeration invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 4096), n=st.integers(1, 4096),
       k=st.integers(1, 4096),
       in_bytes=st.sampled_from([2, 4]),
       ft_level=st.sampled_from(["off", "block", "tile", "inner"]))
def test_candidates_are_legal(m, n, k, in_bytes, ft_level):
    cands = search.enumerate_candidates(m, n, k, in_bytes=in_bytes,
                                        ft_level=ft_level)
    assert cands, (m, n, k)
    mp = autotune._round_up(m, MXU)
    np_ = autotune._round_up(n, MXU)
    kp = autotune._round_up(k, MXU)
    for p in cands:
        # MXU-aligned in every dimension
        assert p.bm % MXU == 0 and p.bn % MXU == 0 and p.bk % MXU == 0, p
        # within the VMEM working-set budget (FT scratch included)
        assert search.vmem_bytes(p, in_bytes, ft_level) <= VMEM_BUDGET, p
        # never exceeds — and exactly divides — the MXU-padded problem
        assert p.bm <= mp and p.bn <= np_ and p.bk <= kp, p
        assert (autotune._round_up(m, p.bm) % p.bm == 0
                and autotune._round_up(n, p.bn) % p.bn == 0
                and autotune._round_up(k, p.bk) % p.bk == 0)


def test_candidate_set_is_deterministic_and_covers_table_sizes():
    c1 = search.enumerate_candidates(2048, 2048, 2048)
    c2 = search.enumerate_candidates(2048, 2048, 2048)
    assert c1 == c2
    tiles = {(p.bm, p.bn, p.bk) for p in c1}
    # The static table's "huge" pick must be in the searched space.
    assert tuple(autotune.TABLE["huge"]) in tiles


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 2048), n=st.integers(1, 2048),
       k=st.integers(1, 2048))
def test_model_selection_is_deterministic(m, n, k):
    p1 = search.select_best(m, n, k, measure=False)
    p2 = search.select_best(m, n, k, measure=False)
    assert p1 == p2
    assert search.vmem_bytes(p1) <= VMEM_BUDGET


def test_predicted_time_prefers_fitting_tiles_on_ragged_shapes():
    """The roofline score must charge padding FLOPs: a 512-tile on a 160²
    problem is strictly worse than a 256-tile."""
    small = KernelParams(256, 256, 256, "small")
    huge = KernelParams(512, 512, 256, "huge")
    assert (search.predicted_time_s(160, 160, 256, small)
            < search.predicted_time_s(160, 160, 256, huge))


@settings(max_examples=15, deadline=None)
@given(dim=st.integers(1, 4096), max_tile=st.sampled_from([128, 256, 512]),
       align=st.sampled_from([8, 128]))
def test_fit_tile_minimizes_executed_work(dim, max_tile, align):
    c = search.fit_tile(dim, max_tile, align)
    assert c % align == 0 and align <= c <= max_tile
    waste = -(-dim // c) * c
    for other in range(align, max_tile + 1, align):
        assert waste <= -(-dim // other) * other


def test_fit_tile_examples():
    assert search.fit_tile(100, 128, 8) == 104      # one masked tile
    assert search.fit_tile(77, 128, 128) == 128     # lane floor
    assert search.fit_tile(300, 384, 128) == 384    # single deep k tile
    assert search.fit_tile(4096, 512, 128) == 512   # divisible → largest


# ---------------------------------------------------------------------------
# best_params + persistent cache
# ---------------------------------------------------------------------------

def test_best_params_deterministic_with_warm_cache(fresh_cache):
    p1 = autotune.best_params(300, 300, 600, measure=False)
    assert os.path.exists(fresh_cache)          # search result persisted
    p2 = autotune.best_params(300, 300, 600, measure=False)
    p3 = autotune.best_params(300, 300, 600)    # warm: no search, no measure
    assert p1 == p2 == p3
    # warm-cache hit must serve a *different* shape of the same class by
    # clamping the stored tile, never exceeding the padded problem
    p4 = autotune.best_params(64, 300, 600)
    assert p4.bm <= autotune._round_up(64, MXU)


def test_cache_round_trip(fresh_cache):
    key = tune_cache.cache_key("cpu", "small", 4, "off")
    params = KernelParams(128, 256, 384, "small")
    c = tune_cache.TuneCache(fresh_cache)
    c.put(key, params)
    reloaded = tune_cache.TuneCache(fresh_cache).get(key)
    assert reloaded == params
    # file is valid schema-tagged JSON
    with open(fresh_cache) as f:
        raw = json.load(f)
    assert raw["schema"] == 1 and key in raw["entries"]


def test_cache_corrupt_file_degrades_to_empty(fresh_cache):
    with open(fresh_cache, "w") as f:
        f.write("{not json")
    c = tune_cache.TuneCache(fresh_cache)
    assert c.get(tune_cache.cache_key("cpu", "small", 4, "off")) is None
    assert len(c) == 0
    # and the next put round-trips fine over the corrupt file
    key = tune_cache.cache_key("cpu", "huge", 2, "block")
    c.put(key, KernelParams(512, 512, 256, "huge"))
    assert tune_cache.TuneCache(fresh_cache).get(key) is not None


def test_best_params_ft_levels_keyed_separately(fresh_cache):
    autotune.best_params(256, 256, 512, measure=False, ft_level="off")
    autotune.best_params(256, 256, 512, measure=False, ft_level="tile")
    c = tune_cache.TuneCache(fresh_cache)
    kinds = {k.rsplit("/", 1)[1] for k in c.keys()}
    assert {"ft_off", "ft_tile"} <= kinds


def test_best_params_divides_padded_problem(fresh_cache):
    for (m, n, k) in [(100, 77, 300), (1, 1, 1), (2048, 2048, 2048),
                      (4096, 128, 1024)]:
        p = autotune.best_params(m, n, k, measure=False)
        mp, np_, kp = autotune.padded_shape(m, n, k, p)
        assert mp % p.bm == 0 and np_ % p.bn == 0 and kp % p.bk == 0
        assert p.vmem_bytes() <= VMEM_BUDGET
