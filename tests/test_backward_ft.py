"""Backward-path FT conformance suite (PR 4).

The paper's protection story is only end-to-end if the *backward* GEMMs —
lower arithmetic intensity, where unfused checksums cost the most (Kosaian &
Rashmi) — are covered like the forward ones. Four pillars:

  1. **Injection matrix** — land a deterministic SEU inside each backward
     GEMM (dense dx/dw, grouped dbuf, grouped tgmm-dw, fused-epilogue
     dx/dw + the saved act'(preact) residual path) at every FT level on
     both backends, and assert the corrected gradients match the clean run
     **bit-for-bit**. Integer-valued operands make the checksum algebra
     exact, so correction subtracts exactly the injected magnitude — any
     residue is a real conformance bug, not float noise.
  2. **Gradient checks** — `check_grads`-style first-order directional
     derivatives plus oracle comparisons for `ft_dot_fused` across every
     registered epilogue chain and for `ft_grouped_matmul` including the
     ragged last group, pallas vs xla vs the jnp oracle.
  3. **No-recompute** — `ft_dot_fused`'s backward consumes the saved
     act_grad residual: the grad jaxpr carries exactly 3 full GEMMs
     (forward, dx, dw), not 4 (asserted on the jaxpr, both backends).
  4. **Protection audit** — the jaxpr of one optimizer step (dense and
     MoE) on the pallas backend contains ZERO dot_generals above a FLOP
     threshold outside registry-emitted kernels (`tools.audit`) — the
     regression gate against reintroducing jnp GEMM fallbacks.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ft_dot, ft_dot_fused, ft_grouped_matmul
from repro.core.policy import FTConfig, InjectionSpec
from repro.kernels.grouped import layout as glayout


def _ints(shape, seed, lo=-3, hi=4, dtype=jnp.float32):
    """Integer-valued float arrays: checksum sums/products stay exact in
    f32, so detection thresholds see zero rounding residual and correction
    is bit-exact."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=shape), dtype)


#: (backend, level) matrix. The jnp checksum path does not branch on the
#: level, so one xla row keeps the suite fast; the pallas kernels implement
#: all three granularities.
MATRIX = [("xla", "block"), ("pallas", "block"), ("pallas", "tile"),
          ("pallas", "inner")]


# ---------------------------------------------------------------------------
# 1. backward injection matrix — corrected grads match clean bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,level", MATRIX)
@pytest.mark.parametrize("target", ["dx", "dw"])
def test_dense_bwd_injection_roundtrip(backend, level, target):
    x = _ints((32, 64), seed=1)
    w = _ints((64, 48), seed=2)
    ftc = FTConfig(level=level, backend=backend)
    inj = (target, InjectionSpec(row=2, col=3, magnitude=384.0, k_step=0))

    clean = jax.grad(lambda x, w: jnp.sum(ft_dot(x, w, ft=ftc)),
                     argnums=(0, 1))(x, w)
    hurt = jax.grad(lambda x, w: jnp.sum(ft_dot(x, w, ft=ftc,
                                                bwd_inject=inj)),
                    argnums=(0, 1))(x, w)
    for c, h in zip(clean, hurt):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(h))


@pytest.mark.parametrize("backend,level", MATRIX)
def test_dense_bwd_detect_only_leaves_error(backend, level):
    """action="detect" must NOT silently fix the backward SEU — the
    corrupted gradient element survives, proving the injection actually
    landed inside the backward GEMM (the correction in the test above is
    doing real work)."""
    x = _ints((32, 64), seed=3)
    w = _ints((64, 48), seed=4)
    ftc = FTConfig(level=level, backend=backend, action="detect")
    inj = ("dx", InjectionSpec(row=2, col=3, magnitude=384.0, k_step=0))
    clean = jax.grad(lambda x: jnp.sum(ft_dot(x, w, ft=ftc)))(x)
    hurt = jax.grad(lambda x: jnp.sum(ft_dot(x, w, ft=ftc,
                                             bwd_inject=inj)))(x)
    err = np.asarray(hurt) - np.asarray(clean)
    assert abs(err[2, 3] - 384.0) < 1e-3
    err[2, 3] = 0.0
    np.testing.assert_allclose(err, 0.0, atol=1e-5)


def _skewed_gids(t, g, seed):
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, g + 1)
    if g > 2:
        probs[g // 2] = 0.0              # empty group in the middle
    probs /= probs.sum()
    return jnp.asarray(np.sort(rng.choice(g, size=t, p=probs)), jnp.int32)


@pytest.mark.parametrize("backend,level", MATRIX)
@pytest.mark.parametrize("target", ["dbuf", "dw"])
def test_grouped_bwd_injection_roundtrip(backend, level, target):
    """The tgmm path: an SEU in the grouped backward dw (the
    output-stationary kernel on pallas, the segment-checksum einsum on
    xla) — and in dbuf (the grouped kernel on wᵀ) — is corrected to the
    clean gradients bit-for-bit, including with an empty group and a
    ragged last group in the layout."""
    t, g, k, n = 61, 4, 96, 40
    gids = _skewed_gids(t, g, seed=5)
    x = _ints((t, k), seed=6)
    w = _ints((g, k, n), seed=7, lo=-2, hi=3)
    ftc = FTConfig(level=level, backend=backend)
    inj = (target, InjectionSpec(row=1, col=2, magnitude=512.0, k_step=0))

    clean = jax.grad(lambda x, w: jnp.sum(ft_grouped_matmul(x, w, gids,
                                                            ft=ftc)),
                     argnums=(0, 1))(x, w)
    hurt = jax.grad(lambda x, w: jnp.sum(ft_grouped_matmul(
        x, w, gids, ft=ftc, bwd_inject=inj)), argnums=(0, 1))(x, w)
    for c, h in zip(clean, hurt):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(h))


@pytest.mark.parametrize("backend,level", MATRIX)
@pytest.mark.parametrize("target", ["dx", "dw"])
def test_fused_bwd_injection_roundtrip(backend, level, target):
    """Fused-epilogue backward: dpre = g ⊙ act'(preact) feeds both
    backward GEMMs from the SAVED residual; relu keeps dpre integer-valued
    so the corrected grads are bit-exact."""
    x = _ints((32, 64), seed=8)
    w = _ints((64, 48), seed=9)
    bias = _ints((48,), seed=10, lo=-2, hi=3)
    ftc = FTConfig(level=level, backend=backend)
    inj = (target, InjectionSpec(row=2, col=3, magnitude=384.0, k_step=0))

    f = lambda x, w, bi=None: jnp.sum(ft_dot_fused(
        x, w, bias=bias, act="relu", ft=ftc, bwd_inject=bi))
    clean = jax.grad(f, argnums=(0, 1))(x, w)
    hurt = jax.grad(lambda x, w: f(x, w, inj), argnums=(0, 1))(x, w)
    for c, h in zip(clean, hurt):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(h))


@pytest.mark.parametrize("backend,level", MATRIX)
def test_fused_residual_path_fwd_injection(backend, level):
    """The saved-residual path under a FORWARD SEU: the fault is corrected
    on the accumulator before act'(preact) is computed, so both the output
    and the gradients (which consume the saved residual) match the clean
    run bit-for-bit."""
    x = _ints((32, 64), seed=11)
    w = _ints((64, 48), seed=12)
    bias = _ints((48,), seed=13, lo=-2, hi=3)
    ftc = FTConfig(level=level, backend=backend)
    inj = InjectionSpec(row=4, col=5, magnitude=640.0, k_step=0)

    f = lambda x, w, sp=None: jnp.sum(ft_dot_fused(
        x, w, bias=bias, act="relu", ft=ftc, spec=sp))
    (y0, clean) = jax.value_and_grad(f, argnums=(0, 1))(x, w)
    (y1, hurt) = jax.value_and_grad(lambda x, w: f(x, w, inj),
                                    argnums=(0, 1))(x, w)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    for c, h in zip(clean, hurt):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(h))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_bwd_injection_detection_reported(backend):
    """The backward correction is observable: with action="detect" the
    corrupted element survives (asserted above); with action="correct" the
    two runs agree — and flipping the magnitude flips nothing, proving
    symmetric correction rather than coincidence."""
    x = _ints((32, 64), seed=14)
    w = _ints((64, 48), seed=15)
    ftc = FTConfig(level="block", backend=backend)
    g1 = jax.grad(lambda x: jnp.sum(ft_dot(x, w, ft=ftc, bwd_inject=(
        "dx", InjectionSpec(row=0, col=0, magnitude=384.0)))))(x)
    g2 = jax.grad(lambda x: jnp.sum(ft_dot(x, w, ft=ftc, bwd_inject=(
        "dx", InjectionSpec(row=0, col=0, magnitude=-384.0)))))(x)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


# ---------------------------------------------------------------------------
# 2. gradient checks: epilogue chains × backends vs jnp oracle
# ---------------------------------------------------------------------------

def _directional_check(f, args, grads, seed, eps=1e-3, tol=2e-2):
    """First-order check à la check_grads: (f(x+εu) − f(x−εu)) / 2ε must
    match ⟨grad, u⟩ along a random direction u."""
    rng = np.random.default_rng(seed)
    us = [jnp.asarray(rng.normal(size=a.shape), a.dtype) for a in args]
    plus = f(*[a + eps * u for a, u in zip(args, us)])
    minus = f(*[a - eps * u for a, u in zip(args, us)])
    num = (plus - minus) / (2 * eps)
    lin = sum(jnp.sum(g * u) for g, u in zip(grads, us))
    np.testing.assert_allclose(float(num), float(lin),
                               rtol=tol, atol=tol)


FUSED_CHAINS = [(True, None), (False, "relu"), (False, "gelu"),
                (True, "relu"), (True, "gelu"), (True, "silu")]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("with_bias,act", FUSED_CHAINS)
def test_fused_grads_every_chain(backend, with_bias, act):
    rng = np.random.default_rng(16)
    x = jnp.asarray(rng.normal(size=(24, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 40)), jnp.float32)
    bias = (jnp.asarray(rng.normal(size=(40,)), jnp.float32)
            if with_bias else None)
    ftc = FTConfig(level="block", backend=backend)

    def f(x, w):
        return jnp.sum(jnp.sin(ft_dot_fused(x, w, bias=bias, act=act,
                                            ft=ftc)))

    def f_ref(x, w):
        from repro.kernels.templates import epilogues
        y = x @ w
        if bias is not None:
            y = y + bias
        if act is not None:
            y = epilogues.activation(act)(y)
        return jnp.sum(jnp.sin(y))

    grads = jax.grad(f, argnums=(0, 1))(x, w)
    ref = jax.grad(f_ref, argnums=(0, 1))(x, w)
    for got, want in zip(grads, ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    _directional_check(f, (x, w), grads, seed=17)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_grouped_grads_ragged_last_group(backend):
    """First-order + oracle gradient checks for ft_grouped_matmul with a
    ragged (non-tile-multiple) last group and an empty middle group."""
    t, g, k, n = 53, 4, 64, 32
    gids = _skewed_gids(t, g, seed=18)
    assert int(jnp.sum(gids == g - 1)) % 8 != 0   # genuinely ragged last
    rng = np.random.default_rng(19)
    x = jnp.asarray(rng.normal(size=(t, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(g, k, n)), jnp.float32)
    ftc = FTConfig(level="block", backend=backend)

    def f(x, w):
        return jnp.sum(jnp.sin(ft_grouped_matmul(x, w, gids, ft=ftc)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(jnp.einsum("tk,tkn->tn", x, w[gids])))

    grads = jax.grad(f, argnums=(0, 1))(x, w)
    ref = jax.grad(f_ref, argnums=(0, 1))(x, w)
    for got, want in zip(grads, ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    _directional_check(f, (x, w), grads, seed=20)
    # the empty group's dw is exactly zero, not garbage
    empty = g // 2
    assert int(jnp.sum(gids == empty)) == 0
    assert not np.asarray(grads[1][empty]).any()


# ---------------------------------------------------------------------------
# 3. the fused backward no longer recomputes the pre-activation GEMM
# ---------------------------------------------------------------------------

def test_fused_bwd_no_preact_recompute_xla():
    from repro.tools import audit
    m, k, n = 32, 64, 48
    x = _ints((m, k), seed=21)
    w = _ints((k, n), seed=22)
    bias = _ints((n,), seed=23)
    ftc = FTConfig(level="block", backend="xla")
    f = lambda x, w: jnp.sum(ft_dot_fused(x, w, bias=bias, act="gelu",
                                          ft=ftc))
    acc = audit.flop_accounting(jax.grad(f, argnums=(0, 1)), x, w)
    full = 2.0 * m * n * k
    n_full = sum(1 for d in acc["records"] if d.flops == full)
    # forward + dx + dw — the 4th (pre-activation recompute) is gone.
    assert n_full == 3, [(d.flops, d.lhs_shape) for d in acc["records"]]


def test_fused_bwd_no_preact_recompute_pallas():
    from repro.tools import audit
    x = _ints((32, 64), seed=24)
    w = _ints((64, 48), seed=25)
    bias = _ints((48,), seed=26)
    ftc = FTConfig(level="block", backend="pallas")

    def make_vg():
        # A FRESH closure per trace: jax's tracing cache is keyed on the
        # callable, so reusing one would return the pre-toggle jaxpr.
        return jax.value_and_grad(
            lambda x, w: jnp.sum(ft_dot_fused(x, w, bias=bias, act="gelu",
                                              ft=ftc)), argnums=(0, 1))

    # ONE multi-output forward kernel (emitting act_grad) + dx + dw.
    # (count_primitives, not str().count: the printer let-binds repeated
    # sub-jaxprs and undercounts launches.)
    assert audit.count_primitives(make_vg(), x, w) == 3
    # …and the legacy flag restores the 4-launch remat-style backward.
    from repro.core import ft_gemm
    ft_gemm.FUSED_BWD_SAVE_RESIDUAL = False
    try:
        n_legacy = audit.count_primitives(make_vg(), x, w)
    finally:
        ft_gemm.FUSED_BWD_SAVE_RESIDUAL = True
    assert n_legacy == 4


def test_tgmm_kernel_single_launch():
    """The grouped backward dw is ONE pallas launch on the pallas backend
    (no segment-summed einsum fallback left in the jaxpr)."""
    t, g, k, n = 61, 4, 96, 40
    gids = _skewed_gids(t, g, seed=27)
    x = _ints((t, k), seed=28)
    w = _ints((g, k, n), seed=29, lo=-2, hi=3)
    ftc = FTConfig(level="block", backend="pallas")
    f = lambda w: jnp.sum(ft_grouped_matmul(x, w, gids, ft=ftc))
    from repro.tools import audit
    # fwd grouped + bwd dbuf grouped + bwd tgmm = 3 launches
    assert audit.count_primitives(jax.value_and_grad(f), w) == 3
    viol = audit.unprotected_dots(jax.grad(f), w, min_flops=2.0 * t * k * n)
    assert viol == []


# ---------------------------------------------------------------------------
# 4. telemetry summary cotangents: loud error, not silent drop
# ---------------------------------------------------------------------------

def test_grouped_summary_cotangent_raises():
    """Regression (satellite): _ft_grouped_bwd used to silently drop the
    (det, maxres) summary cotangents. They are now symbolic-zero-checked:
    differentiating through maxres raises a clear error, while ordinary
    y-gradients (and telemetry threading scan/remat carries — covered by
    the protection-audit tests' value_and_grad) still work."""
    from repro.core.ft_gemm import _ft_grouped_cvjp
    t, g = 24, 2
    gids = jnp.asarray([0] * 14 + [1] * 10, jnp.int32)
    x = _ints((t, 32), seed=30)
    w = _ints((g, 32, 16), seed=31)
    lay = glayout.make_layout(gids, g, 8)
    buf = glayout.scatter_rows(x, lay)
    ftc = FTConfig(level="block")

    def through_maxres(w):
        _y, _det, maxres = _ft_grouped_cvjp(ftc, None, None, buf, w,
                                            lay.gid, lay.row_end, None)
        return maxres

    with pytest.raises(ValueError, match="telemetry"):
        jax.grad(through_maxres)(w)

    def through_y(w):
        y, _det, _maxres = _ft_grouped_cvjp(ftc, None, None, buf, w,
                                            lay.gid, lay.row_end, None)
        return jnp.sum(y)

    assert jax.grad(through_y)(w).shape == w.shape


def test_moe_layer_grads_flow_with_telemetry():
    """The stop_gradient at the telemetry boundary keeps full train-path
    differentiation working: an MoE layer (grouped matmuls + report
    threading) differentiates cleanly on both backends."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as moe_lib
    from repro.models.blocks import Ctx
    mc = MoEConfig(n_experts=4, top_k=2, expert_d_ff=32)
    d = 16
    p = moe_lib.init_moe(jax.random.PRNGKey(0), d, mc, 2, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    for backend in ("xla", "pallas"):
        ctx = Ctx(ft=FTConfig(level="block", backend=backend),
                  dtype=jnp.float32)

        def loss(p):
            y, aux = moe_lib.apply_moe(p, x, mc, ctx)
            return jnp.sum(jnp.sin(y)) + 0.01 * aux

        grads = jax.grad(loss)(p)
        assert all(bool(jnp.all(jnp.isfinite(g)))
                   for g in jax.tree.leaves(grads))


# ---------------------------------------------------------------------------
# 5. flash-routed attention: oracle equivalence + protected backward
# ---------------------------------------------------------------------------

def _attn_args(seed, b=2, sq=32, h=4, kvh=2, dh=16, sk=None):
    rng = np.random.default_rng(seed)
    sk = sq if sk is None else sk
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kvh, dh)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
def test_attention_flash_matches_chunked_oracle(causal):
    """chunked_attention on the pallas backend routes through the flashft
    kernel; the chunked jnp path (attn_impl="chunked") is the oracle —
    forward and gradients must agree (GQA, both masks)."""
    from repro.models.blocks import Ctx, chunked_attention
    q, k, v = _attn_args(seed=32)
    ftc = FTConfig(level="block", backend="pallas")
    flash = Ctx(ft=ftc, dtype=jnp.float32, attn_shard="none")
    oracle = Ctx(ft=ftc, dtype=jnp.float32, attn_shard="none",
                 attn_impl="chunked")

    def run(ctx):
        f = lambda q, k, v: jnp.sum(jnp.sin(chunked_attention(
            q, k, v, causal=causal, chunk=16, ctx=ctx)))
        out = chunked_attention(q, k, v, causal=causal, chunk=16, ctx=ctx)
        return out, jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    of, gf = run(flash)
    oc, gc = run(oracle)
    np.testing.assert_allclose(np.asarray(of), np.asarray(oc),
                               rtol=1e-5, atol=1e-5)
    for a, b_ in zip(gf, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-5)


def test_attention_flash_cross_length_non_causal():
    """Whisper's cross-attention shape: Sq ≠ Skv, non-causal."""
    from repro.models.blocks import Ctx, chunked_attention
    q, k, v = _attn_args(seed=33, sq=24, sk=45)
    ftc = FTConfig(level="block", backend="pallas")
    of = chunked_attention(q, k, v, causal=False, chunk=16,
                          ctx=Ctx(ft=ftc, dtype=jnp.float32,
                                  attn_shard="none"))
    oc = chunked_attention(q, k, v, causal=False, chunk=16,
                          ctx=Ctx(ft=ftc, dtype=jnp.float32,
                                  attn_shard="none", attn_impl="chunked"))
    np.testing.assert_allclose(np.asarray(of), np.asarray(oc),
                               rtol=1e-5, atol=1e-5)


def test_attention_flash_single_kernel_no_score_transient():
    """The forward is ONE pallas launch with no dot_general outside it —
    the O(chunk·S) jnp score transient is gone from the fwd path."""
    from repro.models.blocks import Ctx, chunked_attention
    q, k, v = _attn_args(seed=34)
    ctx = Ctx(ft=FTConfig(level="block", backend="pallas"),
              dtype=jnp.float32, attn_shard="none")
    s = str(jax.make_jaxpr(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, chunk=16, ctx=ctx))(q, k, v))
    assert s.count("pallas_call") == 1
    assert "dot_general" not in s.split("pallas_call")[0]


# ---------------------------------------------------------------------------
# 6. protection audit — zero unprotected large dot_generals per train step
# ---------------------------------------------------------------------------

#: Anything ≥ this is a "large" GEMM that must run in a registry kernel.
#: The only open dots allowed below it are the MoE router einsums
#: (2·T·d·E ≈ 33 kFLOP at this scale — ~16× under the threshold; the
#: smallest protected projection is ~524 kFLOP — ~5× over it).
AUDIT_MIN_FLOPS = 1e5


def _optimizer_step(cfg):
    from repro.configs.base import RunConfig
    from repro.models import model_zoo
    from repro.optim import adamw
    from repro.train import train_loop
    run = RunConfig(model=cfg, ft=FTConfig(level="block", backend="pallas"),
                    dtype="float32", attn_chunk=32)
    tc = train_loop.TrainConfig(total_steps=10, warmup_steps=2)
    opt_cfg = adamw.AdamWConfig()
    step = train_loop.make_train_step(cfg, run, opt_cfg, tc)
    params = model_zoo.module_for(cfg).init(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    opt_state = train_loop.init_opt_state(params, opt_cfg, tc)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
             "labels": jnp.zeros((2, 32), jnp.int32)}
    return (lambda p, o, b: step(p, o, b, jnp.zeros((), jnp.int32)),
            params, opt_state, batch)


def _audit_cfgs():
    from repro.configs.base import ModelConfig, MoEConfig
    dense = ModelConfig(arch_id="audit-dense", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                        d_ff=128, vocab_size=512)
    moe = ModelConfig(arch_id="audit-moe", family="moe", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=512,
                      moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=64))
    return {"dense": dense, "moe": moe}


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_protection_audit_train_step(family):
    """The acceptance criterion: a full optimizer step's jaxpr (forward,
    backward, AdamW update) on the pallas backend has ZERO dot_generals at
    or above AUDIT_MIN_FLOPS outside pallas kernels — every large GEMM,
    including all backward GEMMs, runs under in-kernel ABFT."""
    from repro.tools import audit
    cfg = _audit_cfgs()[family]
    fn, params, opt_state, batch = _optimizer_step(cfg)
    viol = audit.unprotected_dots(fn, params, opt_state, batch,
                                  min_flops=AUDIT_MIN_FLOPS)
    assert viol == [], [(v.flops, v.lhs_shape, v.rhs_shape) for v in viol]
    acc = audit.flop_accounting(fn, params, opt_state, batch)
    assert acc["kernel_fraction"] > 0.99
    assert acc["n_kernel_dots"] > 0


def test_protection_audit_catches_regressions():
    """The audit is not vacuous: the same step with the xla (jnp checksum)
    backend HAS large open dot_generals — so a future fallback
    reintroduction would fail the gate above."""
    from repro.configs.base import RunConfig
    from repro.models import model_zoo
    from repro.optim import adamw
    from repro.tools import audit
    from repro.train import train_loop
    cfg = _audit_cfgs()["dense"]
    run = RunConfig(model=cfg, ft=FTConfig(level="block", backend="xla"),
                    dtype="float32", attn_chunk=32)
    tc = train_loop.TrainConfig(total_steps=10, warmup_steps=2)
    opt_cfg = adamw.AdamWConfig()
    step = train_loop.make_train_step(cfg, run, opt_cfg, tc)
    params = model_zoo.module_for(cfg).init(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    opt_state = train_loop.init_opt_state(params, opt_cfg, tc)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
             "labels": jnp.zeros((2, 32), jnp.int32)}
    viol = audit.unprotected_dots(
        lambda p, o, b: step(p, o, b, jnp.zeros((), jnp.int32)),
        params, opt_state, batch, min_flops=AUDIT_MIN_FLOPS)
    assert len(viol) > 0
