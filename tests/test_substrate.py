"""Substrate tests: optimizer (incl. q8 states), schedule, data pipeline,
checkpointing (atomic/async/resume), gradient compression."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.optim import adamw, schedule
from repro.data.pipeline import TokenPipeline
from repro.checkpoint.ckpt import Checkpointer
from repro.distributed import compress


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (16, 8)),
            "b": jnp.zeros((8,)),
            "emb": jax.random.normal(k2, (32, 16)) * 0.1}


def _toy_loss(params, x, y):
    h = jnp.take(params["emb"], x, axis=0)
    logits = h @ params["w"] + params["b"]
    return jnp.mean((logits - y) ** 2)


def _run_steps(q8: bool, n: int = 30, compress_grads: bool = False):
    cfg = adamw.AdamWConfig(lr=1e-2, q8=q8)
    params = _toy_params(jax.random.PRNGKey(0))
    state = adamw.init(params, cfg)
    err = compress.init_error(params) if compress_grads else None
    losses = []
    for i in range(n):
        key = jax.random.PRNGKey(100 + i)
        x = jax.random.randint(key, (64,), 0, 32)
        y = jnp.sin(jnp.arange(8) + x[:, None] * 0.1)
        loss, g = jax.value_and_grad(_toy_loss)(params, x, y)
        if compress_grads:
            g, err = compress.compress_decompress(g, err)
        params, state, _ = adamw.apply(params, g, state, cfg)
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    losses = _run_steps(q8=False)
    assert losses[-1] < losses[0] * 0.5


def test_adamw_q8_convergence_parity():
    """int8 moment states track f32 AdamW closely (memory-fit mode for the
    480B configs)."""
    l32 = _run_steps(q8=False)
    l8 = _run_steps(q8=True)
    assert l8[-1] < l32[0] * 0.5
    assert abs(l8[-1] - l32[-1]) < 0.2 * abs(l32[0])


def test_compressed_grads_convergence_parity():
    """int8 error-feedback compression must not break convergence."""
    base = _run_steps(q8=False)
    comp = _run_steps(q8=False, compress_grads=True)
    assert comp[-1] < base[0] * 0.5


def test_schedule_shape():
    assert float(schedule.warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert abs(float(schedule.warmup_cosine(10, warmup=10, total=100)) - 1.0) < 1e-6
    end = float(schedule.warmup_cosine(100, warmup=10, total=100))
    assert 0.05 < end < 0.15


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_resume():
    p = TokenPipeline(vocab_size=1000, global_batch=8, seq_len=16, seed=7)
    b5 = p.batch_at(5)
    b5_again = p.batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], b5_again["tokens"])
    # iterator from step 5 yields the same batch
    it = p.iter_from(5, prefetch=0)
    np.testing.assert_array_equal(next(it)["tokens"], b5["tokens"])


def test_pipeline_host_sharding_partitions_batch():
    full = TokenPipeline(1000, 8, 16, seed=7)
    h0 = TokenPipeline(1000, 8, 16, seed=7, host_id=0, n_hosts=2)
    h1 = TokenPipeline(1000, 8, 16, seed=7, host_id=1, n_hosts=2)
    assert h0.local_batch == 4 and h1.local_batch == 4
    t0, t1 = h0.batch_at(3)["tokens"], h1.batch_at(3)["tokens"]
    assert not np.array_equal(t0, t1)       # different host slices
    assert full.batch_at(3)["tokens"].shape == (8, 16)


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(1000, 2, 16, seed=1)
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)


def test_pipeline_prefetch_iterator():
    p = TokenPipeline(1000, 2, 8, seed=3)
    it = p.iter_from(0, prefetch=2)
    a = next(it)
    np.testing.assert_array_equal(a["tokens"], p.batch_at(0)["tokens"])
    b = next(it)
    np.testing.assert_array_equal(b["tokens"], p.batch_at(1)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"params": _toy_params(jax.random.PRNGKey(1)),
            "opt": {"count": jnp.ones((), jnp.int32)}}
    ck.save(10, tree, meta={"note": "hello"})
    restored, step, meta = ck.restore(tree)
    assert step == 10 and meta["note"] == "hello"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_bf16_preserved(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.asarray([[1.5, -2.25]], jnp.bfloat16)}
    ck.save(1, tree)
    restored, _, _ = ck.restore(tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"x": jnp.arange(4.0)}
    ck.save_async(7, tree)
    ck.wait()
    restored, step, _ = ck.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.arange(4.0))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.zeros((1,))})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


# ---------------------------------------------------------------------------
# compression numerics
# ---------------------------------------------------------------------------

def test_compress_error_feedback_bounds_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    err = compress.init_error(g)
    deq, err2 = compress.compress_decompress(g, err)
    # single-step quantization error ≤ scale/2 elementwise
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale * 0.51
    # error feedback carries the residual exactly
    np.testing.assert_allclose(np.asarray(err2["w"]),
                               np.asarray(g["w"] - deq["w"]), rtol=1e-6)
