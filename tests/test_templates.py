"""Kernel-template subsystem validation: every registered variant
(ft_level × masked/plain × epilogue chain) against the unfused two-pass
oracle composition, ABFT injection round-trips through every epilogue
chain, spec validation, variant-aware tuning keys, and the
register-a-new-epilogue extension path."""
import numpy as np
import pytest
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.kernels import autotune, ops, ref, tune_cache
from repro.kernels.templates import KernelSpec, epilogues, registry
from repro.kernels.templates import spec as spec_mod
from repro.core.policy import FTConfig, InjectionSpec

P128 = autotune.KernelParams(128, 128, 128)

#: Every epilogue chain shipped by the registry (plus the empty chain —
#: the legacy plain variant), in canonical bias→act→residual order.
CHAINS = [
    (),
    ("bias",),
    ("relu",),
    ("gelu",),
    ("silu",),
    ("residual",),
    ("bias", "gelu"),
    ("bias", "silu"),
    ("bias", "residual"),
    ("bias", "relu", "residual"),
    ("bias", "gelu", "residual"),
]

#: Per-dtype tolerances (fused applies the chain to the f32 accumulator;
#: the oracle composes the same formulas — differences are rounding-level).
TOL = {jnp.float32: (1e-5, 1e-3), jnp.bfloat16: (2e-2, 2e-1)}


def _rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


def _operands(m, n, k, dtype, seed=0):
    a = _rand((m, k), dtype, seed)
    b = _rand((k, n), dtype, seed + 1)
    bias = _rand((n,), dtype, seed + 2)
    res = _rand((m, n), dtype, seed + 3)
    return a, b, bias, res


def _maybe(chain, bias, res):
    return (bias if "bias" in chain else None,
            res if "residual" in chain else None)


# ---------------------------------------------------------------------------
# fused vs unfused numerics — every variant, per dtype, aligned + ragged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("chain", CHAINS)
def test_fused_matches_unfused_composition(chain, dtype):
    m, n, k = 256, 256, 384
    a, b, bias, res = _operands(m, n, k, dtype, seed=7)
    bias_c, res_c = _maybe(chain, bias, res)
    spec = KernelSpec(epilogue=chain)
    got, rep = ops.gemm_call(spec, a, b, bias=bias_c, residual=res_c,
                             params=P128, interpret=True)
    assert rep is None
    want = ref.fused_matmul_ref(a, b, bias=bias_c, residual=res_c,
                                chain=chain)
    rtol, atol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("level", ["block", "tile", "inner"])
@pytest.mark.parametrize("chain", [("bias",), ("bias", "gelu"),
                                   ("bias", "silu", "residual"),
                                   ("residual", "relu")])
def test_ft_fused_matches_unfused_clean(chain, level):
    """FT variants of every chain: clean runs produce the unfused
    composition with zero false positives — the checksum comparison
    (folded through the linear prefix in block mode) stays calibrated."""
    m, n, k = 256, 384, 256
    a, b, bias, res = _operands(m, n, k, jnp.float32, seed=11)
    bias_c, res_c = _maybe(chain, bias, res)
    spec = KernelSpec(ft_level=level, epilogue=chain)
    got, rep = ops.gemm_call(spec, a, b, bias=bias_c, residual=res_c,
                             ft=FTConfig(level=level), params=P128,
                             interpret=True)
    want = ref.fused_matmul_ref(a, b, bias=bias_c, residual=res_c,
                                chain=chain)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)
    assert float(rep[..., 0].sum()) == 0.0, "false positive through epilogue"


@pytest.mark.parametrize("chain", [("bias",), ("gelu",), ("bias", "gelu"),
                                   ("bias", "silu", "residual"),
                                   ("bias", "residual")])
def test_masked_ragged_fused_matches_unfused(chain):
    """Ragged shapes take the masked variant; zero-padded aux operands keep
    the epilogue (and its checksum fold) exact on edge tiles."""
    m, n, k = 100, 77, 300
    a, b, bias, res = _operands(m, n, k, jnp.float32, seed=13)
    bias_c, res_c = _maybe(chain, bias, res)
    for level in ("off", "block"):
        spec = KernelSpec(ft_level=level, epilogue=chain)
        ft = FTConfig(level=level) if level != "off" else None
        got, rep = ops.gemm_call(spec, a, b, bias=bias_c, residual=res_c,
                                 ft=ft, interpret=True)
        want = ref.fused_matmul_ref(a, b, bias=bias_c, residual=res_c,
                                    chain=chain)
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)
        if rep is not None:
            assert float(rep[..., 0].sum()) == 0.0


# ---------------------------------------------------------------------------
# ABFT survives every epilogue chain: injection round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", ["block", "tile", "inner"])
@pytest.mark.parametrize("chain", [("bias",), ("bias", "gelu"),
                                   ("bias", "silu", "residual"),
                                   ("residual", "relu")])
def test_injection_detected_and_corrected_through_epilogue(chain, level):
    m, n, k = 256, 256, 384
    a, b, bias, res = _operands(m, n, k, jnp.float32, seed=17)
    bias_c, res_c = _maybe(chain, bias, res)
    spec = KernelSpec(ft_level=level, epilogue=chain)
    inj = InjectionSpec(row=130, col=200, magnitude=77.0, k_step=1)
    got, rep = ops.gemm_call(spec, a, b, bias=bias_c, residual=res_c,
                             ft=FTConfig(level=level), inject=inj,
                             params=P128, interpret=True)
    want = ref.fused_matmul_ref(a, b, bias=bias_c, residual=res_c,
                                chain=chain)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)
    assert float(rep[..., 0].sum()) == 1.0
    assert float(rep[..., 1].sum()) == 1.0
    blk = np.asarray(rep[130 // 128, 200 // 128])
    assert int(blk[2]) == 130 and int(blk[3]) == 200


def test_injection_at_last_kstep_hits_folded_verify():
    """An SEU landing in the final k-step interval is only visible to the
    *post-epilogue* (folded) checksum comparison of block mode — the test
    that the fold is real, not just a re-ordering."""
    m, n, k = 256, 256, 384
    a, b, bias, res = _operands(m, n, k, jnp.float32, seed=19)
    spec = KernelSpec(ft_level="block", epilogue=("bias", "residual"))
    inj = InjectionSpec(row=10, col=20, magnitude=55.0, k_step=2)  # last step
    got, rep = ops.gemm_call(spec, a, b, bias=bias, residual=res,
                             ft=FTConfig(level="block"), inject=inj,
                             params=P128, interpret=True)
    want = ref.fused_matmul_ref(a, b, bias=bias, residual=res,
                                chain=("bias", "residual"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)
    assert float(rep[..., 0].sum()) == 1.0


def test_detect_only_leaves_error_through_epilogue():
    m, n, k = 256, 256, 256
    a, b, bias, _ = _operands(m, n, k, jnp.float32, seed=23)
    spec = KernelSpec(ft_level="block", epilogue=("bias",))
    inj = InjectionSpec(row=10, col=20, magnitude=55.0, k_step=0)
    got, rep = ops.gemm_call(spec, a, b, bias=bias,
                             ft=FTConfig(level="block", action="detect"),
                             inject=inj, params=P128, interpret=True)
    want = ref.fused_matmul_ref(a, b, bias=bias, chain=("bias",))
    err = np.asarray(got) - np.asarray(want)
    assert abs(err[10, 20] - 55.0) < 1e-3           # error left in place
    assert float(rep[..., 0].sum()) >= 1.0          # flagged
    assert float(rep[..., 1].sum()) == 0.0          # never corrected


@settings(max_examples=10, deadline=None)
@given(row=st.integers(0, 255), col=st.integers(0, 255),
       k_step=st.integers(0, 2),
       mag=st.floats(min_value=1.0, max_value=1e5),
       sign=st.sampled_from([-1.0, 1.0]))
def test_property_any_seu_corrected_through_fused_chain(row, col, k_step,
                                                        mag, sign):
    """∀ (location, step, |magnitude| > τ): the fused bias+gelu FT variant
    restores the clean fused result — the paper's correctness claim holds
    post-epilogue."""
    m, n, k = 256, 256, 384
    a, b, bias, _ = _operands(m, n, k, jnp.float32, seed=29)
    spec = KernelSpec(ft_level="block", epilogue=("bias", "gelu"))
    inj = InjectionSpec(row=row, col=col, magnitude=sign * mag,
                        k_step=k_step)
    got, rep = ops.gemm_call(spec, a, b, bias=bias,
                             ft=FTConfig(level="block"), inject=inj,
                             params=P128, interpret=True)
    want = ref.fused_matmul_ref(a, b, bias=bias, chain=("bias", "gelu"))
    # gelu is 1-Lipschitz, so the post-correction residue stays bounded by
    # the pre-activation tolerance.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=max(1e-3, 4e-7 * mag))
    assert float(rep[..., 0].sum()) >= 1.0


# ---------------------------------------------------------------------------
# spec validation + registry extension
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        KernelSpec(ft_level="warp")
    with pytest.raises(KeyError):
        KernelSpec(epilogue=("swish",))
    with pytest.raises(ValueError):
        KernelSpec(ft_level="block", acc_dtype="bfloat16")  # FT needs f32
    with pytest.raises(ValueError):
        KernelSpec(epilogue=("bias", "gelu", "bias"))  # two vector aux slots
    s = spec_mod.fused(bias=True, act="gelu", residual=True,
                       ft_level="block")
    assert s.epilogue == ("bias", "gelu", "residual")
    assert s.needs_bias and s.needs_residual and s.ft
    assert s.fold_split() == 1          # bias folds; gelu ends the prefix


def test_register_new_epilogue_roundtrip():
    """The extension path from the package docstring: register an op, use
    it in a spec, run it, clean up."""
    name = "scale2x"
    epilogues.register(epilogues.EpilogueOp(
        name, linear=False, apply=lambda y, aux: 2.0 * y), overwrite=True)
    try:
        a, b, _, _ = _operands(128, 128, 128, jnp.float32, seed=31)
        got, _ = ops.gemm_call(KernelSpec(epilogue=(name,)), a, b,
                               params=P128, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   2.0 * np.asarray(ref.matmul_ref(a, b)),
                                   rtol=1e-5, atol=1e-3)
    finally:
        del epilogues.REGISTRY[name]
    with pytest.raises(KeyError):
        KernelSpec(epilogue=(name,))


def test_acc_dtype_variant():
    """The accumulate-dtype spec axis: bf16 accumulation is a legal non-FT
    variant (lower precision, smaller scratch)."""
    a, b, _, _ = _operands(128, 128, 256, jnp.bfloat16, seed=37)
    got, _ = ops.gemm_call(KernelSpec(acc_dtype="bfloat16"), a, b,
                           params=P128, interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-1)


def test_out_dtype_cast_variant():
    a, b, _, _ = _operands(128, 128, 128, jnp.float32, seed=41)
    got, _ = ops.gemm_call(KernelSpec(out_dtype="bfloat16"), a, b,
                           params=P128, interpret=True)
    assert got.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# variant-aware autotuning
# ---------------------------------------------------------------------------

@pytest.fixture()
def fresh_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    tune_cache.reset()
    yield path
    tune_cache.reset()


def test_tuning_cache_key_distinguishes_variants(fresh_cache):
    m, n, k = 300, 300, 600
    plain = autotune.best_params(m, n, k, measure=False)
    fused = autotune.best_params(
        m, n, k, measure=False,
        spec=KernelSpec(epilogue=("bias", "gelu", "residual")))
    c = tune_cache.TuneCache(fresh_cache)
    variants = {key.rsplit("/", 1)[1] for key in c.keys()
                if "/v_" in key}
    assert "v_bias+gelu+residual" in variants
    assert any("/v_" not in key for key in c.keys())   # plain key unchanged
    # both winners are legal under their own working-set model
    from repro.kernels import search
    assert search.vmem_bytes(plain) <= autotune.VMEM_BUDGET
    assert search.vmem_bytes(
        fused, 4, "off",
        KernelSpec(epilogue=("bias", "gelu", "residual"))
    ) <= autotune.VMEM_BUDGET


def test_residual_spec_shrinks_candidate_space():
    """The residual aux stream adds double-buffered output-sized VMEM — at
    the budget edge (8-byte elements) the legal candidate set under the
    fused spec is a strict subset, and every fused candidate is legal under
    the fused working-set model."""
    from repro.kernels import search
    spec = KernelSpec(epilogue=("residual",))
    base = search.enumerate_candidates(2048, 2048, 2048, in_bytes=8)
    fused = search.enumerate_candidates(2048, 2048, 2048, in_bytes=8,
                                        spec=spec)
    assert set(fused) < set(base)
    for p in fused:
        assert search.vmem_bytes(p, 8, "off", spec) <= autotune.VMEM_BUDGET
    # the model itself: extra = 2 × bm·bn·in_bytes for the residual stream
    p = autotune.KernelParams(256, 512, 256)
    assert (search.vmem_bytes(p, 4, "off", spec) - search.vmem_bytes(p, 4)
            == 2 * 256 * 512 * 4)


def test_variant_key_canonical():
    assert KernelSpec().variant_key() == ""
    assert KernelSpec(epilogue=("bias", "silu")).variant_key() == "bias+silu"
    assert (KernelSpec(out_dtype="bfloat16").variant_key() == "outbf16")
    key = tune_cache.cache_key("cpu", "small", 4, "off",
                               variant="bias+silu")
    assert key.endswith("/v_bias+silu")
    assert tune_cache.cache_key("cpu", "small", 4, "off") == \
        "cpu/small/b4/ft_off"


def test_dispatch_info_derives_width_from_dtype():
    """bf16 shapes get the 16-row sublane floor (not f32's 8) for fitted
    masked tiles — the dtype-width plumbing fix."""
    info16 = ops.dispatch_info(100, 77, 300, P128, dtype=jnp.bfloat16)
    info32 = ops.dispatch_info(100, 77, 300, P128, dtype=jnp.float32)
    assert info16["masked_params"].bm % 16 == 0
    assert info32["masked_params"].bm == 104          # 8-aligned fit
    assert info16["masked_params"].bm != info32["masked_params"].bm
