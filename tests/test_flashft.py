"""Flash-FT attention kernel validation (interpret mode) vs the pure-jnp
oracle, including in-kernel SEU injection + correction."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK


def _qkv(bh=2, sq=256, skv=256, dh=64, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (bh, sq, dh), dtype)
    k = jax.random.normal(ks[1], (bh, skv, dh), dtype)
    v = jax.random.normal(ks[2], (bh, skv, dh), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 256, 256, 64), (1, 128, 384, 128),
                                   (2, 200, 256, 80)])
def test_flash_ft_matches_oracle(shape, causal):
    bh, sq, skv, dh = shape
    if not causal and skv % 128 != 0:
        pytest.skip("non-causal needs aligned skv")
    if causal and sq != skv:
        pytest.skip("causal oracle assumes aligned positions")
    q, k, v = _qkv(bh, sq, skv, dh)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 0.0, "false positive"


def test_flash_ft_corrects_injected_seu():
    q, k, v = _qkv(2, 256, 256, 64)
    # SEU in the PV accumulator of head 1, q-block 1, kv-step 0, elem (7, 20)
    # (bq/bkv pinned: the injection addresses a specific grid block, so the
    # autotuned tile — which may merge blocks — must not re-shape the grid)
    spec = InjectionSpec(row=7, col=20, magnitude=1000.0, k_step=0)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, spec=spec, inj_bh=1,
                            inj_q_block=1, bq=128, bkv=128)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 1.0
    assert float(rep[1, 1, 0]) == 1.0          # right (head, q-block)
    assert abs(float(rep[1, 1, 4]) - 1000.0) < 1.0


def test_flash_ft_detect_only_leaves_error():
    q, k, v = _qkv(1, 128, 128, 64)
    spec = InjectionSpec(row=3, col=5, magnitude=100.0, k_step=0)
    ft = FTConfig(level="block", action="detect")
    out, rep = ops.flash_ft(q, k, v, ft=ft, spec=spec)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out - want)))
    assert err > 0.01                          # corruption visible
    assert float(rep[..., 0].sum()) >= 1.0
    assert float(rep[..., 1].sum()) == 0.0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_ft_dtypes(dtype):
    q, k, v = _qkv(1, 128, 128, 128, dtype=dtype)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert float(rep[..., 0].sum()) == 0.0


# ---------------------------------------------------------------------------
# Ragged sequence lengths: masked dispatch via scalar-prefetched true dims
# ---------------------------------------------------------------------------

RAGGED_SEQS = [
    (2, 100, 200, 64),       # both seq dims ragged
    (1, 200, 200, 80),       # equal ragged (causal-compatible)
    (2, 57, 131, 64),        # primes
    (1, 300, 96, 128),       # skv < one kv block
]


@pytest.mark.parametrize("shape", RAGGED_SEQS)
def test_flash_ft_ragged_noncausal(shape):
    """Non-causal ragged Skv — previously asserted out (zero-padded K rows
    scored 0 and leaked attention); now the kernel masks positions past the
    scalar-prefetched true Skv to -inf, so any length is exact."""
    bh, sq, skv, dh = shape
    q, k, v = _qkv(bh, sq, skv, dh, seed=11)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    assert out.shape == (bh, sq, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 0.0, "false positive on ragged"


def test_flash_ft_ragged_causal():
    q, k, v = _qkv(1, 200, 200, 80, seed=12)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 0.0


@pytest.mark.parametrize("shape", [
    (2, 100, 200, 64),       # ragged Sq < Skv, both off-tile
    (1, 57, 131, 80),        # primes
    (2, 128, 200, 64),       # aligned Sq, ragged Skv
    (1, 40, 512, 128),       # chunked-prefill-like: short q, long history
])
def test_flash_ft_ragged_causal_cross_length(shape):
    """Causal with Sq ≠ Skv — previously only the padded Sq == Skv frame
    was causally correct; now the in-kernel causal∧kv-edge mask is
    bottom-right aligned on the scalar-prefetched TRUE lengths, so ragged
    cross-length causal attention (the decode/chunked-prefill setting,
    Skv ≥ Sq) is exact on fitted blocks."""
    bh, sq, skv, dh = shape
    q, k, v = _qkv(bh, sq, skv, dh, seed=21)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.shape == (bh, sq, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 0.0, "false positive on ragged causal"


def test_flash_ft_ragged_causal_cross_length_corrects_seu():
    q, k, v = _qkv(1, 100, 200, 64, seed=22)
    spec = InjectionSpec(row=5, col=11, magnitude=400.0, k_step=0)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True,
                            spec=spec, inj_q_block=0)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 1.0


def test_flash_ft_ragged_corrects_injected_seu():
    """ABFT must survive the ragged kv masking: one SEU in the PV
    accumulator on a ragged shape is detected and corrected."""
    q, k, v = _qkv(1, 200, 200, 64, seed=13)
    spec = InjectionSpec(row=3, col=9, magnitude=500.0, k_step=0)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True,
                            spec=spec, inj_q_block=0)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 1.0


def test_flash_ft_ragged_avoids_class_tile_padding():
    """The seq blocks are fitted to the ragged lengths (sublane-aligned
    bq), not padded to full 128-tiles: sq=200 runs as one 200-row block."""
    from repro.kernels import search
    assert search.fit_tile(200, 256, 8) == 200
    assert search.fit_tile(100, 128, 8) == 104


@settings(max_examples=8, deadline=None)
@given(row=st.integers(0, 127), col=st.integers(0, 63),
       kv_step=st.integers(0, 1), mag=st.floats(10.0, 1e5),
       sign=st.sampled_from([-1.0, 1.0]))
def test_flash_ft_property_seu_corrected(row, col, kv_step, mag, sign):
    # inject into q-block 1 so both kv steps are causally live
    q, k, v = _qkv(1, 256, 256, 64, seed=3)
    spec = InjectionSpec(row=row, col=col, magnitude=sign * mag,
                         k_step=kv_step)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, spec=spec,
                            inj_q_block=1, bq=128, bkv=128)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=max(1e-3, 4e-7 * mag))
    assert float(rep[..., 0].sum()) >= 1.0
