"""Optional-import shim around `hypothesis` so the suite is self-contained.

When `hypothesis` is installed, re-exports the real `given` / `settings` /
`strategies as st` untouched. When it is absent, degrades every `@given`
property test into a *seeded* `pytest.mark.parametrize` sweep: each strategy
draws `FALLBACK_EXAMPLES` deterministic samples from one shared NumPy
generator, so a clean environment still runs a meaningful (if shallower)
randomized sweep instead of failing collection.

Only the strategy surface actually used by this suite is implemented:
`st.integers`, `st.floats` (with `min_value`/`max_value`, positional or
keyword), `st.sampled_from`, and `Strategy.map`.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as _np
    import pytest as _pytest

    FALLBACK_EXAMPLES = 12
    _SEED = 0xF7B1A5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            # Log-uniform when the range spans decades (mirrors how these
            # tests use floats: injection magnitudes from 1 to 1e6).
            lo, hi = float(min_value), float(max_value)
            if lo > 0 and hi / lo > 1e3:
                return _Strategy(lambda rng: float(
                    _np.exp(rng.uniform(_np.log(lo), _np.log(hi)))))
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

    st = _Strategies()

    def settings(*_a, **_kw):
        """No-op in fallback mode (sweep size is FALLBACK_EXAMPLES)."""
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = sorted(strategies)
        def deco(fn):
            rng = _np.random.default_rng(_SEED)
            cases = [tuple(strategies[n].draw(rng) for n in names)
                     for _ in range(FALLBACK_EXAMPLES)]
            if len(names) == 1:      # pytest wants scalars, not 1-tuples
                cases = [c[0] for c in cases]
            return _pytest.mark.parametrize(",".join(names), cases)(fn)
        return deco
