"""Property tests for the paged KV cache (train/kv_cache.py).

Random alloc/grow/free traces drive the host-side `PageAllocator` while a
numpy mirror shadows the device-side pool — the invariants under test:

  * no page is ever aliased across live slots (checked independently of
    `check_invariants`, so the test doesn't trust the code under test);
  * free-list conservation: every non-null page is live xor free;
  * the reserved null page never enters a live row or the free list;
  * gather-via-page-table == the dense mirror for every live slot, for
    arbitrary interleavings of prefill writes, token appends and frees —
    i.e. page recycling never leaks a previous tenant's KV into a reader.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.train import kv_cache as kvc


def _independent_invariants(alloc: kvc.PageAllocator) -> None:
    """Re-derive the allocator invariants without calling the allocator's
    own checker."""
    live = np.flatnonzero(alloc.live)
    owned = []
    for s in live:
        row = alloc.page_table[s, : alloc.n_alloc[s]].tolist()
        assert kvc.NULL_PAGE not in row
        # enough capacity for the recorded length
        assert alloc.n_alloc[s] * alloc.page_size >= alloc.lengths[s]
        owned.extend(row)
    assert len(set(owned)) == len(owned), "page aliased across live slots"
    free = list(alloc._free)
    assert kvc.NULL_PAGE not in free
    assert not set(owned) & set(free), "page both live and free"
    assert len(owned) + len(free) == alloc.n_pages - 1, "page leaked"
    for s in np.flatnonzero(~alloc.live):
        assert (alloc.page_table[s] == kvc.NULL_PAGE).all()


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_alloc_trace_invariants(seed):
    """Random alloc/grow/free trace: invariants hold after every op."""
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 5))
    max_pages = int(rng.integers(1, 7))
    page = int(rng.choice([4, 8, 16]))
    n_pages = int(rng.integers(2, 2 + n_slots * max_pages))
    alloc = kvc.PageAllocator(n_pages, n_slots, max_pages, page)
    for _ in range(60):
        op = rng.integers(0, 3)
        live = [int(s) for s in np.flatnonzero(alloc.live)]
        if op == 0:
            length = int(rng.integers(0, max_pages * page + 1))
            if alloc.can_admit(length):
                slot, pages = alloc.alloc_slot(length)
                assert len(pages) == alloc.pages_for(length)
                assert alloc.lengths[slot] == length
        elif op == 1 and live:
            slot = int(rng.choice(live))
            new_len = int(alloc.lengths[slot]) + int(rng.integers(1, page + 1))
            if (alloc.pages_for(new_len) <= max_pages
                    and alloc.pages_for(new_len) - alloc.n_alloc[slot]
                    <= alloc.n_free):
                alloc.ensure(slot, new_len)
                assert alloc.lengths[slot] == new_len
        elif op == 2 and live:
            slot = int(rng.choice(live))
            held = int(alloc.n_alloc[slot])
            before = alloc.n_free
            pages = alloc.free_slot(slot)
            assert len(pages) == held
            assert alloc.n_free == before + held
        alloc.check_invariants()
        _independent_invariants(alloc)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_free_list_conservation_after_churn(seed):
    """After freeing everything, every non-null page is back on the free
    list exactly once."""
    rng = np.random.default_rng(seed)
    page, mp, n_slots = 8, 4, 3
    alloc = kvc.PageAllocator(1 + n_slots * mp, n_slots, mp, page)
    for _ in range(40):
        if rng.random() < 0.6:
            length = int(rng.integers(1, mp * page + 1))
            if alloc.can_admit(length):
                alloc.alloc_slot(length)
        else:
            live = np.flatnonzero(alloc.live)
            if len(live):
                alloc.free_slot(int(rng.choice(live)))
    for s in np.flatnonzero(alloc.live):
        alloc.free_slot(int(s))
    assert alloc.n_free == alloc.n_pages - 1
    assert sorted(alloc._free) == list(range(1, alloc.n_pages))
    alloc.check_invariants()


def test_allocator_errors():
    with pytest.raises(ValueError):
        kvc.PageAllocator(1, 1, 1, 8)          # no room for the null page
    alloc = kvc.PageAllocator(4, 2, 2, 8)      # 3 usable pages
    with pytest.raises(ValueError):
        alloc.alloc_slot(3 * 8)                # needs 3 pages > max_pages
    s0, _ = alloc.alloc_slot(16)               # 2 pages
    with pytest.raises(RuntimeError):
        alloc.alloc_slot(16)                   # pool exhausted (1 page left)
    s1, _ = alloc.alloc_slot(8)
    with pytest.raises(RuntimeError):
        alloc.ensure(s1, 16)                   # pool exhausted mid-grow
    with pytest.raises(RuntimeError):
        alloc.alloc_slot(1)                    # no free slot
    alloc.free_slot(s0)
    with pytest.raises(RuntimeError):
        alloc.free_slot(s0)                    # double free
    with pytest.raises(RuntimeError):
        alloc.ensure(s0, 8)                    # dead slot
    alloc.check_invariants()


def test_lowest_free_slot_and_page_reuse_order():
    alloc = kvc.PageAllocator(8, 3, 2, 4)
    a, pa = alloc.alloc_slot(4)
    b, pb = alloc.alloc_slot(4)
    assert (a, b) == (0, 1)
    assert pa == [1] and pb == [2]             # low page ids first
    alloc.free_slot(a)
    c, pc = alloc.alloc_slot(4)
    assert c == 0                              # lowest slot recycled
    alloc.check_invariants()


# ---------------------------------------------------------------------------
# device side: gather-via-page-table ≡ dense numpy mirror
# ---------------------------------------------------------------------------

_NL, _KVH, _DH, _PAGE, _MP, _SLOTS = 2, 2, 4, 4, 3, 3


def _mirror_trace(seed: int, n_ops: int = 14):
    """Run a random admit/append/free trace against both the paged device
    cache and a dense numpy mirror; yield (cache, mirror, cur_len, live)."""
    rng = np.random.default_rng(seed)
    smax = _MP * _PAGE
    n_pages = 1 + _SLOTS * _MP
    alloc = kvc.PageAllocator(n_pages, _SLOTS, _MP, _PAGE)
    cache = kvc.init_paged_cache(_NL, n_pages, _SLOTS, _MP, _KVH, _PAGE,
                                 _DH, jnp.float32)
    mirror_k = np.zeros((_NL, _SLOTS, smax, _KVH, _DH), np.float32)
    mirror_v = np.zeros_like(mirror_k)
    cur_len = np.zeros((_SLOTS,), np.int32)

    for _ in range(n_ops):
        op = rng.integers(0, 4)
        live = [int(s) for s in np.flatnonzero(alloc.live)]
        if op <= 1:                                       # admit (weighted)
            length = int(rng.integers(1, smax + 1))
            if not alloc.can_admit(length):
                continue
            slot, _ = alloc.alloc_slot(length)
            ks = rng.standard_normal((_NL, length, _KVH, _DH)) \
                .astype(np.float32)
            vs = rng.standard_normal((_NL, length, _KVH, _DH)) \
                .astype(np.float32)
            cache = kvc.write_prefill(cache, slot,
                                      jnp.asarray(alloc.page_table[slot]),
                                      jnp.asarray(ks), jnp.asarray(vs),
                                      length)
            mirror_k[:, slot, :length] = ks
            mirror_v[:, slot, :length] = vs
            cur_len[slot] = length
        elif op == 2 and live:                            # append one token
            ok = True
            for s in live:
                want = int(cur_len[s]) + 1
                if (alloc.pages_for(want) > _MP
                        or alloc.pages_for(want) - alloc.n_alloc[s]
                        > alloc.n_free):
                    ok = False
            if not ok:
                continue
            for s in live:
                alloc.ensure(s, int(cur_len[s]) + 1)
            cache["page_table"] = jnp.asarray(alloc.page_table)
            cache["length"] = jnp.asarray(cur_len)
            k_new = rng.standard_normal((_NL, _SLOTS, _KVH, _DH)) \
                .astype(np.float32)
            v_new = rng.standard_normal((_NL, _SLOTS, _KVH, _DH)) \
                .astype(np.float32)
            cache = kvc.append_token(cache, jnp.asarray(k_new),
                                     jnp.asarray(v_new))
            for s in live:
                mirror_k[:, s, cur_len[s]] = k_new[:, s]
                mirror_v[:, s, cur_len[s]] = v_new[:, s]
                cur_len[s] += 1
            cache["length"] = jnp.asarray(cur_len)
        elif op == 3 and live:                            # evict
            slot = int(rng.choice(live))
            alloc.free_slot(slot)
            cache["page_table"] = jnp.asarray(alloc.page_table)
            mirror_k[:, slot] = 0.0
            mirror_v[:, slot] = 0.0
            cur_len[slot] = 0
            cache["length"] = jnp.asarray(cur_len)
        alloc.check_invariants()
    return cache, (mirror_k, mirror_v), cur_len, \
        [int(s) for s in np.flatnonzero(alloc.live)]


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gather_matches_dense_mirror(seed):
    """gather-via-page-table == the dense mirror for every live slot up to
    its length, after a random alloc/append/free trace (page recycling must
    never surface a previous tenant's KV)."""
    cache, (mk, mv), cur_len, live = _mirror_trace(seed)
    kd, vd = kvc.gather_dense(cache)
    kd, vd = np.asarray(kd), np.asarray(vd)
    for s in live:
        n = int(cur_len[s])
        np.testing.assert_array_equal(kd[:, s, :n], mk[:, s, :n])
        np.testing.assert_array_equal(vd[:, s, :n], mv[:, s, :n])


@pytest.mark.parametrize("length", [1, _PAGE, _PAGE * _MP, _PAGE + 1])
def test_write_prefill_roundtrip(length):
    """Prefill scatter + gather is the identity up to ``length``, including
    exact page-boundary lengths and the full-capacity case."""
    rng = np.random.default_rng(length)
    n_pages = 1 + _MP
    alloc = kvc.PageAllocator(n_pages, 1, _MP, _PAGE)
    cache = kvc.init_paged_cache(_NL, n_pages, 1, _MP, _KVH, _PAGE, _DH,
                                 jnp.float32)
    slot, _ = alloc.alloc_slot(length)
    ks = rng.standard_normal((_NL, length, _KVH, _DH)).astype(np.float32)
    vs = rng.standard_normal((_NL, length, _KVH, _DH)).astype(np.float32)
    cache = kvc.write_prefill(cache, slot,
                              jnp.asarray(alloc.page_table[slot]),
                              jnp.asarray(ks), jnp.asarray(vs), length)
    kd, vd = kvc.gather_dense(cache)
    np.testing.assert_array_equal(np.asarray(kd)[:, 0, :length], ks)
    np.testing.assert_array_equal(np.asarray(vd)[:, 0, :length], vs)
    assert int(cache["length"][0]) == length


def test_append_layer_dead_slot_hits_trash_page():
    """Dead (all-NULL) slots scatter into page 0 and never corrupt a live
    slot's pages."""
    n_pages = 1 + 2 * _MP
    alloc = kvc.PageAllocator(n_pages, 2, _MP, _PAGE)
    cache = kvc.init_paged_cache(1, n_pages, 2, _MP, _KVH, _PAGE, _DH,
                                 jnp.float32)
    slot, _ = alloc.alloc_slot(3)
    ks = np.ones((1, 3, _KVH, _DH), np.float32)
    cache = kvc.write_prefill(cache, slot,
                              jnp.asarray(alloc.page_table[slot]),
                              jnp.asarray(ks), jnp.asarray(ks), 3)
    alloc.ensure(slot, 4)
    cache["page_table"] = jnp.asarray(alloc.page_table)
    k_new = np.full((1, 2, _KVH, _DH), 7.0, np.float32)
    cache = kvc.append_token(cache, jnp.asarray(k_new), jnp.asarray(k_new))
    kd, _ = kvc.gather_dense(cache)
    kd = np.asarray(kd)
    np.testing.assert_array_equal(kd[0, 0, :3],
                                  np.ones((3, _KVH, _DH), np.float32))
    np.testing.assert_array_equal(kd[0, 0, 3],
                                  np.full((_KVH, _DH), 7.0, np.float32))
    # the dead slot's write landed in the trash page, not in slot 0's pages
    trash = np.asarray(cache["k_pages"][0, kvc.NULL_PAGE])
    assert float(np.abs(trash).max()) == 7.0


def test_plan_pages_geometry():
    from repro.configs.base import ModelConfig
    from repro.core.policy import ONLINE_BLOCK
    cfg = ModelConfig(arch_id="tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=128)
    plan = kvc.plan_pages(cfg, ONLINE_BLOCK, n_slots=4, max_len=96,
                          dtype=jnp.float32, page_size=16)
    assert plan.page_size == 16
    assert plan.max_pages == -(-96 // 16)
    assert plan.n_pages >= 1 + plan.max_pages
    # paged HBM-per-slot beats the dense slot-based baseline at slack=1
    assert plan.hbm_bytes_per_slot(cfg) <= plan.dense_hbm_bytes_per_slot(cfg)
    # oversubscription shrinks the pool below n_slots * max_pages
    tight = kvc.plan_pages(cfg, ONLINE_BLOCK, n_slots=4, max_len=96,
                           dtype=jnp.float32, page_size=16, slack=0.5)
    assert tight.n_pages < plan.n_pages
    assert tight.hbm_bytes_per_slot(cfg) < plan.hbm_bytes_per_slot(cfg)
    # a page edge below the sublane is rounded up; above max_len clamped
    small = kvc.plan_pages(cfg, ONLINE_BLOCK, n_slots=2, max_len=64,
                           dtype=jnp.float32, page_size=1)
    assert small.page_size >= 1 and small.page_size * small.max_pages >= 64
    big = kvc.plan_pages(cfg, ONLINE_BLOCK, n_slots=2, max_len=64,
                         dtype=jnp.float32, page_size=4096)
    assert big.page_size <= 64
