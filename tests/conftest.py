"""Suite-wide fixtures."""
import os

import pytest


@pytest.fixture(scope="session", autouse=True)
def _isolated_tune_cache(tmp_path_factory):
    """Point the autotuner's persistent cache at a per-session temp file so
    tests neither read a developer's warm ~/.cache nor leave one behind."""
    path = str(tmp_path_factory.mktemp("tune") / "repro_tune.json")
    prev = os.environ.get("REPRO_TUNE_CACHE")
    os.environ["REPRO_TUNE_CACHE"] = path
    from repro.kernels import tune_cache
    tune_cache.reset()
    yield
    if prev is None:
        os.environ.pop("REPRO_TUNE_CACHE", None)
    else:
        os.environ["REPRO_TUNE_CACHE"] = prev
    tune_cache.reset()
