"""Unit + property tests for the framework-level ABFT core (repro.core)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import (ft_dot, ft_batched_dot, ft_verdict_dot, abft,
                        ONLINE_BLOCK, OFFLINE_DETECT, NONFUSED_BASELINE,
                        FT_OFF, InjectionSpec, ft_scope)


def _ab(m=64, k=32, n=48, dtype=jnp.float32, seed=0):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(ka, (m, k), dtype),
            jax.random.normal(kb, (k, n), dtype))


def test_clean_fused_exact():
    a, w = _ab()
    np.testing.assert_array_equal(np.asarray(ft_dot(a, w, ft=ONLINE_BLOCK)),
                                  np.asarray(a @ w))


def test_ft_off_is_plain_dot():
    a, w = _ab()
    np.testing.assert_array_equal(np.asarray(ft_dot(a, w, ft=FT_OFF)),
                                  np.asarray(a @ w))


@pytest.mark.parametrize("ft", [ONLINE_BLOCK, NONFUSED_BASELINE])
def test_injected_error_corrected(ft):
    a, w = _ab()
    spec = InjectionSpec(row=10, col=20, magnitude=100.0)
    out, v = ft_verdict_dot(a, w, ft, spec=spec)
    assert bool(v.detected) and int(v.row) == 10 and int(v.col) == 20
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ w),
                               rtol=1e-5, atol=1e-4)


def test_detect_only_leaves_error():
    a, w = _ab()
    spec = InjectionSpec(row=10, col=20, magnitude=100.0)
    out, v = ft_verdict_dot(a, w, OFFLINE_DETECT, spec=spec)
    assert bool(v.detected)
    assert abs(float(out[10, 20] - (a @ w)[10, 20]) - 100.0) < 1e-3


def test_gradients_flow_and_match_plain():
    a, w = _ab()
    g1 = jax.grad(lambda a, w: jnp.sum(ft_dot(a, w, ft=ONLINE_BLOCK) ** 2))(a, w)
    g2 = jax.grad(lambda a, w: jnp.sum((a @ w) ** 2))(a, w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)


def test_gradients_with_injection_are_clean():
    """SEUs injected into fwd AND bwd GEMMs must be corrected so gradients
    equal the fault-free ones — end-to-end training-step hardening."""
    a, w = _ab()
    key = jax.random.PRNGKey(3)
    ft = ONLINE_BLOCK.replace(inject_rate=1.0)
    g1 = jax.grad(lambda a, w: jnp.sum(ft_dot(a, w, ft=ft, key=key) ** 2))(a, w)
    g2 = jax.grad(lambda a, w: jnp.sum((a @ w) ** 2))(a, w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-2)


def test_batched_dot_clean_and_injected():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(k1, (4, 8, 16, 32))
    b = jax.random.normal(k2, (4, 8, 32, 16))
    out = ft_batched_dot(a, b, ft=ONLINE_BLOCK)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)
    # stochastic injection with rate 1 → every batch element hit; corrected
    out2 = ft_batched_dot(a, b, ft=ONLINE_BLOCK.replace(inject_rate=1.0),
                          key=jax.random.PRNGKey(9))
    np.testing.assert_allclose(np.asarray(out2), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-2)


def test_telemetry_scope_counts():
    a, w = _ab()
    with ft_scope() as s:
        ft_dot(a, w, ft=ONLINE_BLOCK.replace(inject_rate=1.0),
               key=jax.random.PRNGKey(7))
        ft_dot(a, w, ft=ONLINE_BLOCK)  # clean
        rep = s.report()
    assert int(rep.detected) == 1 and int(rep.corrected) == 1
    assert float(rep.max_residual) > 0


def test_under_jit_with_telemetry():
    a, w = _ab()

    @jax.jit
    def step(a, w, key):
        with ft_scope() as s:
            y = ft_dot(a, w, ft=ONLINE_BLOCK.replace(inject_rate=1.0), key=key)
            return y, s.report()

    y, rep = step(a, w, jax.random.PRNGKey(11))
    assert int(rep.detected) == 1
    np.testing.assert_allclose(np.asarray(y), np.asarray(a @ w),
                               rtol=1e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# hypothesis: checksum-algebra invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 40), k=st.integers(2, 40), n=st.integers(2, 40),
       seed=st.integers(0, 10_000))
def test_property_checksum_identity(m, k, n, seed):
    """(e^T A)·B == e^T(A·B) and A·(B e) == (A·B)e — Huang–Abraham Eq. 3."""
    a, b = _ab(m, k, n, seed=seed)
    c = a @ b
    ck = abft.product_checksums(a, b)
    np.testing.assert_allclose(np.asarray(ck.col),
                               np.asarray(jnp.sum(c, 0, keepdims=True)),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(ck.row),
                               np.asarray(jnp.sum(c, 1, keepdims=True)),
                               rtol=1e-4, atol=1e-3)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 32), k=st.integers(2, 32), n=st.integers(2, 32),
       row=st.integers(0, 31), col=st.integers(0, 31),
       mag=st.floats(1.0, 1e5), sign=st.sampled_from([-1.0, 1.0]),
       seed=st.integers(0, 10_000))
def test_property_single_error_always_located(m, k, n, row, col, mag, sign,
                                              seed):
    """∀ single SEU above threshold: detected, located exactly, corrected to
    within relative eps of the magnitude."""
    row, col = row % m, col % n
    a, b = _ab(m, k, n, seed=seed)
    spec = InjectionSpec(row=row, col=col, magnitude=sign * mag)
    out, v = ft_verdict_dot(a, b, ONLINE_BLOCK, spec=spec)
    assert bool(v.detected)
    assert int(v.row) == row and int(v.col) == col
    atol = max(1e-3, 4e-7 * mag)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=atol)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_property_no_false_positive(seed, dtype):
    a, b = _ab(48, 64, 32, dtype=dtype, seed=seed)
    _, v = ft_verdict_dot(a, b, ONLINE_BLOCK)
    assert not bool(v.detected)
