"""Pallas kernel validation: shape/dtype sweeps + property tests against the
pure-jnp oracle (ref.py). Kernels run in interpret mode on CPU."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref, autotune
from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK

P128 = autotune.KernelParams(128, 128, 128)


def _rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# Baseline GEMM kernel vs oracle — shape & dtype sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mnk", [
    (128, 128, 128),        # single block
    (256, 384, 512),        # multi-block all dims
    (100, 77, 300),         # ragged → padding path
    (128, 1024, 128),       # wide
    (512, 128, 256),        # tall
])
def test_gemm_matches_oracle(mnk, dtype):
    m, n, k = mnk
    a, b = _rand((m, k), dtype, 1), _rand((k, n), dtype, 2)
    got = ops.matmul(a, b, params=P128)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 50)


def test_gemm_autotuned_params_shape_classes():
    for m, n, k in [(64, 64, 64), (300, 300, 256), (2000, 256, 512),
                    (64, 2048, 256)]:
        p = autotune.build_params(m, n, k)
        a, b = _rand((m, k), jnp.float32, 3), _rand((k, n), jnp.float32, 4)
        got = ops.matmul(a, b, params=p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                   rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# FT-GEMM: clean runs have zero false positives and exact GEMM semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", ["block", "tile", "inner"])
@pytest.mark.parametrize("verify", ["step", "final"])
def test_ftgemm_clean(level, verify):
    a, b = _rand((256, 512), jnp.float32, 5), _rand((512, 384), jnp.float32, 6)
    ft = FTConfig(level=level, verify=verify)
    got, rep = ops.ft_matmul_report(a, b, ft=ft, params=P128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)
    assert float(rep[..., 0].sum()) == 0.0, "false positive on clean GEMM"


# ---------------------------------------------------------------------------
# FT-GEMM: a single injected SEU is detected, located, and corrected
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", ["block", "tile", "inner"])
def test_ftgemm_corrects_injected_error(level):
    a, b = _rand((256, 512), jnp.float32, 7), _rand((512, 384), jnp.float32, 8)
    spec = InjectionSpec(row=130, col=200, magnitude=77.0, k_step=1)
    ft = FTConfig(level=level, verify="step")
    got, rep = ops.ft_matmul_report(a, b, ft=ft, spec=spec, params=P128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)
    assert float(rep[..., 0].sum()) == 1.0
    blk = np.asarray(rep[130 // 128, 200 // 128])
    assert int(blk[2]) == 130 and int(blk[3]) == 200
    assert abs(blk[4] - 77.0) < 1e-2


def test_ftgemm_detect_only_flags_without_correcting():
    a, b = _rand((256, 512), jnp.float32, 9), _rand((512, 384), jnp.float32, 10)
    spec = InjectionSpec(row=10, col=20, magnitude=55.0, k_step=0)
    ft = FTConfig(level="block", action="detect")
    got, rep = ops.ft_matmul_report(a, b, ft=ft, spec=spec, params=P128)
    err = np.asarray(got) - np.asarray(a @ b)
    assert abs(err[10, 20] - 55.0) < 1e-3          # error left in place
    assert float(rep[..., 0].sum()) >= 1.0          # flagged (each interval)
    assert float(rep[..., 1].sum()) == 0.0          # never corrected


def test_ftgemm_matches_ft_oracle_with_injection():
    a, b = _rand((128, 256), jnp.float32, 11), _rand((256, 128), jnp.float32, 12)
    spec = InjectionSpec(row=5, col=9, magnitude=33.0, k_step=0)
    got, _ = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, spec=spec, params=P128)
    want = ref.ft_matmul_ref(a, b, ONLINE_BLOCK, spec=spec)
    assert bool(want.detected)
    # Kernel accumulates per k-block, the oracle in one pass — identical
    # semantics, different f32 summation order, so rounding-level tolerance.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want.out),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ftgemm_dtype_sweep_with_injection(dtype):
    a, b = _rand((256, 256), dtype, 13), _rand((256, 256), dtype, 14)
    spec = InjectionSpec(row=200, col=100, magnitude=64.0, k_step=1)
    got, rep = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, spec=spec, params=P128)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 50)
    assert float(rep[..., 0].sum()) == 1.0


# ---------------------------------------------------------------------------
# Property tests (hypothesis): ABFT invariants under arbitrary SEUs
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    row=st.integers(0, 127),
    col=st.integers(0, 127),
    k_step=st.integers(0, 1),
    mag=st.floats(min_value=1.0, max_value=1e6).map(lambda x: float(x)),
    sign=st.sampled_from([-1.0, 1.0]),
)
def test_ftgemm_property_any_seu_is_corrected(row, col, k_step, mag, sign):
    """∀ (location, step, magnitude > τ): online ABFT restores the fault-free
    result up to f32 rounding of the correction (relative eps of the injected
    magnitude) — the paper's core correctness claim.

    Very large magnitudes leave an eps-relative residue after the first
    correction; per-step verification then *iteratively refines* it in the
    next interval, so the detection count may legitimately exceed 1."""
    a, b = _rand((128, 256), jnp.float32, 15), _rand((256, 128), jnp.float32, 16)
    spec = InjectionSpec(row=row, col=col, magnitude=sign * mag, k_step=k_step)
    got, rep = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, spec=spec, params=P128)
    atol = max(1e-4, 4e-7 * mag)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=atol)
    assert float(rep[..., 0].sum()) >= 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ftgemm_property_no_false_positives(seed):
    """∀ clean inputs: no detection fires (threshold calibration)."""
    a = _rand((128, 384), jnp.float32, seed)
    b = _rand((384, 128), jnp.float32, seed + 1)
    _, rep = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, params=P128)
    assert float(rep[..., 0].sum()) == 0.0


def test_autotune_classes_and_vmem_budget():
    assert autotune.classify(64, 64, 64) == "small"
    assert autotune.classify(512, 512, 64) == "medium"
    assert autotune.classify(4096, 4096, 64) == "huge"
    assert autotune.classify(4096, 128, 64) == "tall_skinny"
    assert autotune.classify(128, 4096, 64) == "wide_flat"
    for cls, (bm, bn, bk) in autotune.TABLE.items():
        p = autotune.KernelParams(bm, bn, bk, cls)
        assert p.vmem_bytes(4) <= autotune.VMEM_BUDGET, cls
        assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
