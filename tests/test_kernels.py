"""Pallas kernel validation: shape/dtype sweeps + property tests against the
pure-jnp oracle (ref.py). Kernels run in interpret mode on CPU."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref, autotune
from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK

P128 = autotune.KernelParams(128, 128, 128)


def _rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# Baseline GEMM kernel vs oracle — shape & dtype sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mnk", [
    (128, 128, 128),        # single block
    (256, 384, 512),        # multi-block all dims
    (100, 77, 300),         # ragged → padding path
    (128, 1024, 128),       # wide
    (512, 128, 256),        # tall
])
def test_gemm_matches_oracle(mnk, dtype):
    m, n, k = mnk
    a, b = _rand((m, k), dtype, 1), _rand((k, n), dtype, 2)
    got = ops.matmul(a, b, params=P128)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 50)


def test_gemm_autotuned_params_shape_classes():
    for m, n, k in [(64, 64, 64), (300, 300, 256), (2000, 256, 512),
                    (64, 2048, 256)]:
        p = autotune.build_params(m, n, k)
        a, b = _rand((m, k), jnp.float32, 3), _rand((k, n), jnp.float32, 4)
        got = ops.matmul(a, b, params=p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                   rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# FT-GEMM: clean runs have zero false positives and exact GEMM semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", ["block", "tile", "inner"])
@pytest.mark.parametrize("verify", ["step", "final"])
def test_ftgemm_clean(level, verify):
    a, b = _rand((256, 512), jnp.float32, 5), _rand((512, 384), jnp.float32, 6)
    ft = FTConfig(level=level, verify=verify)
    got, rep = ops.ft_matmul_report(a, b, ft=ft, params=P128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)
    assert float(rep[..., 0].sum()) == 0.0, "false positive on clean GEMM"


# ---------------------------------------------------------------------------
# FT-GEMM: a single injected SEU is detected, located, and corrected
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", ["block", "tile", "inner"])
def test_ftgemm_corrects_injected_error(level):
    a, b = _rand((256, 512), jnp.float32, 7), _rand((512, 384), jnp.float32, 8)
    spec = InjectionSpec(row=130, col=200, magnitude=77.0, k_step=1)
    ft = FTConfig(level=level, verify="step")
    got, rep = ops.ft_matmul_report(a, b, ft=ft, spec=spec, params=P128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)
    assert float(rep[..., 0].sum()) == 1.0
    blk = np.asarray(rep[130 // 128, 200 // 128])
    assert int(blk[2]) == 130 and int(blk[3]) == 200
    assert abs(blk[4] - 77.0) < 1e-2


def test_ftgemm_detect_only_flags_without_correcting():
    a, b = _rand((256, 512), jnp.float32, 9), _rand((512, 384), jnp.float32, 10)
    spec = InjectionSpec(row=10, col=20, magnitude=55.0, k_step=0)
    ft = FTConfig(level="block", action="detect")
    got, rep = ops.ft_matmul_report(a, b, ft=ft, spec=spec, params=P128)
    err = np.asarray(got) - np.asarray(a @ b)
    assert abs(err[10, 20] - 55.0) < 1e-3          # error left in place
    assert float(rep[..., 0].sum()) >= 1.0          # flagged (each interval)
    assert float(rep[..., 1].sum()) == 0.0          # never corrected


def test_ftgemm_matches_ft_oracle_with_injection():
    a, b = _rand((128, 256), jnp.float32, 11), _rand((256, 128), jnp.float32, 12)
    spec = InjectionSpec(row=5, col=9, magnitude=33.0, k_step=0)
    got, _ = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, spec=spec, params=P128)
    want = ref.ft_matmul_ref(a, b, ONLINE_BLOCK, spec=spec)
    assert bool(want.detected)
    # Kernel accumulates per k-block, the oracle in one pass — identical
    # semantics, different f32 summation order, so rounding-level tolerance.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want.out),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ftgemm_dtype_sweep_with_injection(dtype):
    a, b = _rand((256, 256), dtype, 13), _rand((256, 256), dtype, 14)
    spec = InjectionSpec(row=200, col=100, magnitude=64.0, k_step=1)
    got, rep = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, spec=spec, params=P128)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 50)
    assert float(rep[..., 0].sum()) == 1.0


# ---------------------------------------------------------------------------
# Property tests (hypothesis): ABFT invariants under arbitrary SEUs
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    row=st.integers(0, 127),
    col=st.integers(0, 127),
    k_step=st.integers(0, 1),
    mag=st.floats(min_value=1.0, max_value=1e6).map(lambda x: float(x)),
    sign=st.sampled_from([-1.0, 1.0]),
)
def test_ftgemm_property_any_seu_is_corrected(row, col, k_step, mag, sign):
    """∀ (location, step, magnitude > τ): online ABFT restores the fault-free
    result up to f32 rounding of the correction (relative eps of the injected
    magnitude) — the paper's core correctness claim.

    Very large magnitudes leave an eps-relative residue after the first
    correction; per-step verification then *iteratively refines* it in the
    next interval, so the detection count may legitimately exceed 1."""
    a, b = _rand((128, 256), jnp.float32, 15), _rand((256, 128), jnp.float32, 16)
    spec = InjectionSpec(row=row, col=col, magnitude=sign * mag, k_step=k_step)
    got, rep = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, spec=spec, params=P128)
    atol = max(1e-4, 4e-7 * mag)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=atol)
    assert float(rep[..., 0].sum()) >= 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ftgemm_property_no_false_positives(seed):
    """∀ clean inputs: no detection fires (threshold calibration)."""
    a = _rand((128, 384), jnp.float32, seed)
    b = _rand((384, 128), jnp.float32, seed + 1)
    _, rep = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, params=P128)
    assert float(rep[..., 0].sum()) == 0.0


# ---------------------------------------------------------------------------
# Ragged-shape conformance: masked dispatch vs oracle (no full-padding path)
# ---------------------------------------------------------------------------

RAGGED_SHAPES = [
    (100, 77, 300),      # the flagship irregular shape
    (97, 101, 103),      # all prime
    (1, 129, 257),       # 1-row edge
    (130, 1, 259),       # 1-col edge
    (127, 255, 63),      # k < MXU
    (255, 383, 130),     # just under tile multiples
    (129, 257, 129),     # just over tile multiples
    (313, 241, 521),     # larger primes, multi-tile every dim
    (40, 24, 8),         # tiny, far below one MXU tile
]


@pytest.mark.parametrize("mnk", RAGGED_SHAPES)
def test_masked_gemm_ragged_conformance(mnk):
    m, n, k = mnk
    a, b = _rand((m, k), jnp.float32, m + n), _rand((k, n), jnp.float32, k)
    got = ops.matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=5e-4)


@pytest.mark.parametrize("mnk", RAGGED_SHAPES)
def test_masked_ft_gemm_ragged_conformance(mnk):
    m, n, k = mnk
    a, b = _rand((m, k), jnp.float32, m), _rand((k, n), jnp.float32, n)
    got, rep = ops.ft_matmul_report(a, b, ft=ONLINE_BLOCK, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-5, atol=5e-4)
    assert float(rep[..., 0].sum()) == 0.0, "false positive on ragged clean GEMM"


@pytest.mark.parametrize("level", ["block", "tile", "inner"])
def test_masked_ft_gemm_ragged_corrects_injection(level):
    """Checksums must survive masking: one SEU on a ragged shape is still
    detected, located, and corrected — per FT level."""
    m, n, k = 100, 77, 300
    a, b = _rand((m, k), jnp.float32, 21), _rand((k, n), jnp.float32, 22)
    spec = InjectionSpec(row=63, col=50, magnitude=44.0, k_step=0)
    ft = FTConfig(level=level, verify="step")
    got, rep = ops.ft_matmul_report(a, b, ft=ft, spec=spec, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-3)
    assert float(rep[..., 0].sum()) == 1.0
    blk = np.asarray(rep).reshape(-1, 8)[np.asarray(rep).reshape(-1, 8)[:, 0] > 0][0]
    assert int(blk[2]) == 63 and int(blk[3]) == 50
    assert abs(blk[4] - 44.0) < 1e-2


def test_masked_kernel_ignores_garbage_padding():
    """The masked kernels must be driven by the scalar-prefetched true dims,
    not by zero padding: fill the padded region with NaN and the result must
    still match the oracle (both non-FT and FT paths)."""
    from repro.kernels import gemm as gemm_mod, ftgemm, search
    m, n, k = 100, 77, 300
    a, b = _rand((m, k), jnp.float32, 31), _rand((k, n), jnp.float32, 32)
    info = ops.dispatch_info(m, n, k, in_bytes=4)
    q = info["masked_params"]
    me, ne, ke = info["executed_shape"]

    def nan_pad(x, rows, cols):
        return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])),
                       constant_values=np.nan)

    dims = jnp.array([m, n, k], jnp.int32)
    got = gemm_mod.gemm_masked(nan_pad(a, me, ke), nan_pad(b, ke, ne), dims,
                               params=q, interpret=True)[:m, :n]
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=5e-4)

    idx, mag = ftgemm.encode_injection(None)
    out, rep = ftgemm.ft_gemm(nan_pad(a, me, ke), nan_pad(b, ke, ne), idx, mag,
                              params=q, ft=ONLINE_BLOCK, interpret=True,
                              dims=dims)
    np.testing.assert_allclose(np.asarray(out[:m, :n]), np.asarray(a @ b),
                               rtol=1e-5, atol=5e-4)
    assert float(rep[..., 0].sum()) == 0.0


def test_ragged_dispatch_avoids_padding_flops():
    """Acceptance: (100, 77, 300) takes the masked path at ≤ 1.25× the
    hardware-aligned FLOP floor, where the seed's full-padding path paid
    ≥ 1.6× — no full-padding fallback."""
    m, n, k = 100, 77, 300
    info = ops.dispatch_info(m, n, k, in_bytes=4)
    assert info["path"] == "masked"
    assert info["padded_flop_ratio"] <= 1.25
    # the seed behaviour: static-table params + zero padding to class tiles
    seed_p = autotune.build_params(m, n, k)
    mp, np_, kp = autotune.padded_shape(m, n, k, seed_p)
    hw = info["hw_aligned_flops"] / 2.0
    assert (mp * np_ * kp) / hw >= 1.6


# ---------------------------------------------------------------------------
# Injection encoding → kernel → report round-trip (per FT level)
# ---------------------------------------------------------------------------

def test_encode_injection_none_is_noop():
    from repro.kernels import ftgemm
    idx, mag = ftgemm.encode_injection(None)
    assert idx.shape == (4,) and mag.shape == (1,)
    assert int(idx[0]) == 0 and float(mag[0]) == 0.0
    # and the kernel treats it as a clean run
    a, b = _rand((128, 128), jnp.float32, 41), _rand((128, 128), jnp.float32, 42)
    out, rep = ftgemm.ft_gemm(a, b, idx, mag, params=P128, ft=ONLINE_BLOCK,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)
    assert float(rep.sum(axis=(0, 1))[0]) == 0.0


@pytest.mark.parametrize("level", ["block", "tile", "inner"])
def test_injection_report_round_trip(level):
    """encode_injection → ft_gemm → report: [detected, corrected, row, col,
    magnitude] reproduce the spec exactly for every FT level."""
    from repro.kernels import ftgemm
    spec = InjectionSpec(row=140, col=210, magnitude=-66.0, k_step=1)
    idx, mag = ftgemm.encode_injection(spec)
    assert [int(v) for v in idx] == [1, 140, 210, 1]
    assert float(mag[0]) == -66.0

    a, b = _rand((256, 384), jnp.float32, 43), _rand((384, 256), jnp.float32, 44)
    ft = FTConfig(level=level, verify="step")
    out, rep = ftgemm.ft_gemm(a, b, idx, mag, params=P128, ft=ft,
                              interpret=True)
    blk = np.asarray(rep[140 // 128, 210 // 128])
    assert float(rep[..., 0].sum()) == 1.0          # detected exactly once
    assert float(rep[..., 1].sum()) == 1.0          # corrected exactly once
    assert int(blk[2]) == 140 and int(blk[3]) == 210  # located globally
    assert abs(blk[4] - (-66.0)) < 1e-2             # signed magnitude
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-3)


def test_autotune_classes_and_vmem_budget():
    assert autotune.classify(64, 64, 64) == "small"
    assert autotune.classify(512, 512, 64) == "medium"
    assert autotune.classify(4096, 4096, 64) == "huge"
    assert autotune.classify(4096, 128, 64) == "tall_skinny"
    assert autotune.classify(128, 4096, 64) == "wide_flat"
    for cls, (bm, bn, bk) in autotune.TABLE.items():
        p = autotune.KernelParams(bm, bn, bk, cls)
        assert p.vmem_bytes(4) <= autotune.VMEM_BUDGET, cls
        assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
