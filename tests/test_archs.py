"""Per-architecture smoke tests (assignment deliverable (f)).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family config, run one forward/train step on CPU, assert output shapes
and absence of NaNs; plus one prefill→decode serve step. The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation) — see
tests/test_dryrun_small.py and launch/dryrun.py.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.core.policy import ONLINE_BLOCK
from repro.models import model_zoo
from repro.models.blocks import Ctx

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.n_audio_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = registry.get_smoke(arch)
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    ctx = Ctx(ft=ONLINE_BLOCK, key=None, dtype=jnp.float32)
    b, s = 2, 64
    batch = _batch(cfg, b, s)
    kw = {}
    if cfg.family == "vlm":
        kw["extra_embeds"] = batch["patches"]
    if cfg.family == "encdec":
        kw["frames"] = batch["frames"]
    logits, aux = mod.forward(params, batch["tokens"], cfg, ctx,
                              remat=False, chunk=32, **kw)
    exp_s = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_s, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_one_train_step(arch):
    """One jitted train step: loss finite, grads finite, params update."""
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.optim import adamw
    from repro.train import train_loop

    cfg = registry.get_smoke(arch)
    mod = model_zoo.module_for(cfg)
    run = RunConfig(model=cfg, ft=ONLINE_BLOCK, dtype="float32",
                    attn_chunk=32)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    tc = train_loop.TrainConfig(total_steps=10, warmup_steps=1)
    params = mod.init(cfg, KEY, jnp.float32)
    opt_state = train_loop.init_opt_state(params, opt_cfg, tc)
    step_fn = jax.jit(train_loop.make_train_step(cfg, run, opt_cfg, tc))
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg).items()}
    new_params, _, metrics = step_fn(params, opt_state, batch,
                                     jnp.asarray(1), None)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved
    assert int(metrics["ft"].detected) == 0      # no SDCs without injection


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-780m", "zamba2-2.7b",
                                  "whisper-medium", "phi-3-vision-4.2b",
                                  "arctic-480b"])
def test_smoke_serve_prefill_decode(arch):
    cfg = registry.get_smoke(arch)
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    ctx = Ctx(ft=ONLINE_BLOCK, key=None, dtype=jnp.float32)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    cache = mod.init_cache(cfg, b, 64, jnp.float32)
    kw = {}
    if cfg.family == "vlm":
        kw["extra_embeds"] = batch["patches"]
    if cfg.family == "encdec":
        kw["frames"] = batch["frames"]
    logits, cache = mod.prefill(params, batch["tokens"], cache, cfg, ctx,
                                chunk=16, **kw)
    assert logits.shape == (b, cfg.padded_vocab())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = mod.decode_step(params, tok, cache, cfg, ctx)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache2["length"][0]) == int(cache["length"][0]) + 1


@pytest.mark.parametrize("arch", ["qwen2-7b", "codeqwen1.5-7b"])
def test_prefill_decode_consistency_with_forward(arch):
    """Greedy decode via (prefill + decode_step) must agree with teacher-
    forced forward logits — validates the KV-cache path numerically."""
    cfg = registry.get_smoke(arch)
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    ctx = Ctx(ft=ONLINE_BLOCK, key=None, dtype=jnp.float32)
    b, s = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s + 1), 0,
                                cfg.vocab_size)
    full_logits, _ = mod.forward(params, tokens, cfg, ctx, remat=False,
                                 chunk=16)
    cache = mod.init_cache(cfg, b, 32, jnp.float32)
    pre_logits, cache = mod.prefill(params, tokens[:, :s], cache, cfg, ctx,
                                    chunk=16)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full_logits[:, s - 1]),
                               rtol=2e-4, atol=2e-4)
    dec_logits, _ = mod.decode_step(params, tokens[:, s:s + 1], cache, cfg,
                                    ctx)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, s]),
                               rtol=2e-4, atol=2e-4)


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    c = registry.get_config("arctic-480b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (35, 7168, 56, 8, 4864, 32000)
    assert c.moe.n_experts == 128 and c.moe.top_k == 2
    assert c.moe.dense_d_ff == 4864          # dense residual
    c = registry.get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (94, 4096, 64, 4, 151936)
    assert c.moe.n_experts == 128 and c.moe.top_k == 8
    assert c.moe.expert_d_ff == 1536
    c = registry.get_config("qwen2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 3584, 28, 4, 18944, 152064)
    assert c.qkv_bias
    c = registry.get_config("codeqwen1.5-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 32, 13440, 92416)
    c = registry.get_config("phi4-mini-3.8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 24, 8, 8192, 200064)
    c = registry.get_config("minitron-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 24, 8, 9216, 256000)
    c = registry.get_config("mamba2-780m")
    assert (c.n_layers, c.d_model, c.vocab_size) == (48, 1536, 50280)
    assert c.ssm.state == 128 and c.attention_free
    c = registry.get_config("phi-3-vision-4.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 32, 32, 8192, 32064)
    c = registry.get_config("whisper-medium")
    assert (c.n_layers, c.enc_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab_size) == (24, 24, 1024, 16, 4096, 51865)
    c = registry.get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (54, 2560, 32, 32, 10240, 32000)
    assert c.ssm.state == 64 and c.subquadratic


def test_param_counts_match_scale():
    """Full configs land near their nameplate parameter counts (built
    abstractly — no allocation)."""
    expected = {
        "arctic-480b": (460e9, 520e9),
        "qwen3-moe-235b-a22b": (210e9, 260e9),
        "qwen2-7b": (7e9, 8.5e9),
        "codeqwen1.5-7b": (6.5e9, 8.5e9),
        "phi4-mini-3.8b": (3.5e9, 4.8e9),
        "minitron-4b": (3.8e9, 5.2e9),
        "mamba2-780m": (0.7e9, 0.95e9),
        "phi-3-vision-4.2b": (3.6e9, 4.6e9),
        "whisper-medium": (0.7e9, 0.95e9),
        "zamba2-2.7b": (2.4e9, 3.4e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = registry.get_config(arch)
        mod = model_zoo.module_for(cfg)
        struct = jax.eval_shape(
            lambda m=mod, c=cfg: m.init(c, jax.random.PRNGKey(0),
                                        jnp.bfloat16))
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(struct))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in " \
                              f"[{lo/1e9:.1f}, {hi/1e9:.1f}]"


def test_long_500k_applicability_matrix():
    """Assignment rule: long_500k runs only for sub-quadratic archs."""
    runnable = {a for a in registry.ARCH_IDS
                if model_zoo.supports_shape(registry.get_config(a),
                                            SHAPES["long_500k"])}
    assert runnable == {"mamba2-780m", "zamba2-2.7b"}
