"""Distributed behaviour tests.

Device count is locked at first JAX init, so multi-device tests run in
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
(mesh 2×4 over ("data","model")) — pjit-sharded train step, sharding-rule
consistency, elastic checkpoint resharding 8→4 devices.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_pjit_train_step_shards_and_matches_single_device():
    """One ABFT-protected train step under a 2×4 mesh: loss finite, params
    sharded per the rules, loss equal to the unsharded run."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import registry
        from repro.configs.base import RunConfig
        from repro.core.policy import ONLINE_BLOCK
        from repro.distributed import sharding as shd
        from repro.models import model_zoo
        from repro.optim import adamw
        from repro.train import train_loop

        cfg = registry.get_smoke("qwen2-7b")
        mod = model_zoo.module_for(cfg)
        run = RunConfig(model=cfg, ft=ONLINE_BLOCK, dtype="float32",
                        attn_chunk=32)
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        tc = train_loop.TrainConfig(total_steps=10, warmup_steps=1)
        params = mod.init(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = train_loop.init_opt_state(params, opt_cfg, tc)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                         cfg.vocab_size),
        }
        step = train_loop.make_train_step(cfg, run, opt_cfg, tc)
        # single-device reference
        _, _, m_ref = jax.jit(step)(params, opt, batch, jnp.asarray(0), None)
        ref = float(m_ref["loss"])

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.use_mesh(mesh):
            specs = shd.param_specs(params)
            p_sh = jax.device_put(params, jax.tree.map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec)))
            o_sh = jax.device_put(opt, None)
            b_sh = jax.device_put(batch, NamedSharding(
                mesh, jax.sharding.PartitionSpec("data")))
            new_p, _, metrics = jax.jit(step)(p_sh, o_sh, b_sh,
                                              jnp.asarray(0), None)
            loss = float(metrics["loss"])
        # params actually sharded over the mesh
        wq = new_p["layers"]["attn"]["wq"]
        n_shards = len(set(d for d in wq.sharding.device_set))
        print("LOSS", loss, "REF", ref, "SHARDS", n_shards)
        assert n_shards > 1
        assert abs(loss - ref) < 1e-3
    """)
    assert "LOSS" in out


def test_ft_adds_no_collectives():
    """DESIGN.md §2.2: ABFT checksums inherit operand shardings — enabling
    FT must not add collective ops to the partitioned HLO."""
    out = run_sub("""
        import jax, jax.numpy as jnp, re
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ft_dot
        from repro.core.policy import ONLINE_BLOCK, FT_OFF
        from repro.tools import roofline

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        x = jax.ShapeDtypeStruct((256, 512), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data")))
        w = jax.ShapeDtypeStruct((512, 384), jnp.float32,
                                 sharding=NamedSharding(mesh,
                                                        P(None, "model")))

        def collectives(ft):
            fn = lambda x, w: ft_dot(x, w, ft=ft)
            hlo = jax.jit(fn).lower(x, w).compile().as_text()
            _, per = roofline.collective_bytes(hlo)
            return per

        with mesh:
            off = collectives(FT_OFF)
            on = collectives(ONLINE_BLOCK)
        print("OFF", off, "ON", on)
        # FT may add only sub-kilobyte scalar reductions (threshold/verdict),
        # never operand-scale collectives
        extra = sum(on.values()) - sum(off.values())
        assert extra < 64 * 1024, (off, on)
    """)
    assert "ON" in out


def test_checkpoint_elastic_reshard_8_to_4():
    """Save under an 8-device mesh, restore under a 4-device mesh."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.ckpt import Checkpointer

        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        mesh8 = jax.make_mesh((8,), ("data",))
        sh8 = {"w": NamedSharding(mesh8, P("data"))}
        tree8 = jax.device_put(tree, sh8)
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(5, tree8)

        devs = np.array(jax.devices()[:4]).reshape(4)
        mesh4 = jax.sharding.Mesh(devs, ("data",))
        sh4 = {"w": NamedSharding(mesh4, P("data"))}
        restored, step, _ = ck.restore(tree, shardings=sh4)
        assert step == 5
        assert restored["w"].sharding.num_devices == 4
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("RESHARD OK")
    """)


def test_mesh_construction():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh
        import jax
        # 8 host devices can't build 256; just validate axis plumbing via a
        # tiny replica of the production mesh builder
        m = jax.make_mesh((2, 4), ("data", "model"))
        assert m.axis_names == ("data", "model")
        m2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert m2.axis_names == ("pod", "data", "model")
        print("MESH OK", m.devices.shape, m2.devices.shape)
    """)
    assert "MESH OK" in out


def test_sharding_rule_rank_mismatch_raises():
    """A PARAM_RULES entry whose rank disagrees with the array must raise —
    the pre-PR-5 behaviour silently replicated (de-sharded) the weight,
    turning a sharding-rule typo into an invisible perf regression."""
    from repro.distributed import sharding

    # sane paths still resolve
    spec = sharding.spec_for_path("layers/attn/wq", ndim=2)
    assert len(spec) == 2
    # stacked leading dim is filled with None, not an error
    spec3 = sharding.spec_for_path("layers/attn/wq", ndim=3, n_stacked=1)
    assert len(spec3) == 3 and spec3[0] is None
    # rank mismatch (rule names more dims than the array has) raises loudly
    with pytest.raises(ValueError, match="attn.*wq"):
        sharding.spec_for_path("layers/attn/wq", ndim=1)
    with pytest.raises(ValueError, match="de-shard"):
        sharding.spec_for_path("moe/w_gate", ndim=2)
