"""Flash-attention backward as a first-class ABFT kernel (PR 5).

Validates, in interpret mode:

  * the dedicated dQ / dK/dV kernels against jax.grad of the jnp oracle
    (GQA, ragged, causal cross-length);
  * bit-for-bit correction of SEUs injected into each of the four backward
    GEMMs (dP, dQ, dV, dK) on exactly-representable operands, and
    detect-only leaving the corruption visible;
  * saved (m, l) statistics and m-degenerate row zeroing (ragged Sq edge,
    causal empty kv span);
  * the in-kernel stochastic SEU hook (campaign key honored in BOTH
    directions; jaxpr contains the flash kernels, counters non-zero);
  * the blocks-level wiring: zero chunked-oracle recompute in the backward
    (3 Pallas launches, no open dot_generals), decode-geometry dispatch,
    telemetry recorded once per direction, no cotangent leaks.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK
from repro.kernels import flashft, ops, ref
from repro.tools import audit


def _qkvg(bh=2, sq=256, skv=256, dh=64, kvh=None, seed=0):
    kvh = kvh or bh
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (jax.random.normal(ks[0], (bh, sq, dh)),
            jax.random.normal(ks[1], (kvh, skv, dh)),
            jax.random.normal(ks[2], (kvh, skv, dh)),
            jax.random.normal(ks[3], (bh, sq, dh)))


def _oracle_grads(q, k, v, g, *, causal, n_rep):
    def f(q, k, v):
        kk = jnp.repeat(k, n_rep, axis=0)
        vv = jnp.repeat(v, n_rep, axis=0)
        return jnp.sum(ref.flash_attention_ref(q, kk, vv, causal=causal) * g)
    return jax.grad(f, argnums=(0, 1, 2))(q, k, v)


def _bwd(q, k, v, g, *, causal, n_rep=1, ft=ONLINE_BLOCK, **kw):
    out, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=causal,
                                n_rep=n_rep, save_stats=True)
    return ops.flash_ft_bwd(q, k, v, out, m, l, g, ft=ft, causal=causal,
                            n_rep=n_rep, **kw)


# ---------------------------------------------------------------------------
# 1. kernel backward vs autodiff oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (2, 2, 256, 256, 64, True),     # square causal
    (2, 1, 128, 256, 64, True),     # GQA n_rep=2, causal cross-length
    (1, 1, 100, 200, 80, False),    # ragged non-causal
    (2, 2, 57, 131, 64, True),      # ragged primes, causal
    (4, 1, 64, 192, 32, True),      # GQA n_rep=4
])
def test_flash_bwd_matches_autodiff_oracle(shape):
    bh, kvh, sq, skv, dh, causal = shape
    n_rep = bh // kvh
    q, k, v, g = _qkvg(bh, sq, skv, dh, kvh=kvh, seed=shape[2])
    dq, dk, dv, rep_dq, rep_dkv = _bwd(q, k, v, g, causal=causal,
                                       n_rep=n_rep)
    gq, gk, gv = _oracle_grads(q, k, v, g, causal=causal, n_rep=n_rep)
    for got, want in ((dq, gq), (dk, gk), (dv, gv)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    assert float(rep_dq[..., 0].sum() + rep_dkv[..., 0].sum()) == 0.0, \
        "false positive in a clean backward"


def test_flash_bwd_stats_match_reference():
    """The saved (m, l) are the scaled-score row max and exp-sum of the
    causally masked scores — checked against a dense recompute."""
    q, k, v, _ = _qkvg(2, 256, 256, 64)
    out, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True,
                                save_stats=True)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * (64 ** -0.5)
    mask = jnp.tril(jnp.ones((256, 256), bool))
    s = jnp.where(mask[None], s, -1e30)
    m_ref = jnp.max(s, -1)
    l_ref = jnp.sum(jnp.exp(s - m_ref[..., None]), -1)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# 2. SEU injection into each backward GEMM — bit-for-bit correction
# ---------------------------------------------------------------------------

def _exact_attention_case(bh=2, sq=256, skv=256, dh=64, seed=3):
    """Operands on which every flash quantity is exactly representable, so
    checksum residuals are exactly zero and correction is bit-for-bit:
    one-hot q/k at magnitude 40 (matched score = 40²·dh^-½ = 200 ⇒
    exp(0)=1 matched, exp(−200) underflows to exactly 0), dh=64 so the
    softmax scale is the exact power of two 2⁻³, and small-integer v/g.
    Each query row matches skv/dh kv positions ⇒ p ∈ {0, dh/skv} exact."""
    assert dh == 64 and skv % dh == 0
    rng = np.random.default_rng(seed)
    tq = rng.integers(0, dh, (bh, sq))
    q = 40.0 * np.eye(dh, dtype=np.float32)[tq]
    k = 40.0 * np.eye(dh, dtype=np.float32)[np.arange(skv) % dh
                                            ][None].repeat(bh, 0)
    v = rng.integers(-2, 3, (bh, skv, dh)).astype(np.float32)
    g = rng.integers(-2, 3, (bh, sq, dh)).astype(np.float32)
    return tuple(map(jnp.asarray, (q, k, v, g))) + (tq,)


#: (target, needs a live p at the injected coordinate)
BWD_TARGETS = ["dp_q", "dq", "dp_kv", "dv", "dk"]


@pytest.mark.parametrize("target", BWD_TARGETS)
def test_flash_bwd_seu_corrected_bit_for_bit(target):
    q, k, v, g, tq = _exact_attention_case()
    kw = dict(causal=False, bq=128, bkv=128)
    out, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, save_stats=True,
                                **kw)
    clean = ops.flash_ft_bwd(q, k, v, out, m, l, g, ft=ONLINE_BLOCK, **kw)
    # For the dP targets, pick a (row, col) where p != 0 so the corruption
    # would actually propagate into dS if left uncorrected.
    row = 5
    col = int(tq[1, 128 + row]) if target.startswith("dp") else 9
    spec = InjectionSpec(row=row, col=col, magnitude=777.0, k_step=1)
    inj = ops.flash_ft_bwd(q, k, v, out, m, l, g, ft=ONLINE_BLOCK,
                           inject=spec, inj_target=target, inj_bh=1,
                           inj_blk=1, **kw)
    det = float(inj[3][..., 0].sum() + inj[4][..., 0].sum())
    assert det == 1.0, (target, det)
    for got, want, name in zip(inj[:3], clean[:3], ("dq", "dk", "dv")):
        assert bool(jnp.all(got == want)), \
            f"{target}: corrected {name} not bit-identical to clean"


@pytest.mark.parametrize("target", ["dq", "dv", "dk"])
def test_flash_bwd_detect_only_leaves_error(target):
    q, k, v, g, tq = _exact_attention_case()
    kw = dict(causal=False, bq=128, bkv=128)
    out, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, save_stats=True,
                                **kw)
    clean = ops.flash_ft_bwd(q, k, v, out, m, l, g, ft=ONLINE_BLOCK, **kw)
    spec = InjectionSpec(row=5, col=9, magnitude=777.0, k_step=1)
    ftd = FTConfig(level="block", action="detect")
    inj = ops.flash_ft_bwd(q, k, v, out, m, l, g, ft=ftd, inject=spec,
                           inj_target=target, inj_bh=1, inj_blk=1, **kw)
    dev = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(inj[:3], clean[:3]))
    assert dev == 777.0, (target, dev)
    assert float(inj[3][..., 0].sum() + inj[4][..., 0].sum()) >= 1.0
    assert float(inj[3][..., 1].sum() + inj[4][..., 1].sum()) == 0.0


# ---------------------------------------------------------------------------
# 3. m-degenerate rows: ragged Sq edge + causal empty kv span
# ---------------------------------------------------------------------------

def test_degenerate_rows_ragged_sq_edge():
    """Kernel-level: dead query rows (past the true Sq) flush exact zeros
    and degenerate stats — not `exp(0)=1`-weighted garbage / 1e-30."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    sq_p, true_sq = 128, 100
    q = jax.random.normal(ks[0], (1, sq_p, 128))
    k = jax.random.normal(ks[1], (1, 128, 128))
    v = jax.random.normal(ks[2], (1, 128, 128))
    inj, mag = flashft.encode_injection(None)
    dims = jnp.array([true_sq, 128], jnp.int32)
    out, m, l, rep = flashft.flash_ft_attention(
        q, k, v, inj, mag, dims, bq=128, bkv=128, causal=False,
        ft=ONLINE_BLOCK, interpret=True, save_stats=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(out[0, true_sq:] == 0.0)), "dead rows must be zero"
    assert bool(jnp.all(m[0, true_sq:, 0] <= -1e29))
    assert bool(jnp.all(l[0, true_sq:, 0] == 0.0))
    # live rows match the oracle on the true lengths
    want = ref.flash_attention_ref(q[:, :true_sq], k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out[:, :true_sq]),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(rep[..., 0].sum()) == 0.0


def test_degenerate_rows_causal_empty_kv_span():
    """Causal with true Skv < true Sq (negative bottom-right offset): rows
    i < Sq − Skv have an EMPTY kv span. Pre-fix they accumulated uniform
    exp(−∞ − (−∞)) = 1 weights over the whole block; now they flush exact
    zeros, and live rows match the oracle."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    sq, skv = 128, 64
    q = jax.random.normal(ks[0], (1, sq, 128))
    k = jax.random.normal(ks[1], (1, 128, 128))
    v = jax.random.normal(ks[2], (1, 128, 128))
    inj, mag = flashft.encode_injection(None)
    dims = jnp.array([sq, skv], jnp.int32)
    out, m, l, rep = flashft.flash_ft_attention(
        q, k, v, inj, mag, dims, bq=128, bkv=128, causal=True,
        ft=ONLINE_BLOCK, interpret=True, save_stats=True)
    empty = sq - skv
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(out[0, :empty] == 0.0)), \
        "empty-span rows must flush zeros"
    assert bool(jnp.all(l[0, :empty, 0] == 0.0))
    # live rows: bottom-right-aligned causal on the true lengths
    want = ref.flash_attention_ref(q[:, :, :], k[:, :skv], v[:, :skv],
                                   causal=True)
    np.testing.assert_allclose(np.asarray(out[0, empty:]),
                               np.asarray(want[0, empty:]),
                               rtol=2e-4, atol=2e-4)


def test_degenerate_rows_backward_zero():
    """The backward maps degenerate stats (l=0) to p ≡ 0: dead ragged rows
    contribute nothing to dK/dV and get zero dQ — exactly, with no NaN from
    exp(−(−∞)) or 1/l."""
    q, k, v, g = _qkvg(1, 100, 128, 64, seed=7)
    n_rep = 1
    out, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=False,
                                save_stats=True)
    dq, dk, dv, _, _ = ops.flash_ft_bwd(q, k, v, out, m, l, g,
                                        ft=ONLINE_BLOCK, causal=False)
    gq, gk, gv = _oracle_grads(q, k, v, g, causal=False, n_rep=n_rep)
    for got, want in ((dq, gq), (dk, gk), (dv, gv)):
        assert bool(jnp.all(jnp.isfinite(got)))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# 4. stochastic in-kernel SEU hook (campaign path)
# ---------------------------------------------------------------------------

def test_stochastic_hook_fwd_detects_and_corrects():
    q, k, v, _ = _qkvg(2, 256, 256, 64, seed=11)
    clean, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True,
                            bq=128, bkv=128)
    ftc = ONLINE_BLOCK.replace(inject_rate=1.0)
    out, rep = ops.flash_ft(q, k, v, ft=ftc, causal=True, bq=128, bkv=128,
                            key=jax.random.PRNGKey(0))
    assert float(rep[..., 0].sum()) > 0.0, "campaign must detect SEUs"
    assert float(rep[..., 1].sum()) == float(rep[..., 0].sum())
    np.testing.assert_allclose(np.asarray(out), np.asarray(clean),
                               rtol=2e-3, atol=2e-3)


def test_stochastic_hook_bwd_detects_and_corrects():
    q, k, v, g = _qkvg(2, 256, 256, 64, seed=12)
    out, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True,
                                save_stats=True, bq=128, bkv=128)
    clean = ops.flash_ft_bwd(q, k, v, out, m, l, g, ft=ONLINE_BLOCK,
                             causal=True, bq=128, bkv=128)
    ftc = ONLINE_BLOCK.replace(inject_rate=1.0)
    inj = ops.flash_ft_bwd(q, k, v, out, m, l, g, ft=ftc, causal=True,
                           bq=128, bkv=128, key=jax.random.PRNGKey(1))
    assert float(inj[3][..., 0].sum()) > 0.0, "dq campaign must detect"
    assert float(inj[4][..., 0].sum()) > 0.0, "dkv campaign must detect"
    for got, want in zip(inj[:3], clean[:3]):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def test_stochastic_hook_is_deterministic_per_key():
    q, k, v, _ = _qkvg(1, 128, 128, 64, seed=13)
    ftc = ONLINE_BLOCK.replace(inject_rate=0.5)
    r1 = ops.flash_ft(q, k, v, ft=ftc, key=jax.random.PRNGKey(3))[1]
    r2 = ops.flash_ft(q, k, v, ft=ftc, key=jax.random.PRNGKey(3))[1]
    assert bool(jnp.all(r1 == r2))


# ---------------------------------------------------------------------------
# 5. blocks-level wiring: no oracle recompute, campaigns on-kernel,
#    decode geometry, telemetry
# ---------------------------------------------------------------------------

def _attn_args(seed, b=2, sq=32, h=4, kvh=2, dh=16, sk=None):
    rng = np.random.default_rng(seed)
    sk = sq if sk is None else sk
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kvh, dh)), jnp.float32)
    return q, k, v


def _pallas_ctx(**kw):
    from repro.models.blocks import Ctx
    return Ctx(ft=FTConfig(level="block", backend="pallas"),
               dtype=jnp.float32, attn_shard="none", **kw)


def test_attention_backward_zero_oracle_recompute():
    """The acceptance jaxpr assert: fwd+bwd of the flash-routed attention
    is exactly THREE dedicated Pallas launches (fwd, dq, dkv) with no
    dot_general outside them — the chunked-oracle recompute is gone."""
    from repro.models.blocks import chunked_attention
    q, k, v = _attn_args(seed=40)
    ctx = _pallas_ctx()

    def gradfn(q, k, v):
        f = lambda q, k, v: jnp.sum(jnp.sin(chunked_attention(
            q, k, v, causal=True, chunk=16, ctx=ctx)))
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    assert audit.count_primitives(gradfn, q, k, v) == 3
    names = audit.pallas_call_names(gradfn, q, k, v)
    assert sorted(names) == ["_flash_dkv_kernel", "_flash_dq_kernel",
                             "_flash_ft_kernel"], names
    assert audit.unprotected_dots(gradfn, q, k, v, min_flops=1.0) == []


def test_attention_bwd_kernel_matches_oracle_vjp():
    """Kernel backward vs the legacy oracle-recompute backward (the PR-4
    path, still available behind FLASH_BWD_USE_KERNEL) — same gradients."""
    from repro.models import blocks
    q, k, v = _attn_args(seed=41)
    ctx = _pallas_ctx()

    def grads(q, k, v):
        f = lambda q, k, v: jnp.sum(jnp.sin(blocks.chunked_attention(
            q, k, v, causal=True, chunk=16, ctx=ctx)))
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    g_kernel = grads(q, k, v)
    old = blocks.FLASH_BWD_USE_KERNEL
    blocks.FLASH_BWD_USE_KERNEL = False
    try:
        g_oracle = grads(q, k, v)
    finally:
        blocks.FLASH_BWD_USE_KERNEL = old
    for a, b in zip(g_kernel, g_oracle):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_stochastic_campaign_stays_on_kernel_path():
    """The silently-clean-campaign bugfix, end to end: a forced-flash
    `inject_rate > 0` campaign's jaxpr contains the flash kernels (NOT the
    chunked oracle), its detection counters are non-zero at runtime, and
    online correction keeps the results at the clean run's values."""
    from repro.models.blocks import chunked_attention
    q, k, v = _attn_args(seed=42)
    camp = dataclasses.replace(_pallas_ctx(attn_impl="flash"),
                               ft=FTConfig(level="block", backend="pallas",
                                           inject_rate=1.0),
                               key=jax.random.PRNGKey(9))
    clean_ctx = _pallas_ctx()

    def gradfn(ctx):
        def f(q, k, v):
            return jnp.sum(jnp.sin(chunked_attention(
                q, k, v, causal=True, chunk=16, ctx=ctx)))
        return lambda q, k, v: (f(q, k, v),
                                jax.grad(f, argnums=(0, 1, 2))(q, k, v))

    names = audit.pallas_call_names(gradfn(camp), q, k, v)
    assert "_flash_ft_kernel" in names and "_flash_dq_kernel" in names \
        and "_flash_dkv_kernel" in names, names
    # the campaign jaxpr must NOT fall back to the oracle's batched kernels
    assert not any("batched" in n for n in names), names

    with telemetry.ft_scope() as s:
        loss_c, grads_c = gradfn(camp)(q, k, v)
        rep = s.report()
    assert float(rep.detected) > 0.0, "campaign counters must be non-zero"
    loss_0, grads_0 = gradfn(clean_ctx)(q, k, v)
    np.testing.assert_allclose(float(loss_c), float(loss_0), rtol=1e-4)
    for a, b in zip(grads_c, grads_0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_auto_impl_keeps_campaigns_on_flash():
    """`attn_impl="auto"` no longer reroutes key-driven campaigns to the
    jnp oracle — the kernel hook serves them."""
    from repro.models.blocks import _use_flash
    camp = dataclasses.replace(_pallas_ctx(),
                               ft=FTConfig(level="block", backend="pallas",
                                           inject_rate=0.5),
                               key=jax.random.PRNGKey(0))
    assert _use_flash(camp, camp.ft, True, 32, 32, 0)


def test_forced_flash_raises_when_hook_unavailable(monkeypatch):
    """A campaign that cannot be honored must raise — never report a clean
    run as a fault campaign."""
    from repro.models.blocks import chunked_attention
    q, k, v = _attn_args(seed=43)
    camp = dataclasses.replace(_pallas_ctx(attn_impl="flash"),
                               ft=FTConfig(level="block", backend="pallas",
                                           inject_rate=1.0),
                               key=jax.random.PRNGKey(0))
    monkeypatch.setattr(flashft, "SUPPORTS_STOCHASTIC_INJECTION", False)
    with pytest.raises(ValueError, match="cannot honor"):
        chunked_attention(q, k, v, causal=True, chunk=16, ctx=camp)


def test_decode_geometry_flash_dispatch():
    """Sq=1 at q_offset = Sk−1 (the decode convention) dispatches to the
    flash kernel and matches both the chunked oracle and the dedicated
    decode_attention core."""
    from repro.models.blocks import Ctx, chunked_attention, decode_attention
    rng = np.random.default_rng(44)
    b, sk, h, kvh, dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, 1, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kvh, dh)), jnp.float32)
    ctx = _pallas_ctx()
    names = audit.pallas_call_names(
        lambda q, k, v: chunked_attention(q, k, v, causal=True, chunk=16,
                                          ctx=ctx, q_offset=sk - 1),
        q, k, v)
    assert "_flash_ft_kernel" in names, names
    out = chunked_attention(q, k, v, causal=True, chunk=16, ctx=ctx,
                            q_offset=sk - 1)
    oracle_ctx = _pallas_ctx(attn_impl="chunked")
    want = chunked_attention(q, k, v, causal=True, chunk=16, ctx=oracle_ctx,
                             q_offset=sk - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    dec = decode_attention(q, k, v, jnp.full((b,), sk), Ctx(
        ft=FTConfig(level="block", backend="pallas"), dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(dec),
                               rtol=1e-4, atol=1e-5)


def test_decode_step_end_to_end_pallas():
    """serve-path smoke: prefill + decode_step on the pallas backend agree
    with the xla backend (the decode geometry composes with the kernel
    dispatch end to end)."""
    from repro.configs.base import ModelConfig, RunConfig
    from repro.models import model_zoo
    from repro.train import serve

    cfg = ModelConfig(arch_id="dec-smoke", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=256)
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 256))
    outs = {}
    for backend in ("pallas", "xla"):
        run = RunConfig(model=cfg, ft=FTConfig(level="block",
                                               backend=backend),
                        dtype="float32", attn_chunk=16)
        outs[backend] = serve.generate(
            params, prompts, cfg, run, serve.ServeConfig(max_len=32),
            max_new_tokens=4)
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])


def test_flash_telemetry_once_per_direction():
    """One summary per attention call site, whether or not the call is
    differentiated: the forward's (det, maxres) is recorded exactly once
    at the caller's trace level; backward corrections are applied in-kernel
    but not double-counted (DESIGN.md convention)."""
    from repro.models.blocks import chunked_attention
    q, k, v = _attn_args(seed=45)
    ctx = _pallas_ctx()
    with telemetry.ft_scope() as s:
        chunked_attention(q, k, v, causal=True, chunk=16, ctx=ctx)
        n_fwd = len(s._items)
    with telemetry.ft_scope() as s2:
        jax.grad(lambda q: jnp.sum(chunked_attention(
            q, k, v, causal=True, chunk=16, ctx=ctx)))(q)
        n_grad = len(s2._items)
    assert n_fwd == 1, n_fwd
    assert n_grad == 1, n_grad


def test_flash_telemetry_no_cotangent_leak():
    """Using the scoped FT report next to the loss must not leak cotangents
    through the custom_vjp summary outputs (they are stop_gradient'ed at
    record time) — the gradient equals the report-free one."""
    from repro.models.blocks import chunked_attention
    q, k, v = _attn_args(seed=46)
    ctx = _pallas_ctx()

    def loss_with_report(q):
        out, rep = telemetry.scoped(lambda: chunked_attention(
            q, k, v, causal=True, chunk=16, ctx=ctx))
        return jnp.sum(jnp.sin(out)) + 0.0 * rep.max_residual

    def loss_plain(q):
        return jnp.sum(jnp.sin(chunked_attention(
            q, k, v, causal=True, chunk=16, ctx=ctx)))

    g1 = jax.grad(loss_with_report)(q)
    g2 = jax.grad(loss_plain)(q)
    assert bool(jnp.all(jnp.isfinite(g1)))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)


# ---------------------------------------------------------------------------
# 6. autotuner registration: flash variant keys
# ---------------------------------------------------------------------------

def test_flash_variant_keys_registered():
    from repro.kernels import autotune, tune_cache
    from repro.kernels.templates.spec import FlashKernelSpec

    keys = set()
    for direction, stats in (("fwd", False), ("fwd", True), ("dq", False),
                             ("dkv", False)):
        spec = FlashKernelSpec(ft_level="block", direction=direction,
                               dh=128, save_stats=stats)
        p = autotune.best_params(256, 256, 128, 4, ft_level="block",
                                 spec=spec, batch=8, use_cache=False)
        assert p.bm % 128 == 0 and p.bn % 128 == 0
        keys.add(tune_cache.cache_key("cpu", "medium", 4, "block",
                                      (256, 256, 128),
                                      variant=spec.variant_key(),
                                      batch="b_8"))
    assert len(keys) == 4, keys            # distinct cache keys per variant
    assert any("/v_flashbwd_dq" in k for k in keys)
    assert any("/v_flashbwd_dkv" in k for k in keys)
    # plain-GEMM keys are untouched by the flash variants
    plain = tune_cache.cache_key("cpu", "medium", 4, "block",
                                 (256, 256, 128))
    assert "/v_" not in plain


def test_flash_spec_validation():
    from repro.kernels.templates.spec import FlashKernelSpec
    with pytest.raises(ValueError, match="direction"):
        FlashKernelSpec(direction="sideways")
    with pytest.raises(ValueError, match="lane-padded"):
        FlashKernelSpec(dh=96)
    with pytest.raises(ValueError, match="forward-direction"):
        FlashKernelSpec(direction="dq", save_stats=True)
    with pytest.raises(ValueError, match="epilogue"):
        FlashKernelSpec(epilogue=("bias",))


# ---------------------------------------------------------------------------
# 7. injection-target validation + stochastic rate fidelity (review fixes)
# ---------------------------------------------------------------------------

def test_injection_target_outside_grid_raises():
    """A deterministic InjectionSpec addressing a grid cell the fitted
    (possibly autotuned) grid never executes must raise — not silently
    inject nothing and report a clean round-trip."""
    q, k, v, _ = _qkvg(1, 128, 128, 64, seed=50)
    spec = InjectionSpec(row=0, col=0, magnitude=10.0, k_step=0)
    with pytest.raises(ValueError, match="never land"):
        ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, spec=spec, inj_q_block=1,
                     bq=128, bkv=128)
    # autotuned tiles may merge blocks: the stale-block target still raises
    q2, k2, v2, g2 = _qkvg(1, 256, 256, 64, seed=51)
    with pytest.raises(ValueError, match="never executes"):
        ops.flash_ft(q2, k2, v2, ft=ONLINE_BLOCK,
                     spec=InjectionSpec(row=0, col=0, magnitude=10.0,
                                        k_step=0),
                     inj_q_block=1, bq=256, bkv=256)
    # causally-dead cell: (q-block 0, kv-step 1) under the triangular mask
    with pytest.raises(ValueError, match="never executes"):
        ops.flash_ft(q2, k2, v2, ft=ONLINE_BLOCK, causal=True,
                     spec=InjectionSpec(row=0, col=0, magnitude=10.0,
                                        k_step=1),
                     inj_q_block=0, bq=128, bkv=128)
    # same for the backward: (kv-block 1, q-step 0) is above the causal
    # bound in the dkv kernel's walk
    out, m, l, _ = ops.flash_ft(q2, k2, v2, ft=ONLINE_BLOCK, causal=True,
                                save_stats=True, bq=128, bkv=128)
    with pytest.raises(ValueError, match="never executes"):
        ops.flash_ft_bwd(q2, k2, v2, out, m, l, g2, ft=ONLINE_BLOCK,
                         causal=True, bq=128, bkv=128,
                         inject=InjectionSpec(row=0, col=0, magnitude=10.0,
                                              k_step=0),
                         inj_target="dv", inj_blk=1)


def test_stochastic_rate_fidelity_under_causal_skipping():
    """The stochastic step is drawn over each block's LIVE span, so
    inject_rate=1.0 lands exactly one SEU per (head, stationary block) even
    under causal skipping (drawing over the full grid extent would deflate
    the realized rate to ~62% on a triangular 4×4-step grid)."""
    q, k, v, g = _qkvg(1, 512, 512, 64, seed=52)
    ftc = ONLINE_BLOCK.replace(inject_rate=1.0)
    key = jax.random.PRNGKey(5)
    _, rep = ops.flash_ft(q, k, v, ft=ftc, causal=True, bq=128, bkv=128,
                          key=key)
    assert float(rep[..., 0].sum()) == 512 // 128   # one per (head, q-blk)
    out, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True,
                                save_stats=True, bq=128, bkv=128)
    _, _, _, rep_dq, rep_dkv = ops.flash_ft_bwd(
        q, k, v, out, m, l, g, ft=ftc, causal=True, bq=128, bkv=128,
        key=key)
    assert float(rep_dq[..., 0].sum()) == 512 // 128
    assert float(rep_dkv[..., 0].sum()) == 512 // 128
