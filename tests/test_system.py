"""End-to-end behaviour tests for the paper's system: training with live
SEU injection matches fault-free training bit-for-bit; checkpoint/restart
resumes deterministically; the serve path generates under injection."""
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.policy import ONLINE_BLOCK, OFFLINE_DETECT
from repro.models import model_zoo
from repro.train import train_loop, serve as serve_lib

CFG = ModelConfig(
    arch_id="sys-test", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
)
SHAPE = ShapeConfig("t", 64, 2, "train")
RUN = RunConfig(model=CFG, ft=ONLINE_BLOCK, dtype="float32", attn_chunk=32,
                learning_rate=1e-3)


def _train(tc, **kw):
    return train_loop.train(CFG, RUN, SHAPE, tc, log=lambda s: None, **kw)


def test_training_under_sdc_storm_matches_clean_run():
    """The paper's claim at system scale: with online ABFT, a machine
    suffering SEUs every step trains identically to a clean one."""
    tc_clean = train_loop.TrainConfig(total_steps=12, warmup_steps=2,
                                      log_every=1, ckpt_every=10_000)
    tc_storm = train_loop.TrainConfig(total_steps=12, warmup_steps=2,
                                      log_every=1, ckpt_every=10_000,
                                      inject_every=1)
    clean = _train(tc_clean)
    storm = _train(tc_storm)
    lc = [h["loss"] for h in clean["history"]]
    ls = [h["loss"] for h in storm["history"]]
    assert max(abs(a - b) for a, b in zip(lc, ls)) < 5e-3
    assert ls[-1] < ls[0]          # actually learning


def test_checkpoint_restart_is_deterministic(tmp_path):
    d = str(tmp_path / "ck")
    tc = train_loop.TrainConfig(total_steps=12, warmup_steps=1,
                                log_every=1, ckpt_every=6)
    # phase A: same 12-step schedule, drained ("crashed") after step 6
    _train(tc, ckpt_dir=d, stop_at=6)
    resumed = _train(tc, ckpt_dir=d, resume=True)
    straight = _train(tc)
    lr = [h["loss"] for h in resumed["history"]]
    lt = [h["loss"] for h in straight["history"]][-len(lr):]
    assert abs(lr[-1] - lt[-1]) < 1e-4


def test_detect_only_policy_does_not_correct():
    """Offline ABFT (§5.5) leaves the corruption; the step must still run
    (framework escalates via recompute in production)."""
    run = RUN
    import dataclasses
    run = dataclasses.replace(RUN, ft=OFFLINE_DETECT.replace(inject_rate=1.0))
    from repro.models.blocks import Ctx
    mod = model_zoo.module_for(CFG)
    params = mod.init(CFG, jax.random.PRNGKey(0), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 512)
    batch = {"tokens": tokens, "labels": tokens}
    ctx_c = Ctx(ft=OFFLINE_DETECT, key=None, dtype=jnp.float32)
    ctx_i = Ctx(ft=OFFLINE_DETECT.replace(inject_rate=1.0),
                key=jax.random.PRNGKey(2), dtype=jnp.float32)
    loss_c, m_c = mod.loss_fn(params, batch, CFG, ctx_c, remat=False,
                              chunk=32)
    loss_i, m_i = mod.loss_fn(params, batch, CFG, ctx_i, remat=False,
                              chunk=32)
    assert int(m_i["ft"].detected) > 0
    assert int(m_i["ft"].corrected) == 0
    # uncorrected SDCs visibly corrupt the loss (that's the point)
    assert abs(float(loss_i) - float(loss_c)) > 1e-4


def test_serve_generation_under_injection():
    """Batched generation with SEUs injected into decode GEMMs matches the
    clean generation token-for-token (greedy)."""
    import dataclasses
    mod = model_zoo.module_for(CFG)
    params = mod.init(CFG, jax.random.PRNGKey(0), jnp.float32)
    prompts = np.random.default_rng(0).integers(0, 512, (2, 16)
                                                ).astype(np.int32)
    sc = serve_lib.ServeConfig(max_len=48, temperature=0.0)
    clean = serve_lib.generate(params, prompts, CFG, RUN, sc,
                               max_new_tokens=8)
    run_inj = dataclasses.replace(
        RUN, ft=ONLINE_BLOCK.replace(inject_rate=0.0))
    hostile = serve_lib.generate(params, prompts, CFG, run_inj, sc,
                                 max_new_tokens=8)
    np.testing.assert_array_equal(clean, hostile)


def test_straggler_watchdog_flags_slow_steps():
    t = {"now": 0.0}

    def clock():
        return t["now"]

    wd = train_loop.Watchdog(window=20, k=3.0, clock=clock)
    for i in range(20):
        wd.start()
        t["now"] += 0.1
        assert not wd.stop(i)
    wd.start()
    t["now"] += 1.0            # 10× slower step
    assert wd.stop(20)
    assert wd.stragglers and wd.stragglers[0][0] == 20
