"""Per-site FT telemetry (PR 8): site registry + report pytree units,
attribution under jit+scan+remat+grad, the microbatch aggregation
regression, SDC-storm detector behaviour, and the metrics sink / serve
feed."""
import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.core import telemetry
from repro.core.policy import ONLINE_BLOCK
from repro.models import model_zoo
from repro.models.blocks import Ctx
from repro.tools import metrics as metrics_lib

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    return {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }


def _mk_report(site, det, cor=None, mr=1.0, rows=1, row=0):
    """Hand-build a single-site FTReport (host-side test fixture)."""
    sid = telemetry.site_id(site)
    w = telemetry.site_width()
    z = jnp.zeros((rows, w), jnp.float32)
    cor = det if cor is None else cor
    return telemetry.FTReport(
        detected=jnp.float32(det), corrected=jnp.float32(cor),
        max_residual=jnp.float32(mr),
        site_detected=z.at[row, sid].add(det),
        site_corrected=z.at[row, sid].add(cor),
        site_max_residual=z.at[row, sid].max(mr))


# ---------------------------------------------------------------------------
# registry + report pytree units
# ---------------------------------------------------------------------------


def test_registry_stable_ids_and_overflow():
    r = telemetry.SiteRegistry(4)
    assert r.site("a") == 1 and r.site("b") == 2
    assert r.site("a") == 1                      # stable on re-registration
    # past capacity-1 real slots everything aliases the overflow bucket
    assert r.site("c") == 3 and r.site("d") == 3
    assert r.label(3) == telemetry.OVERFLOW
    assert r.labels()[0] == telemetry.UNATTRIBUTED


def test_report_empty_width_is_static():
    rep = telemetry.FTReport.empty(rows=3)
    assert rep.site_detected.shape == (3, telemetry.site_width())
    assert rep.n_rows == 3


def test_merge_pads_rows_and_merge_at_places_row():
    one = _mk_report("unit_site_a", det=2.0, mr=5.0)
    big = telemetry.FTReport.empty(rows=4).merge_at(one, 2)
    assert float(big.detected) == 2.0
    sid = telemetry.site_id("unit_site_a")
    m = np.asarray(big.site_detected)
    assert m[2, sid] == 2.0 and m.sum() == 2.0   # landed at row 2 only
    # merge pads the shorter report at the bottom (absolute row semantics)
    merged = one.merge(big)
    assert merged.n_rows == 4
    assert np.asarray(merged.site_detected)[0, sid] == 2.0
    assert float(merged.max_residual) == 5.0


def test_expand_rows_refuses_shrink():
    with pytest.raises(ValueError):
        telemetry.FTReport.empty(rows=3).expand_rows(1)
    with pytest.raises(ValueError):
        telemetry.FTReport.empty(rows=2).merge_at(
            telemetry.FTReport.empty(rows=2), 0)


def test_reduce_microbatch_sums_counts_maxes_residuals():
    a = _mk_report("unit_site_a", det=1.0, mr=2.0)
    b = _mk_report("unit_site_a", det=3.0, mr=7.0)
    stacked = jax.tree.map(lambda x, y: jnp.stack([x, y]), a, b)
    red = telemetry.reduce_microbatch(stacked)
    assert float(red.detected) == 4.0            # SUM, not mean
    assert float(red.max_residual) == 7.0        # MAX
    sid = telemetry.site_id("unit_site_a")
    assert float(red.site_detected[0, sid]) == 4.0


def test_site_rows_decode_and_layer_mapping():
    rep = telemetry.FTReport.empty(rows=3).merge_at(
        _mk_report("unit_site_b", det=1.0, mr=0.5), 2)
    rows = telemetry.site_rows(rep)
    assert len(rows) == 1
    assert rows[0]["site"] == "unit_site_b"
    assert rows[0]["layer"] == 1                 # row 2 == layer index 1
    assert rows[0]["detected"] == 1.0


def test_scope_report_site_column_sums_to_total():
    with telemetry.ft_scope() as s:
        s.record(jnp.array(True), jnp.float32(3.0), True, site="unit_site_c")
        s.record(jnp.array(False), jnp.float32(0.0), True, site="unit_site_c")
        s.record_summary(jnp.float32(2.0), jnp.float32(9.0), False,
                         site="unit_site_d")
        rep = s.report()
    assert float(rep.detected) == 3.0 and float(rep.corrected) == 1.0
    assert float(rep.max_residual) == 9.0
    np.testing.assert_array_equal(
        np.asarray(rep.site_detected).sum(), np.asarray(rep.detected))
    cid = telemetry.site_id("unit_site_c")
    did = telemetry.site_id("unit_site_d")
    assert float(rep.site_detected[0, cid]) == 1.0
    assert float(rep.site_detected[0, did]) == 2.0


# ---------------------------------------------------------------------------
# end-to-end attribution: jit + scan + remat + grad
# ---------------------------------------------------------------------------


def _loss_ft(cfg, ctx, params, batch, remat):
    mod = model_zoo.module_for(cfg)

    def f(p):
        loss, mets = mod.loss_fn(p, batch, cfg, ctx, remat=remat, chunk=16)
        return loss, mets["ft"]

    (loss, ft), grads = jax.jit(
        lambda p: jax.value_and_grad(f, has_aux=True)(p))(params)
    return loss, ft, grads


@pytest.mark.parametrize("remat", [False, True])
def test_injection_attributed_to_named_site_only(remat):
    """inject_sites=("wq",) ⇒ detections land in the "wq" column (per
    layer row) and nowhere else — under jit, the layer scan, remat, and
    value_and_grad."""
    cfg = registry.get_smoke("qwen2-7b")
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    batch = _batch(cfg)
    ctx = Ctx(ft=ONLINE_BLOCK.replace(inject_rate=1.0),
              key=jax.random.PRNGKey(7), dtype=jnp.float32,
              inject_sites=("wq",))
    loss, ft, grads = _loss_ft(cfg, ctx, params, batch, remat)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree.leaves(grads))
    assert float(ft.detected) >= cfg.n_layers    # every layer's wq injected
    # clean sites may still log tiny residual magnitudes; *detections* must
    # land exclusively on the injected site
    rows = [r for r in telemetry.site_rows(ft) if r["detected"] > 0]
    assert rows and all(r["site"] == "wq" for r in rows)
    layers = {r["layer"] for r in rows}
    assert layers == set(range(cfg.n_layers))    # per-layer rows resolved
    np.testing.assert_array_equal(np.asarray(ft.site_detected).sum(),
                                  np.asarray(ft.detected))
    np.testing.assert_array_equal(np.asarray(ft.site_corrected).sum(),
                                  np.asarray(ft.corrected))


def test_totals_bit_identical_with_attribution_off():
    """The scalar triple is produced by the same reduction sequence in both
    modes — attribution only adds the site matrices next to it."""
    cfg = registry.get_smoke("qwen2-7b")
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    batch = _batch(cfg)
    ctx = Ctx(ft=ONLINE_BLOCK.replace(inject_rate=1.0),
              key=jax.random.PRNGKey(7), dtype=jnp.float32)
    _, ft_on, _ = _loss_ft(cfg, ctx, params, batch, True)
    with telemetry.site_attribution(False):
        assert telemetry.site_width() == 1
        _, ft_off, _ = _loss_ft(cfg, ctx, params, batch, True)
    assert ft_off.site_detected.shape[-1] == 1
    np.testing.assert_array_equal(np.asarray(ft_on.detected),
                                  np.asarray(ft_off.detected))
    np.testing.assert_array_equal(np.asarray(ft_on.corrected),
                                  np.asarray(ft_off.corrected))
    np.testing.assert_array_equal(np.asarray(ft_on.max_residual),
                                  np.asarray(ft_off.max_residual))


def test_moe_expert_site_attribution():
    """Injection filtered to one MoE expert GEMM shows up as exactly that
    site (the ISSUE's acceptance campaign in unit form)."""
    cfg = registry.get_smoke("qwen3-moe-235b-a22b")
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    batch = _batch(cfg, b=1, s=16)
    ctx = Ctx(ft=ONLINE_BLOCK.replace(inject_rate=1.0),
              key=jax.random.PRNGKey(11), dtype=jnp.float32,
              inject_sites=("moe_gate",))
    loss, ft, _ = _loss_ft(cfg, ctx, params, batch, False)
    assert np.isfinite(float(loss))
    rows = [r for r in telemetry.site_rows(ft) if r["detected"] > 0]
    assert rows and all(r["site"] == "moe_gate" for r in rows)
    assert float(ft.detected) > 0


# ---------------------------------------------------------------------------
# microbatch aggregation regression (satellite a)
# ---------------------------------------------------------------------------


def test_microbatch_ft_counters_sum_not_mean():
    """Gradient-accumulation steps must SUM the per-microbatch FT event
    counts (the old dtype-keyed branch silently averaged the f32
    counters)."""
    from repro.optim import adamw
    from repro.train import train_loop

    cfg = registry.get_smoke("qwen2-7b")
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    tc = train_loop.TrainConfig(total_steps=10, warmup_steps=1)
    inject_key = jax.random.PRNGKey(5)
    ft = ONLINE_BLOCK.replace(inject_rate=1.0)

    def detected(microbatch, b):
        run = RunConfig(model=cfg, ft=ft, dtype="float32", attn_chunk=16,
                        microbatch=microbatch)
        opt_state = train_loop.init_opt_state(params, opt_cfg, tc)
        step = jax.jit(train_loop.make_train_step(cfg, run, opt_cfg, tc))
        _, _, mets = step(params, opt_state, _batch(cfg, b=b),
                          jnp.asarray(1), inject_key)
        return mets["ft"]

    ft1 = detected(0, b=1)                       # one microbatch's worth
    ft2 = detected(2, b=2)                       # two microbatches, same key
    assert float(ft1.detected) > 0
    # same ctx key per microbatch ⇒ identical injection pattern ⇒ exactly 2×
    assert float(ft2.detected) == 2 * float(ft1.detected)
    assert float(ft2.corrected) == 2 * float(ft1.corrected)
    np.testing.assert_array_equal(np.asarray(ft2.site_detected).sum(),
                                  np.asarray(ft2.detected))
    # residual magnitudes take the max, not the sum (max semantics are
    # unit-tested in test_reduce_microbatch_sums_counts_maxes_residuals)
    assert np.isfinite(float(ft2.max_residual)) and float(ft2.max_residual) > 0


# ---------------------------------------------------------------------------
# storm detector (satellite d)
# ---------------------------------------------------------------------------


def test_storm_fires_on_single_site_spike():
    det = telemetry.StormDetector(window=8, spike_factor=8.0,
                                  min_detections=3.0)
    fired = []
    det.on_alert(fired.append)
    alerts = []
    for step in range(4):
        alerts += det.observe(step, {"bad": 1.0, "ok": 0.0})
    assert len(alerts) == 1 and alerts[0].site == "bad"
    assert fired == alerts == det.alerts
    a = alerts[0]
    assert a.detections >= 3.0 and a.rate >= a.threshold_rate


def test_storm_quiet_on_uniform_background():
    """Every site elevated equally = tau mis-calibration, not a failing
    part — must stay quiet."""
    det = telemetry.StormDetector(window=8)
    counts = {f"s{i}": 1.0 for i in range(4)}
    for step in range(32):
        assert det.observe(step, counts) == []
    assert det.alerts == []


def test_storm_rearms_once_per_window():
    det = telemetry.StormDetector(window=4, min_detections=2.0)
    n = 0
    for step in range(13):
        n += len(det.observe(step, {"bad": 1.0}))
    # fires at step 1 (sum=2), re-arms after 4 further observations:
    # steps 1, 5, 9 ... once per window, not every step.
    assert n == 3


def test_storm_ignores_subthreshold_counts():
    det = telemetry.StormDetector(window=8, min_detections=3.0)
    for step in range(8):
        assert det.observe(step, {"a": 0.25}) == []   # windowed sum < 3


# ---------------------------------------------------------------------------
# metrics sink (tentpole part 2) + report table (satellite b)
# ---------------------------------------------------------------------------


def test_sink_step_record_counters_deltas_gauges():
    mem = metrics_lib.MemoryEmitter()
    sink = metrics_lib.MetricsSink([mem], clock=lambda: 123.0)
    sink.count("tokens", 10)
    rec1 = sink.step_end(0, loss=2.5)
    sink.count("tokens", 5)
    rec2 = sink.step_end(1)
    assert rec1["counters"]["tokens"] == 10 and rec1["deltas"]["tokens"] == 10
    assert rec2["counters"]["tokens"] == 15 and rec2["deltas"]["tokens"] == 5
    assert rec1["gauges"]["loss"] == 2.5 and "loss" not in rec2["gauges"]
    assert rec1["t"] == 123.0
    assert mem.records == [rec1, rec2]


def test_sink_record_ft_sites_and_storm_alert():
    mem = metrics_lib.MemoryEmitter()
    sink = metrics_lib.MetricsSink([mem])
    seen = []
    sink.on_storm(seen.append)
    rep = _mk_report("unit_storm_site", det=5.0, mr=2.0)
    sink.record_ft(rep, step=0)
    rec = sink.step_end(0)
    assert rec["ft"]["detected"] == 5.0
    assert [r["site"] for r in rec["ft_sites"]] == ["unit_storm_site"]
    # 5 detections in one observation >= min_detections ⇒ storm
    assert [a["site"] for a in rec["alerts"]] == ["unit_storm_site"]
    assert seen and seen[0].site == "unit_storm_site"
    # alert state is per-step: next step record carries none
    assert "alerts" not in sink.step_end(1)


def test_histogram_log2_buckets():
    assert metrics_lib._log2_bucket(0.0) == "0"
    assert metrics_lib._log2_bucket(float("nan")) == "nonfinite"
    assert metrics_lib._log2_bucket(3.0) == "<=2^2"
    sink = metrics_lib.MetricsSink([])
    sink.histogram("h", 3.0)
    sink.histogram("h", 3.5)
    rec = sink.step_end(0)
    assert rec["hists"]["h"] == {"<=2^2": 2}


def test_jsonl_roundtrip_aggregate_and_report_table(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    sink = metrics_lib.MetricsSink([metrics_lib.JsonlEmitter(path)])
    for step in range(3):
        sink.record_ft(_mk_report("unit_tbl_site", det=2.0, mr=1.5),
                       step=step)
        sink.step_end(step, loss=1.0)
    sink.close()
    records = metrics_lib.read_jsonl(path)
    assert len(records) == 3
    json.loads(open(path).readline())            # really is JSONL
    agg = metrics_lib.aggregate_sites(records)
    assert agg["unit_tbl_site"]["detected"] == 6.0
    assert agg["unit_tbl_site"]["steps_seen"] == 3.0
    from repro.tools.report import ft_site_table
    table = ft_site_table(path)
    assert "unit_tbl_site" in table and "| site |" in table


# ---------------------------------------------------------------------------
# serve-path telemetry (satellite c)
# ---------------------------------------------------------------------------


def test_serve_generate_feeds_sink():
    from repro.train import serve

    cfg = registry.get_smoke("qwen2-7b")
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    run = RunConfig(model=cfg, ft=ONLINE_BLOCK, dtype="float32",
                    attn_chunk=16)
    sc = serve.ServeConfig(max_len=32, batch_slots=2)
    mem = metrics_lib.MemoryEmitter()
    sink = metrics_lib.MetricsSink([mem])
    prompts = np.asarray(
        jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size))
    out = serve.generate(params, prompts, cfg, run, sc, max_new_tokens=3,
                         sink=sink)
    assert out.shape == (2, 3)
    assert len(mem.records) == 4                 # 1 prefill + 3 decode
    assert mem.records[0]["gauges"]["phase"] == "prefill"
    assert mem.records[0]["counters"]["requests"] == 2
    assert mem.records[-1]["counters"]["decoded_tokens"] == 6
    for rec in mem.records:
        assert "ft" in rec                       # report emitted every step
        assert rec["ft"]["detected"] == 0.0      # no injection in serve


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-2.7b",
                                  "whisper-medium"])
def test_serve_telemetry_all_families(arch):
    """PR 9 closes the PR-8 follow-on: the ssm/hybrid/encdec serve scans
    carry the scoped report like the transformer's, so `with_report` serve
    telemetry works across the zoo — per-layer site attribution included.
    Runs on the pallas FT backend, whose kernels report the (nonzero)
    checksum residual of even a clean run, so row presence is assertable."""
    from repro.core.policy import FTConfig
    from repro.train import serve

    cfg = registry.get_smoke(arch)
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, KEY, jnp.float32)
    ft = FTConfig(action="correct", level="block", backend="pallas")
    run = RunConfig(model=cfg, ft=ft, dtype="float32", attn_chunk=16)
    sc = serve.ServeConfig(max_len=32, batch_slots=2)
    mem = metrics_lib.MemoryEmitter()
    sink = metrics_lib.MetricsSink([mem])
    prompts = np.asarray(jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size))
    extra = None
    if cfg.family == "encdec":
        extra = jax.random.normal(KEY, (2, cfg.n_audio_frames, cfg.d_model),
                                  jnp.float32)
    out = serve.generate(params, prompts, cfg, run, sc, max_new_tokens=2,
                         sink=sink, extra=extra)
    assert out.shape == (2, 2)
    assert len(mem.records) == 3                 # 1 prefill + 2 decode
    assert mem.records[0]["gauges"]["phase"] == "prefill"
    for rec in mem.records:
        assert "ft" in rec
        assert rec["ft"]["detected"] == 0.0      # clean run
        rows = rec.get("ft_sites") or []
        assert rows                              # residuals attributed
        assert any(r["layer"] is not None for r in rows)  # per-layer rows
    dec_sites = {r["site"] for r in mem.records[-1]["ft_sites"]}
    expect = {"ssm": "in_proj", "hybrid": "dec_qk", "encdec": "dec_qk"}
    assert expect[cfg.family] in dec_sites
