"""MoE dispatch: capacity-padded baseline vs ragged grouped kernels (PR 3).

Two regimes over the same routing decision:

  * padded (GShard capacity dispatch) — every expert is padded to the same
    capacity C and overflow tokens are dropped; the one-hot dispatch/combine
    einsums additionally cost ≈ 4·E·C·d FLOPs per token.
  * grouped (`core.ft_grouped_matmul`) — the expert FFN GEMMs run over a
    group-sorted token buffer with zero capacity padding; the only overhead
    over the ragged FLOP floor (Σ assignments · FFN FLOPs) is ≤ E·(bm-1)
    row-tile alignment rows.

Per arch this benchmark reports the capacity-padding **waste factor**
(padded expert FLOPs / ragged floor) and the grouped **executed ratio**
(grouped executed FLOPs / ragged floor), asserting the grouped path stays
≤ 1.25× the floor — the masked-GEMM criterion of PR 1 applied to the MoE
dispatch. It also runs an interpret-mode allclose gate: the grouped MoE
layer output must match a dense per-expert oracle (so CI catches a grouped
kernel/layout regression at PR time).

``REPRO_BENCH_SMOKE=1`` (set in CI) shrinks widths to smoke scale.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
from repro.models.blocks import Ctx
from repro.core.policy import ONLINE_BLOCK
from repro.kernels.grouped import layout as glayout
from .common import emit

#: Grouped executed FLOPs must stay within this factor of the ragged floor
#: (mirrors PR 1's masked-GEMM ≤1.25× criterion).
MAX_RATIO = 1.25


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def _grouped_executed_rows(counts: np.ndarray, bm: int) -> int:
    """Rows the grouped kernel executes: each expert's count rounded up to
    the bm row-tile alignment (the layout's only padding)."""
    return int(np.sum(-(-counts // bm) * bm))


def _dense_moe_oracle(p, x, mc: MoEConfig):
    """Per-expert dense reference of the grouped MoE layer (no capacity, no
    drops): y_t = Σ_k gate · FFN_{e_k}(x_t)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gate_vals, idx, _ = moe_lib._routing(xt, p["router"], mc)
    h_all = []
    for e in range(mc.n_experts):
        g = xt @ p["w_gate"][e]
        u = xt @ p["w_up"][e]
        h_all.append((jax.nn.silu(g) * u) @ p["w_down"][e])
    h_all = jnp.stack(h_all, axis=0)               # (E, T, d)
    y = jnp.zeros_like(xt)
    for k in range(mc.top_k):
        y = y + gate_vals[:, k:k + 1] * jnp.take_along_axis(
            h_all, idx[None, :, k:k + 1], axis=0)[0]
    return y.reshape(b, s, d)


def run() -> None:
    smoke = _smoke()
    rng = np.random.default_rng(0)
    for arch in ("arctic-480b", "qwen3-moe-235b-a22b"):
        cfg = registry.get_config(arch)
        mc = cfg.moe
        d = cfg.d_model
        tokens = 4096          # pure arithmetic — no need to smoke-shrink
        # FLOP accounting uses the real arch geometry; the allclose gate
        # below runs a reduced-width replica (same E/top_k routing law).
        useful_per_assign = 6 * d * mc.expert_d_ff      # 3 GEMMs, 2 flops/MAC
        # Simulated routing: Zipf-ish skew, the regime capacity padding is
        # worst at.
        probs = 1.0 / np.arange(1, mc.n_experts + 1)
        probs /= probs.sum()
        assigns = rng.choice(mc.n_experts, size=tokens * mc.top_k, p=probs)
        counts = np.bincount(assigns, minlength=mc.n_experts)

        # padded regime: per-group capacity × groups × experts
        g = moe_lib._group_geometry(1, tokens, mc)
        n_grp = tokens // g
        c = moe_lib.capacity(g, mc)
        padded_rows = mc.n_experts * n_grp * c
        dropped = int(np.maximum(counts - n_grp * c, 0).sum())
        floor_rows = int(counts.sum())
        # Gate the bm the dispatch paths actually use: the jnp backend's
        # sublane tile AND the pallas plan (plan_grouped caps bm so the
        # worst-case G·(bm-1) padding respects the criterion by design).
        from repro.kernels import grouped as kgrouped
        from repro.kernels.templates import BatchedKernelSpec
        bm_plan = kgrouped.plan_grouped(
            floor_rows, mc.expert_d_ff, d, jnp.float32,
            n_groups=mc.n_experts, ft_level="block",
            spec=BatchedKernelSpec(ft_level="block", grouped=True)).bm
        waste_padded = padded_rows / floor_rows
        ratios = {f"bm{bm}": _grouped_executed_rows(counts, bm) / floor_rows
                  for bm in sorted({8, bm_plan})}
        dispatch_flops = 4 * mc.n_experts * c * d       # per token, einsums
        for tag, ratio in ratios.items():
            assert ratio <= MAX_RATIO, (
                f"{arch}: grouped executed {ratio:.3f}x ({tag}) exceeds "
                f"the {MAX_RATIO}x ragged floor criterion")
        emit(f"moe_dispatch/{arch}/flops", float("nan"),
             f"E={mc.n_experts} top_k={mc.top_k} C={c} "
             f"padded_waste={waste_padded:.2f}x "
             + " ".join(f"grouped_ratio[{t}]={r:.3f}x"
                        for t, r in ratios.items())
             + f" dropped_tokens={dropped} "
             f"dispatch_overhead={100.0 * dispatch_flops / (mc.top_k * useful_per_assign):.1f}% "
             f"criterion<= {MAX_RATIO}x: pass")

        # ---- interpret-mode allclose gate (reduced-width replica) --------
        dd, ff = (16, 32) if smoke else (32, 64)
        mcr = dataclasses.replace(mc, expert_d_ff=ff, dispatch="grouped")
        p = moe_lib.init_moe(jax.random.PRNGKey(0), dd, mcr, 2, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, dd),
                              jnp.float32)
        ctx = Ctx(ft=ONLINE_BLOCK, key=None, dtype=jnp.float32)
        y, _ = moe_lib.apply_moe_grouped(p, x, mcr, ctx)
        want = _dense_moe_oracle(p, x, mcr)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # zero-capacity structural check: the grouped buffer executes the
        # assignments themselves, not E×C padded slots
        t = int(np.prod(x.shape[:2])) * mcr.top_k
        lay = glayout.make_layout(
            jnp.zeros((t,), jnp.int32), mcr.n_experts, 8)
        assert lay.t_buf <= t + mcr.n_experts * 8
        emit(f"moe_dispatch/{arch}/allclose", float("nan"),
             "grouped_vs_dense_oracle=1 ft=online_block")
