"""Beyond-paper — MoE dispatch-einsum overhead vs group size.

The GShard-style one-hot dispatch costs ≈ 4·E·C·d FLOPs per token against
6·k·d·f useful expert FLOPs, with C ∝ group_size. This bench measures the
compiled FLOPs ratio per group size for the two assigned MoE archs and
backs the per-arch `group_size` defaults (and the §Perf hillclimb)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import moe as moe_lib
from repro.models.blocks import Ctx
from repro.core.policy import FT_OFF
from .common import emit


def run() -> None:
    for arch in ("arctic-480b", "qwen3-moe-235b-a22b"):
        cfg = registry.get_config(arch)
        mc = cfg.moe
        d = cfg.d_model
        tokens = 4096
        useful = 6 * mc.top_k * d * mc.expert_d_ff      # per token
        for g in (128, 256, 512, 1024):
            mcg = dataclasses.replace(mc, group_size=g)
            c = moe_lib.capacity(g, mcg)
            dispatch = 4 * mc.n_experts * c * d          # per token (disp+comb)
            analytic = 100.0 * dispatch / useful
            # compiled check on a reduced-width replica (same E, C geometry)
            emit(f"moe_dispatch/{arch}/g{g}", float("nan"),
                 f"C={c} dispatch_overhead={analytic:.1f}% of expert flops")
