"""Fig. 12/13 (+17/18) analogue — fault-tolerance scheme comparison.

Paper result: fused threadblock-level online ABFT beats the non-fused
(Ding 2011) baseline by ~39% and costs ~8.9% over cuBLAS. Here the schemes
run through the XLA-fused jnp path (the structure XLA:TPU would fuse the
same way):

  off       — plain GEMM
  fused     — online ABFT, checksums fused into the computation (ours)
  detect    — offline/detect-only ABFT (§5.5; smaller register budget)
  nonfused  — Ding-style: materialized augmented matrices + barriered passes
  dmr       — dual modular redundancy (compute twice + compare; the
              general-purpose baseline ABFT is meant to beat)

Derived: measured overhead % vs `off`, plus the structural FLOPs overhead
from compiled cost_analysis. Paper-direction checks: fused < nonfused,
fused ≪ dmr.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ft_dot
from repro.core.policy import (FTConfig, ONLINE_BLOCK, OFFLINE_DETECT,
                               NONFUSED_BASELINE, FT_OFF)
from .common import emit, time_fn, flops_of


def _dmr(a, b):
    c1 = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    a2, b2 = jax.lax.optimization_barrier((a, b))
    # different precision config so XLA cannot CSE the redundant GEMM
    c2 = jax.lax.dot_general(a2, b2, (((1,), (0,)), ((), ())),
                             precision=jax.lax.Precision.HIGHEST,
                             preferred_element_type=jnp.float32)
    bad = jnp.abs(c1 - c2) > 1e-3
    return jnp.where(bad, 0.5 * (c1 + c2), c1).astype(a.dtype)


def run() -> None:
    m = n = k = 1024
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)

    schemes = {
        "off": FT_OFF,
        "fused_online": ONLINE_BLOCK,
        "detect_only": OFFLINE_DETECT,
        "nonfused_ding2011": NONFUSED_BASELINE,
    }
    fns = {name: jax.jit(lambda a, b, ft=ft: ft_dot(a, b, ft=ft))
           for name, ft in schemes.items()}
    fns["dmr"] = jax.jit(_dmr)

    base_us = None
    base_fl = None
    times = {}
    for name, fn in fns.items():
        out = fn(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-3)
        us = time_fn(fn, a, b)
        fl = flops_of(lambda a, b, f=fn: f(a, b), a, b)
        if name == "off":
            base_us, base_fl = us, fl
        times[name] = us
        over = 100.0 * (us / base_us - 1.0)
        fover = 100.0 * (fl / base_fl - 1.0)
        emit(f"ft_schemes/{name}", us,
             f"overhead={over:.1f}% flops_overhead={fover:.1f}%")

    fused_vs_nonfused = 100.0 * (times["nonfused_ding2011"]
                                 / times["fused_online"] - 1.0)
    emit("ft_schemes/fused_speedup_vs_nonfused", float("nan"),
         f"{fused_vs_nonfused:.1f}% (paper: ~39% on T4)")
    dmr_vs_fused = 100.0 * (times["dmr"] / times["fused_online"] - 1.0)
    emit("ft_schemes/fused_speedup_vs_dmr", float("nan"),
         f"{dmr_vs_fused:.1f}%")
