"""Fig. 10/11 (+14/15/19/20) analogue — template code generation across
irregular input shapes.

Paper result: shape-class parameter selection beats one fixed hard-coded
kernel by up to 230% on irregular shapes and cuBLAS by up to 41%. The TPU
analogue of the win is *padding efficiency*: a fixed 'huge' 512×512 tile on
a 160×160 problem wastes (512/160)² ≈ 10× FLOPs in padding; the generator
picks class-fit tiles. Derived column = padded/useful FLOPs per variant and
the resulting predicted speedup of autotuned over fixed (plus interpret-mode
correctness of the generated kernels).

Tuning
------
Runtime dispatch is the spec → template → autotune pipeline (see the
`repro.kernels` package docstring): a `templates.KernelSpec` — or, for
batched/grouped launches, a `templates.BatchedKernelSpec` — names the
kernel variant (FT level × epilogue chain × dtypes × batch structure),
`templates.emit` renders it into one Pallas body, and
`autotune.best_params` picks the tile parameters — memoizing the candidate
search (`kernels.search`) in a persistent JSON cache,
``$REPRO_TUNE_CACHE`` or ``~/.cache/repro_tune.json``.

Cache keys are ``device/class/caps/bytes/ft_level[/v_variant][/b_N|/g_N]``:
element width comes from the *actual operand dtype* (bf16 gets its own
entries and sublane floor); the variant component
(`KernelSpec.variant_key()`, e.g. ``v_bias+gelu``, ``v_batched``,
``v_grouped``) separates fused-epilogue chains and batched/grouped bodies,
whose aux-operand VMEM and roofline intensity legitimately move the
winner; and the batch component (``best_params(..., batch=B)`` →
``/b_<pow2>``, ``groups=G`` → ``/g_<pow2>``) captures the batch/group
count — a uniform batch multiplies every roofline term, while a group
count charges the per-group row-alignment padding (``G·(bm-1)`` worst
case), which steers grouped launches toward shallower row tiles. Plain
f32 2-D GEMM keeps the bare key, so PR-1/2 caches stay valid.

Worked grouped-MoE tuning example — an E-expert FFN over T routed rows::

    spec = templates.BatchedKernelSpec(ft_level="block", grouped=True)
    autotune.best_params(T, d_ff, d_model, 4, ft_level="block",
                         spec=spec, groups=E)   # key: …/v_grouped/g_<E↑2>

To regenerate a device's cache wholesale, run
``python -m benchmarks.run --only tune_campaign``: it re-searches a fixed
campaign (2-D, fused, batched, grouped — measured on TPU hardware,
roofline-modeled elsewhere) into ``$REPRO_TUNE_CAMPAIGN_OUT`` and diffs
the result against the checked-in ``benchmarks/tuned/<device>.json``.
This benchmark keeps the per-class view: each row reports the
static-table params next to the autotuned ones (``table=… tuned=…``) so
table/search divergence is visible per class, and the run re-reads the
cache file to verify the round trip. Fused-variant rows live in
`benchmarks.fused_epilogue`; to tune a *new* epilogue (after
`templates.epilogues.register`) just call ``best_params(m, n, k,
dtype.itemsize, ft_level=…, spec=your_spec)`` once: the miss searches
under the variant's working-set model and persists under its own key.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels import autotune, ops, tune_cache
from repro.core.policy import ONLINE_BLOCK
from .common import emit


def padded_flops_ratio(m, n, k, p: autotune.KernelParams) -> float:
    mp, np_, kp = autotune.padded_shape(m, n, k, p)
    return (mp * np_ * kp) / (m * n * k)


def run() -> None:
    fixed = autotune.KernelParams(*autotune.TABLE["huge"], "huge")
    shapes = [
        ("small_96", 96, 96, 256),
        ("medium_160", 160, 160, 256),
        ("large_448", 448, 448, 256),
        ("tall_4096x128", 4096, 128, 1024),
        ("wide_128x4096", 128, 4096, 1024),
        ("huge_2048", 2048, 2048, 512),
        ("ragged_100x77x300", 100, 77, 300),
    ]
    rng = np.random.default_rng(0)
    cache = tune_cache.default_cache()
    for name, m, n, k in shapes:
        dtype = jnp.float32
        in_bytes = jnp.dtype(dtype).itemsize      # width from the real dtype
        table = autotune.build_params(m, n, k, in_bytes)
        # ft_level="block" throughout: the kernel run below is ONLINE_BLOCK,
        # so the reported params/path must come from the same tuning key.
        tuned = autotune.best_params(m, n, k, in_bytes, cache=cache,
                                     ft_level="block")
        r_fixed = padded_flops_ratio(m, n, k, fixed)
        r_table = padded_flops_ratio(m, n, k, table)
        info = ops.dispatch_info(m, n, k, tuned, dtype=dtype,
                                 ft_level="block")
        r_disp = (info["executed_flops"] / 2.0) / (m * n * k)
        speedup = 100.0 * (r_fixed / r_disp - 1.0)
        # correctness of the dispatched kernel (FT on) on this shape
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        out = ops.ft_matmul(a, b, ft=ONLINE_BLOCK, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-3)
        emit(f"codegen/{name}", float("nan"),
             f"class={tuned.shape_class} path={info['path']} "
             f"table=({table.bm},{table.bn},{table.bk}) "
             f"tuned=({tuned.bm},{tuned.bn},{tuned.bk}) "
             f"padded_x_fixed={r_fixed:.2f} padded_x_table={r_table:.2f} "
             f"padded_x_dispatch={r_disp:.2f} "
             f"predicted_speedup={speedup:.0f}% correct=1")
    # Persistent-cache round trip: what this run tuned must reload
    # identically from disk in a fresh cache instance.
    reloaded = tune_cache.TuneCache(cache.path)
    assert reloaded.as_dict() == cache.as_dict(), "tuning cache round trip"
    emit("codegen/tune_cache", float("nan"),
         f"path={cache.path} entries={len(reloaded)} round_trip=1")
