"""Fig. 10/11 (+14/15/19/20) analogue — template code generation across
irregular input shapes.

Paper result: shape-class parameter selection beats one fixed hard-coded
kernel by up to 230% on irregular shapes and cuBLAS by up to 41%. The TPU
analogue of the win is *padding efficiency*: a fixed 'huge' 512×512 tile on
a 160×160 problem wastes (512/160)² ≈ 10× FLOPs in padding; the generator
picks class-fit tiles. Derived column = padded/useful FLOPs per variant and
the resulting predicted speedup of autotuned over fixed (plus interpret-mode
correctness of the generated kernels).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels import autotune, ops
from repro.core.policy import ONLINE_BLOCK
from .common import emit


def padded_flops_ratio(m, n, k, p: autotune.KernelParams) -> float:
    mp, np_, kp = autotune.padded_shape(m, n, k, p)
    return (mp * np_ * kp) / (m * n * k)


def run() -> None:
    fixed = autotune.KernelParams(*autotune.TABLE["huge"], "huge")
    shapes = [
        ("small_96", 96, 96, 256),
        ("medium_160", 160, 160, 256),
        ("large_448", 448, 448, 256),
        ("tall_4096x128", 4096, 128, 1024),
        ("wide_128x4096", 128, 4096, 1024),
        ("huge_2048", 2048, 2048, 512),
    ]
    rng = np.random.default_rng(0)
    for name, m, n, k in shapes:
        auto = autotune.build_params(m, n, k)
        r_fixed = padded_flops_ratio(m, n, k, fixed)
        r_auto = padded_flops_ratio(m, n, k, auto)
        speedup = 100.0 * (r_fixed / r_auto - 1.0)
        # correctness of the generated kernel (FT on) on this shape
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        out = ops.ft_matmul(a, b, ft=ONLINE_BLOCK, params=auto,
                            interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-3)
        emit(f"codegen/{name}", float("nan"),
             f"class={auto.shape_class} padded_x_fixed={r_fixed:.2f} "
             f"padded_x_auto={r_auto:.2f} predicted_speedup={speedup:.0f}% "
             f"correct=1")
