"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).

    PYTHONPATH=src python -m benchmarks.run [--only ft_schemes]

Prints ``name,us_per_call,derived`` CSV rows (us = NaN for structural-only
rows; see benchmarks/common.py for what transfers to TPU and what is a
CPU-trend measurement).
"""
import argparse
import sys
import traceback

SUITES = ("stepwise_gemm", "ft_schemes", "codegen_shapes",
          "fused_epilogue", "error_injection", "online_vs_offline",
          "moe_dispatch", "flash_attention", "backward_path",
          "tune_campaign", "telemetry_overhead", "serve_engine", "ft_plan")


def main() -> None:
    import contextlib

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=SUITES)
    ap.add_argument("--trace-dir", default=None,
                    help="capture a Perfetto-compatible profiler trace of "
                         "the selected suites into this directory (open "
                         "with ui.perfetto.dev)")
    args = ap.parse_args()
    if args.trace_dir:
        from repro.tools.trace import trace_dump
        tracer = trace_dump(args.trace_dir)
    else:
        tracer = contextlib.nullcontext()
    print("name,us_per_call,derived")
    failed = []
    with tracer:
        for name in SUITES:
            if args.only and name != args.only:
                continue
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            try:
                mod.run()
            except Exception:                     # noqa: BLE001
                traceback.print_exc()
                failed.append(name)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
