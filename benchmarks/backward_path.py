"""Backward-path protection coverage + the tgmm kernel's roofline win.

Two questions, both structural (they transfer to TPU):

  1. **What fraction of a train step's GEMM FLOPs runs under in-kernel
     ABFT?** Walk the optimizer-step jaxpr (`tools.audit`) for a dense and
     an MoE config on the pallas backend, before vs after the PR-4 backward
     work. "Before" re-enables the three legacy paths this PR closed —
     chunked-jnp attention (`attn_impl="chunked"`), the segment-summed jnp
     tgmm (`core.ft_gemm.TGMM_USE_KERNEL=False`), and the remat-style
     pre-activation recompute (`FUSED_BWD_SAVE_RESIDUAL=False`; its
     recompute GEMM *was* protected, but the attention/tgmm jnp GEMMs ran
     outside any kernel). After: every large GEMM — forward AND backward —
     sits inside a registry-emitted pallas_call; the open remainder is the
     MoE router einsum.
  2. **What does the output-stationary tgmm kernel buy over the
     segment-einsum baseline?** Roofline both: the baseline materializes a
     per-row-tile (tiles, K, N) f32 outer-product tensor in HBM and
     segment-sums it (then re-reads dw to verify); the kernel keeps the
     per-group accumulator and checksums in VMEM and writes dw once.
     `derived` reports the modeled speedup.

An interpret-mode allclose gate (tgmm kernel vs the segment einsum, with a
per-group injection round-trip) runs in smoke mode so a tgmm regression
fails CI, not the TPU campaign. ``REPRO_BENCH_SMOKE=1`` shrinks shapes.

Run via ``python -m benchmarks.run --only backward_path``.
"""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, RunConfig
from repro.core import ft_gemm
from repro.core.policy import FTConfig, InjectionSpec
from repro.kernels import autotune, search
from repro.kernels.templates import BatchedKernelSpec
from repro.tools import audit, roofline
from .common import emit


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


# ---------------------------------------------------------------------------
# 1. train-step FLOP fraction under in-kernel ABFT, before vs after
# ---------------------------------------------------------------------------

def _configs(smoke: bool):
    if smoke:
        dims = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    head_dim=16, d_ff=128, vocab_size=512)
        moe_dims = dict(n_experts=4, top_k=2, expert_d_ff=64)
        shape = (2, 32)
    else:
        dims = dict(n_layers=2, d_model=256, n_heads=8, n_kv_heads=4,
                    head_dim=32, d_ff=512, vocab_size=2048)
        moe_dims = dict(n_experts=8, top_k=2, expert_d_ff=256)
        shape = (2, 128)
    dense = ModelConfig(arch_id="bwd-dense", family="dense", **dims)
    moe = ModelConfig(arch_id="bwd-moe", family="moe",
                      moe=MoEConfig(**moe_dims), **dims)
    return [("dense", dense, shape), ("moe", moe, shape)]


def _step_fn(cfg: ModelConfig, shape, attn_impl: str):
    from repro.models import model_zoo
    from repro.optim import adamw
    from repro.train import train_loop
    run = RunConfig(model=cfg, ft=FTConfig(level="block", backend="pallas"),
                    dtype="float32", attn_chunk=32, attn_impl=attn_impl)
    tc = train_loop.TrainConfig(total_steps=10, warmup_steps=2)
    opt_cfg = adamw.AdamWConfig()
    step = train_loop.make_train_step(cfg, run, opt_cfg, tc)
    params = model_zoo.module_for(cfg).init(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    opt_state = train_loop.init_opt_state(params, opt_cfg, tc)
    b, s = shape
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "labels": jnp.zeros((b, s), jnp.int32)}
    return (lambda: audit.flop_accounting(
        lambda p, o, bt: step(p, o, bt, jnp.zeros((), jnp.int32)),
        params, opt_state, batch))


def _coverage_rows() -> None:
    for name, cfg, shape in _configs(_smoke()):
        after = _step_fn(cfg, shape, attn_impl="auto")()
        ft_gemm.TGMM_USE_KERNEL = False
        ft_gemm.FUSED_BWD_SAVE_RESIDUAL = False
        try:
            before = _step_fn(cfg, shape, attn_impl="chunked")()
        finally:
            ft_gemm.TGMM_USE_KERNEL = True
            ft_gemm.FUSED_BWD_SAVE_RESIDUAL = True
        emit(f"backward_path/{name}/abft_kernel_fraction", float("nan"),
             f"before={before['kernel_fraction']:.4f} "
             f"after={after['kernel_fraction']:.4f} "
             f"open_dots:{before['n_open_dots']}->{after['n_open_dots']} "
             f"kernel_dots:{before['n_kernel_dots']}->"
             f"{after['n_kernel_dots']}")
        # Structural gates: the PR's acceptance criterion, kept hot in CI.
        # Dense was already fully in-kernel on the pallas backend (its
        # legacy costs were the recompute GEMM + the score transient, both
        # *inside* kernels); the MoE step's tgmm einsum was genuinely open,
        # so its fraction must strictly improve.
        assert after["kernel_fraction"] >= before["kernel_fraction"], (name,)
        if name == "moe":
            assert after["kernel_fraction"] > before["kernel_fraction"]
        assert after["kernel_fraction"] > 0.99, after["kernel_fraction"]


# ---------------------------------------------------------------------------
# 2. tgmm kernel roofline vs the segment-einsum baseline
# ---------------------------------------------------------------------------

def segment_einsum_time_s(t_rows: int, k: int, n: int, groups: int,
                          bm: int, ft_level: str = "block") -> float:
    """Modeled segment-summed jnp tgmm: einsum materializes the per-tile
    (tiles, K, N) f32 outer products in HBM, segment_sum re-reads them and
    writes (G, K, N), and the checksum verification re-reads dw plus both
    buffers. Same useful FLOPs as the kernel — the delta is pure HBM
    traffic (this is the arithmetic-intensity argument for fusing backward
    ABFT: the outer-product GEMM is the *low*-intensity one)."""
    tiles = max(1, -(-t_rows // bm))
    f32 = 4
    flops = 2.0 * t_rows * k * n
    bytes_ = (t_rows * k + t_rows * n) * f32        # read X, G
    bytes_ += 2.0 * tiles * k * n * f32             # write + re-read tiles
    bytes_ += groups * k * n * f32                  # write dw
    if ft_level != "off":
        flops += 2.0 * (t_rows * k + t_rows * n) + 3.0 * groups * k * n
        bytes_ += (t_rows * k + t_rows * n + groups * k * n) * f32
    return roofline.kernel_time_s(flops, bytes_)


def _tgmm_roofline_rows() -> None:
    smoke = _smoke()
    shapes = ([(512, 256, 256, 8)] if smoke else
              [(4096, 1024, 4096, 8),       # MoE dw: (T·k, d_model, d_ff)
               (16384, 1024, 4096, 64),
               (16384, 1024, 4096, 8)])
    for t_rows, k, n, groups in shapes:
        spec = BatchedKernelSpec(ft_level="block", tgmm=True)
        p = autotune.best_params(t_rows, n, k, 4, ft_level="block",
                                 spec=spec, groups=groups, measure=False)
        t_kernel = search.predicted_time_s(t_rows, n, k, p, in_bytes=4,
                                           ft_level="block", spec=spec,
                                           groups=groups)
        t_einsum = segment_einsum_time_s(t_rows, k, n, groups, p.bm)
        emit(f"backward_path/tgmm_roofline/t{t_rows}_k{k}_n{n}_g{groups}",
             float("nan"),
             f"kernel_s={t_kernel:.3e} einsum_s={t_einsum:.3e} "
             f"speedup={t_einsum / t_kernel:.2f}x bm={p.bm}")
        # The whole point of output-stationary: never slower than paying
        # the materialized outer-product round-trip.
        assert t_kernel <= t_einsum, (t_kernel, t_einsum)


# ---------------------------------------------------------------------------
# 3. interpret-mode correctness gate (CI smoke)
# ---------------------------------------------------------------------------

def _allclose_gate() -> None:
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    t, g, k, n = 70, 3, 96, 40
    gids = jnp.asarray(np.sort(rng.integers(0, g, size=t)), jnp.int32)
    x = jnp.asarray(rng.integers(-3, 4, size=(t, k)), jnp.float32)
    gr = jnp.asarray(rng.integers(-3, 4, size=(t, n)), jnp.float32)
    want = np.zeros((g, k, n), np.float32)
    for e in range(g):
        m = np.asarray(gids) == e
        want[e] = np.asarray(x)[m].T @ np.asarray(gr)[m]
    spec = BatchedKernelSpec(ft_level="block", tgmm=True)
    inj = InjectionSpec(row=5, col=7, magnitude=444.0, k_step=0)
    dw, rep = ops.grouped_gemm_call(spec, x, gr, group_ids=gids, n_groups=g,
                                    ft=FTConfig(level="block"), inject=inj,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(dw), want)
    assert float(rep[..., 1].sum()) == 1.0
    emit("backward_path/tgmm_injection_gate", float("nan"),
         "corrected=1 exact=True")


def run() -> None:
    _coverage_rows()
    _tgmm_roofline_rows()
    _allclose_gate()


if __name__ == "__main__":
    run()
