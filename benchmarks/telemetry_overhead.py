"""Telemetry overhead + attribution campaign gates (PR 8).

Three claims, each emitted as a CSV row and asserted in place so a
regression fails CI rather than the analysis notebook:

  1. **Launch parity** — per-site attribution adds ZERO pallas launches to
     a pallas-backend train step. The site matrices ride the existing
     FTReport pytree; everything per-site is scatter-adds on scalars the
     step already computed. Counted from the optimizer-step jaxpr
     (`tools.audit.count_primitives`), attribution on vs off
     (`telemetry.site_attribution(False)` = the pre-PR-8 global triple).
  2. **Step overhead** — wall-clock A/B of the jitted xla-backend step in
     both modes (CPU trend signal; the structural launch-parity row is
     what transfers to TPU).
  3. **Attribution campaign** — the ISSUE's acceptance criterion: an
     injection campaign filtered to ONE named site (an MoE expert GEMM,
     ``moe_gate``) run through a real `MetricsSink` with a JSONL emitter.
     The JSONL must parse; detections must attribute to exactly that site
     (all other sites zero); the SDC-storm detector must fire on it.

``REPRO_BENCH_SMOKE=1`` shrinks shapes. Run via
``python -m benchmarks.run --only telemetry_overhead``.
"""
from __future__ import annotations

import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, RunConfig
from repro.core import telemetry
from repro.core.policy import FTConfig, ONLINE_BLOCK
from repro.models import model_zoo
from repro.models.blocks import Ctx
from repro.tools import audit
from repro.tools import metrics as metrics_lib
from .common import emit, time_fn


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def _cfgs(smoke: bool):
    if smoke:
        dims = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    head_dim=16, d_ff=128, vocab_size=512)
        moe_dims = dict(n_experts=4, top_k=2, expert_d_ff=64)
        shape = (2, 32)
    else:
        dims = dict(n_layers=2, d_model=256, n_heads=8, n_kv_heads=4,
                    head_dim=32, d_ff=512, vocab_size=2048)
        moe_dims = dict(n_experts=8, top_k=2, expert_d_ff=256)
        shape = (2, 128)
    dense = ModelConfig(arch_id="tel-dense", family="dense", **dims)
    moe = ModelConfig(arch_id="tel-moe", family="moe",
                      moe=MoEConfig(**moe_dims), **dims)
    return dense, moe, shape


def _batch(cfg, shape):
    b, s = shape
    k = jax.random.PRNGKey(0)
    return {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(k, (b, s), 0, cfg.vocab_size)}


def _train_step_parts(cfg, shape, backend: str):
    from repro.optim import adamw
    from repro.train import train_loop
    run = RunConfig(model=cfg, ft=FTConfig(level="block", backend=backend),
                    dtype="float32", attn_chunk=32)
    tc = train_loop.TrainConfig(total_steps=10, warmup_steps=2)
    opt_cfg = adamw.AdamWConfig()
    params = model_zoo.module_for(cfg).init(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    opt_state = train_loop.init_opt_state(params, opt_cfg, tc)
    args = (params, opt_state, _batch(cfg, shape), jnp.zeros((), jnp.int32),
            None)
    # fresh closure per call: jax's tracing cache is keyed on the callable,
    # so one reused fn would return the pre-toggle jaxpr
    mk = lambda: train_loop.make_train_step(cfg, run, opt_cfg, tc)
    return mk, args


# ---------------------------------------------------------------------------
# 1 + 2: launch parity and wall-clock A/B
# ---------------------------------------------------------------------------

def _launch_parity(cfg, shape) -> None:
    mk, args = _train_step_parts(cfg, shape, backend="pallas")
    n_on = audit.count_primitives(mk(), *args)
    with telemetry.site_attribution(False):
        n_off = audit.count_primitives(mk(), *args)
    extra = n_on - n_off
    emit("telemetry_overhead/pallas_launch_parity", float("nan"),
         f"attributed={n_on} baseline={n_off} extra_launches={extra}")
    assert extra == 0, (
        f"per-site attribution added {extra} pallas launches "
        f"({n_off} -> {n_on})")


def _step_overhead(cfg, shape) -> None:
    mk, args = _train_step_parts(cfg, shape, backend="xla")
    f_on = jax.jit(mk())
    jax.block_until_ready(f_on(*args)[2]["loss"])     # compile in-mode
    with telemetry.site_attribution(False):
        f_off = jax.jit(mk())
        jax.block_until_ready(f_off(*args)[2]["loss"])
    us_off = time_fn(f_off, *args)
    us_on = time_fn(f_on, *args)
    over = 100.0 * (us_on / us_off - 1.0)
    emit("telemetry_overhead/step_attributed", us_on,
         f"baseline_us={us_off:.1f} overhead={over:+.1f}%")


# ---------------------------------------------------------------------------
# 3: single-site injection campaign through the metrics sink
# ---------------------------------------------------------------------------

def _campaign(cfg, shape, target_site: str = "moe_gate",
              n_steps: int = 8) -> None:
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg, shape)
    ft = ONLINE_BLOCK.replace(inject_rate=1.0)

    @jax.jit
    def step(p, key):
        ctx = Ctx(ft=ft, key=key, dtype=jnp.float32,
                  inject_sites=(target_site,))
        loss, mets = mod.loss_fn(p, batch, cfg, ctx, remat=False, chunk=32)
        return loss, mets["ft"]

    path = os.path.join(tempfile.mkdtemp(prefix="telemetry_bench_"),
                        "metrics.jsonl")
    mem = metrics_lib.MemoryEmitter()
    sink = metrics_lib.MetricsSink(
        [metrics_lib.JsonlEmitter(path), mem],
        detector=telemetry.StormDetector(window=8, min_detections=3.0))
    storms = []
    sink.on_storm(storms.append)
    for i in range(n_steps):
        _, rep = step(params, jax.random.PRNGKey(100 + i))
        sink.record_ft(rep, step=i)
        sink.step_end(i)
    sink.close()

    records = metrics_lib.read_jsonl(path)           # must parse as JSONL
    assert len(records) == n_steps
    agg = metrics_lib.aggregate_sites(records)
    hit = {s: a["detected"] for s, a in agg.items() if a["detected"] > 0}
    assert target_site in hit, f"no detections at {target_site}: {agg}"
    assert set(hit) == {target_site}, (
        f"detections leaked to other sites: {hit}")
    assert any(a.site == target_site for a in storms), (
        f"storm detector stayed quiet through {n_steps} injected steps")
    assert mem.records == records or len(mem.records) == len(records)
    emit("telemetry_overhead/campaign_single_site", float("nan"),
         f"site={target_site} detections={hit[target_site]:.0f} "
         f"steps={n_steps} storms={len(storms)} jsonl_ok=1")


def run() -> None:
    dense, moe, shape = _cfgs(_smoke())
    _launch_parity(dense, shape)
    _step_overhead(dense, shape)
    _campaign(moe, shape)
