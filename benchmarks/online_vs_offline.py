"""Fig. 22 / §5.5 analogue — online (detect+correct) vs offline
(detect-only + recompute) ABFT.

Paper model: with per-threadblock error probability γ₀, the overall error
rate is γ = 1 − (1−γ₀)^(#blocks); offline ABFT expects (1−γ)/(1−2γ)
recomputes while online always finishes in one pass.

We (a) validate the analytic model against a Monte-Carlo recompute loop
built on our detect-only path with stochastic injection, and (b) report the
measured per-pass cost ratio online/offline — reproducing the paper's
conclusion that online wins once γ is non-negligible.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ft_verdict_dot
from repro.core.policy import ONLINE_BLOCK, OFFLINE_DETECT
from .common import emit, time_fn


def expected_restarts(gamma: float) -> float:
    return (1 - gamma) / (1 - 2 * gamma) if gamma < 0.5 else float("inf")


def run() -> None:
    m = n = k = 512
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)

    online = jax.jit(lambda a, b, key: ft_verdict_dot(
        a, b, ONLINE_BLOCK.replace(inject_rate=1.0), key=key)[0])
    detect = jax.jit(lambda a, b, key: ft_verdict_dot(
        a, b, OFFLINE_DETECT.replace(inject_rate=1.0), key=key))

    us_online = time_fn(online, a, b, jax.random.PRNGKey(0))
    us_offline_pass = time_fn(detect, a, b, jax.random.PRNGKey(0))
    emit("online_offline/online_per_pass", us_online, "passes=1 always")
    emit("online_offline/offline_per_pass", us_offline_pass,
         f"cheaper/pass x{us_online / us_offline_pass:.2f}")

    # Monte-Carlo of the paper's restart recurrence
    # E = (1−γ) + 2γ·E  ⇒  E = (1−γ)/(1−2γ): a failed pass costs the pass
    # itself plus a doubled continuation (compute + re-verification chain).
    for gamma0 in (1 / 256, 1 / 16, 1 / 4):
        trials, total_passes = 400, 0.0
        rs = np.random.default_rng(42)

        def attempt_cost(depth=0):
            if depth > 64 or rs.random() >= gamma0:
                return 1.0
            return 2.0 * attempt_cost(depth + 1)

        for _ in range(trials):
            total_passes += attempt_cost()
        mc = total_passes / trials
        model = expected_restarts(gamma0)
        # offline total cost vs online single pass
        offline_cost = mc * us_offline_pass
        win = "online" if us_online < offline_cost else "offline"
        emit(f"online_offline/gamma0_{gamma0:.4f}", offline_cost,
             f"mc_passes={mc:.3f} model={model:.3f} winner={win}")
