"""Shared benchmark utilities: wall-clock timing of jitted fns on CPU and
CSV emission (name,us_per_call,derived).

CPU wall time is a *trend* signal for the XLA-fused jnp ABFT paths (the
same fusion structure XLA:TPU sees); Pallas kernels are timed in interpret
mode only for completeness (correctness-path, not perf) and their §Perf
claims come from the roofline model instead. Every row's `derived` column
carries the structural metric (overhead %, flops ratio …) that transfers
to TPU.

`time_fn` is also the measurement primitive of the kernel autotuner: on
TPU hardware `repro.kernels.search.measure_candidates` times each
enumerated tile config through it (falling back to an internal copy when
the benchmarks package is not importable, e.g. library-only installs).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (µs) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def flops_of(fn, *args) -> float:
    from repro.tools import roofline
    compiled = jax.jit(fn).lower(*args).compile()
    return float(roofline.cost_dict(compiled).get("flops", 0.0))


def bytes_of(fn, *args) -> float:
    from repro.tools import roofline
    compiled = jax.jit(fn).lower(*args).compile()
    return float(roofline.cost_dict(compiled).get("bytes accessed", 0.0))
