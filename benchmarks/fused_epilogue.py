"""Fused-epilogue variants vs the unfused two-pass composition.

The fusion claim (FT-BLAS applied to the whole epilogue): running
bias/activation/residual inside the GEMM kernel removes a full HBM
round-trip over C — the unfused composition writes the (M, N) product out
and reads it back for the elementwise pass. Two signals per chain:

  * roofline — modeled kernel time of the fused variant
    (`search.predicted_time_s` with the spec's aux-operand bytes) vs the
    unfused pipeline (base GEMM + an elementwise pass that re-reads and
    re-writes C); `derived` reports the modeled speedup and the saved HBM
    bytes. This is the number that transfers to TPU.
  * interpret-mode wall time — a correctness-path trend only (Pallas
    interpret on CPU), plus an allclose check of fused vs unfused so a
    variant regression fails the suite at PR time.

Run directly or via `python -m benchmarks.run --only fused_epilogue`;
``REPRO_BENCH_SMOKE=1`` (set in CI) shrinks shapes/iterations to smoke
scale.
"""
from __future__ import annotations

import os

import numpy as np
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref, search
from repro.kernels.templates import KernelSpec
from repro.core.policy import FTConfig
from repro.tools import roofline
from .common import emit, time_fn

CHAINS = [
    ("bias",),
    ("bias", "gelu"),
    ("bias", "silu"),
    ("bias", "gelu", "residual"),
]


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def unfused_time_s(m, n, k, p, in_bytes, ft_level, spec: KernelSpec) -> float:
    """Modeled unfused pipeline: the base GEMM kernel followed by one
    elementwise pass that reads C (+ aux operands) and writes C again."""
    base = search.predicted_time_s(m, n, k, p, in_bytes=in_bytes,
                                   ft_level=ft_level)
    me, ne, _ = search.executed_dims(m, n, k, p)
    c_bytes = me * ne * in_bytes
    epi_bytes = 2 * c_bytes + spec.extra_hbm_bytes(me, ne, in_bytes)
    epi = roofline.kernel_time_s(spec.epilogue_flops(me, ne), epi_bytes)
    return base + epi


def run() -> None:
    smoke = _smoke()
    shapes = ([("smoke_256", 256, 256, 256)] if smoke else
              [("medium_512", 512, 512, 512),
               ("large_1024", 1024, 2048, 1024),
               ("ragged_300x200x520", 300, 200, 520)])
    iters = 1 if smoke else 3
    rng = np.random.default_rng(0)
    for name, m, n, k in shapes:
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        bias = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        res = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        for chain in CHAINS:
            for ft_level in ("off", "block"):
                spec = KernelSpec(ft_level=ft_level, epilogue=chain)
                ft = FTConfig(level=ft_level) if ft_level != "off" else None
                kw = dict(
                    bias=bias if "bias" in chain else None,
                    residual=res if "residual" in chain else None)
                p = autotune.best_params(m, n, k, 4, ft_level=ft_level,
                                        spec=spec, measure=False)
                t_fused = search.predicted_time_s(
                    m, n, k, p, in_bytes=4, ft_level=ft_level, spec=spec)
                t_unfused = unfused_time_s(m, n, k, p, 4, ft_level, spec)
                me, ne, _ = search.executed_dims(m, n, k, p)
                saved = 2 * me * ne * 4  # the avoided C round-trip

                # correctness + interpret-mode trend timing
                out, rep = ops.gemm_call(spec, a, b, ft=ft, interpret=True,
                                         **kw)
                want = ref.fused_matmul_ref(a, b, chain=chain, **kw)
                np.testing.assert_allclose(np.asarray(out),
                                           np.asarray(want),
                                           rtol=1e-4, atol=1e-3)
                if rep is not None:
                    assert float(np.asarray(rep)[..., 0].sum()) == 0.0
                us = time_fn(
                    lambda a, b: ops.gemm_call(spec, a, b, ft=ft,
                                               interpret=True, **kw)[0],
                    a, b, warmup=1, iters=iters)
                tag = "+".join(chain)
                emit(f"fused_epilogue/{name}/{tag}/ft_{ft_level}", us,
                     f"roofline_speedup={t_unfused / t_fused:.3f}x "
                     f"saved_hbm_mb={saved / 2**20:.2f} "
                     f"tile=({p.bm},{p.bn},{p.bk}) correct=1")
