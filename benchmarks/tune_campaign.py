"""Tune-cache regeneration campaign — the ROADMAP "measured cache" follow-on.

Regenerates the persistent autotuning cache for the CURRENT device kind over
a fixed campaign of shape classes (2-D, fused-epilogue, batched, grouped —
every key family `autotune.best_params` can produce), then diffs it against
the checked-in baseline under ``benchmarks/tuned/<device_kind>.json``:

  * on TPU hardware the campaign *measures* candidates
    (`search.measure_candidates` wall-clocks each tile config), so running
    this benchmark on a new device kind and checking in the emitted file is
    how a measured cache ships;
  * on CPU (CI) scoring falls back to the deterministic roofline model, so
    the diff doubles as a regression gate: an unintended cost-model change
    shows up as ``changed=…`` rows against the checked-in baseline.

Rows report added/removed/changed keys; ``REPRO_TUNE_CAMPAIGN_OUT`` (or a
temp file) receives the regenerated cache for checking in. Wired into
``python -m benchmarks.run`` as the ``tune_campaign`` suite.
"""
from __future__ import annotations

import os
import tempfile

import jax.numpy as jnp

from repro.kernels import autotune, tune_cache
from repro.kernels.templates import BatchedKernelSpec, KernelSpec
from .common import emit

#: (name, m, n, k, dtype, ft_level, spec, batch, groups) — one entry per
#: cache-key family the runtime dispatch can produce. Keep this list in sync
#: with the hot paths: codegen_shapes' classes, the fused model-block
#: chains, attention QK/PV batched shapes, grouped MoE FFN shapes.
CAMPAIGN = [
    ("small_f32", 96, 96, 256, jnp.float32, "off", None, 1, 0),
    ("small_ft", 96, 96, 256, jnp.float32, "block", None, 1, 0),
    ("medium_ft", 300, 300, 600, jnp.float32, "block", None, 1, 0),
    ("large_ft", 1024, 2048, 1024, jnp.float32, "block", None, 1, 0),
    ("tall_ft", 4096, 128, 1024, jnp.float32, "block", None, 1, 0),
    ("huge_bf16", 2048, 2048, 2048, jnp.bfloat16, "block", None, 1, 0),
    ("fused_mlp", 512, 2048, 512, jnp.float32, "block",
     KernelSpec(ft_level="block", epilogue=("bias", "silu")), 1, 0),
    # attention QK/PV cores: uniform batched, ragged seq dims
    ("attn_qk_b16", 512, 512, 128, jnp.float32, "block",
     BatchedKernelSpec(ft_level="block"), 16, 0),
    ("attn_pv_b16", 512, 128, 512, jnp.float32, "block",
     BatchedKernelSpec(ft_level="block"), 16, 0),
    # grouped MoE expert FFN: G experts over a routed token buffer
    ("moe_ffn_g64", 8192, 1536, 1024, jnp.float32, "block",
     BatchedKernelSpec(ft_level="block", grouped=True), 1, 64),
    ("moe_ffn_g64_off", 8192, 1536, 1024, jnp.float32, "off",
     BatchedKernelSpec(ft_level="off", grouped=True), 1, 64),
]


def baseline_path(dev: str) -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "tuned", f"{dev}.json")


def regenerate(path: str) -> tune_cache.TuneCache:
    """Run the campaign into a fresh cache at `path` (search per entry —
    measured on TPU, roofline-modeled elsewhere)."""
    if os.path.exists(path):
        os.unlink(path)
    cache = tune_cache.TuneCache(path)
    for (_, m, n, k, dtype, ft_level, spec, batch, groups) in CAMPAIGN:
        autotune.best_params(m, n, k, jnp.dtype(dtype).itemsize,
                             ft_level=ft_level, spec=spec, batch=batch,
                             groups=groups, cache=cache)
    return cache


def diff(baseline: dict, fresh: dict):
    added = sorted(set(fresh) - set(baseline))
    removed = sorted(set(baseline) - set(fresh))
    changed = sorted(k for k in set(fresh) & set(baseline)
                     if fresh[k] != baseline[k])
    return added, removed, changed


def run() -> None:
    dev = autotune.device_kind()
    out_path = os.environ.get(
        "REPRO_TUNE_CAMPAIGN_OUT",
        os.path.join(tempfile.gettempdir(), f"repro_tuned_{dev}.json"))
    fresh = regenerate(out_path)
    base_file = baseline_path(dev)
    base = tune_cache.TuneCache(base_file)
    if len(base) == 0:
        emit(f"tune_campaign/{dev}", float("nan"),
             f"entries={len(fresh)} baseline=absent "
             f"regenerated={out_path} (check in as {base_file})")
        return
    added, removed, changed = diff(base.as_dict(), fresh.as_dict())
    emit(f"tune_campaign/{dev}", float("nan"),
         f"entries={len(fresh)} baseline={len(base)} added={len(added)} "
         f"removed={len(removed)} changed={len(changed)} "
         f"regenerated={out_path}")
    for key in changed:
        emit(f"tune_campaign/changed/{key}", float("nan"),
             f"baseline={base.as_dict()[key]} fresh={fresh.as_dict()[key]}")
    # On CPU the scorer is the deterministic roofline model: any drift from
    # the checked-in baseline is an unintended cost-model change — fail the
    # suite so it surfaces at PR time. (On TPU, measured results may move
    # with hardware/runtime; the diff is informational there.)
    import jax
    if jax.default_backend() != "tpu":
        assert not changed and not removed, (
            "tune cache drift vs checked-in baseline", changed, removed)
