"""Beyond-paper — flash-FT attention vs unfused attention, HBM-traffic model.

The dry-run's memory term is dominated by materialized attention scores
(≈12 bytes per score element across the qk-write/softmax/p-read chain). The
flash-FT Pallas kernel keeps scores in VMEM (verified in interpret mode,
tests/test_flashft.py), so attention HBM bytes drop from O(S²) to O(S):

    unfused ≈ B·H·S²·12 / 2 (causal)      fused ≈ B·H·S·dh·3·2 + O bytes

Since PR 5 the BACKWARD is flash-shaped too: the forward saves the per-row
(m, l) softmax statistics and the dedicated dQ/dK/dV kernels consume them —
vs the PR-4 oracle recompute, which re-ran the whole forward through the
chunked-jnp path (one extra softmax pass + an O(chunk·S) score transient
per chunk). The backward section gates: 3 total Pallas launches for
fwd+grad, zero open dot_generals, an injected backward-GEMM SEU corrected
in interpret mode, and reports the modeled transient-memory drop.

Derived column reports the per-layer reduction at the assigned shapes and
the projected new memory-roofline term for the hillclimbed cells (§Perf).
Correctness of the kernel itself (incl. in-kernel ABFT + SEU correction) is
asserted here on a small shape.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import FTConfig, ONLINE_BLOCK, InjectionSpec
from repro.kernels import ops, ref
from repro.tools import audit
from .common import emit


def traffic(b, h, s, dh, causal=True):
    unfused = b * h * s * s * 12 * (0.5 if causal else 1.0)
    fused = b * h * s * dh * 2 * 4        # q,k,v in + o out, bf16
    return unfused, fused


def bwd_transient(b, h, s, dh, chunk=512):
    """Peak transient of the attention backward: the PR-4 oracle recompute
    materialized an O(chunk·S) score block per chunk (f32, ×3 for
    scores/p/ds live at once under vjp); the dedicated kernels keep the
    (bq, bkv) block in VMEM — the HBM-side residual is just the three O(S)
    statistic columns (m, l, di)."""
    oracle = b * h * chunk * s * 4 * 3
    kernel = b * h * s * 4 * 3
    return oracle, kernel


def run() -> None:
    # correctness + injected-SEU correction on a live shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 256, 64))
    k = jax.random.normal(ks[1], (2, 256, 64))
    v = jax.random.normal(ks[2], (2, 256, 64))
    spec = InjectionSpec(row=5, col=7, magnitude=500.0, k_step=0)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, spec=spec,
                            inj_bh=1, inj_q_block=1, bq=128, bkv=128)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    emit("flash_ft/correctness", float("nan"),
         f"seu_corrected=1 detections={int(rep[..., 0].sum())}")

    # ---- dedicated flash backward (PR 5) --------------------------------
    g = jax.random.normal(jax.random.PRNGKey(3), q.shape)
    out_s, m, l, _ = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, causal=True,
                                  save_stats=True, bq=128, bkv=128)
    clean = ops.flash_ft_bwd(q, k, v, out_s, m, l, g, ft=ONLINE_BLOCK,
                             causal=True, bq=128, bkv=128)
    inj = ops.flash_ft_bwd(q, k, v, out_s, m, l, g, ft=ONLINE_BLOCK,
                           causal=True, bq=128, bkv=128,
                           inject=InjectionSpec(row=3, col=5,
                                                magnitude=400.0, k_step=1),
                           inj_target="dv", inj_bh=1, inj_blk=1)
    dev = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(inj[:3], clean[:3]))
    assert dev < 2e-3, dev
    det = int(inj[3][..., 0].sum() + inj[4][..., 0].sum())
    assert det >= 1, det

    # structural gate: fwd+grad = 3 dedicated launches, no open GEMMs
    from repro.models.blocks import Ctx, chunked_attention
    rngq = jax.random.PRNGKey(4)
    q4 = jax.random.normal(rngq, (2, 32, 2, 16))
    ctx = Ctx(ft=FTConfig(level="block", backend="pallas"),
              dtype=jnp.float32, attn_shard="none")

    def gradfn(q4):
        f = lambda x: jnp.sum(chunked_attention(x, q4, q4, causal=True,
                                                chunk=16, ctx=ctx))
        return jax.grad(f)(q4)

    launches = audit.count_primitives(gradfn, q4)
    opens = audit.unprotected_dots(gradfn, q4, min_flops=1.0)
    assert launches == 3 and opens == [], (launches, opens)
    emit("flash_ft/backward", float("nan"),
         f"bwd_seu_corrected=1 detections={det} launches_fwd_bwd=3 "
         f"open_dots=0")

    # backward transient-memory model at the assigned shapes
    for name, b, h, s, dh in [
        ("qwen2_train_4k", 256, 28, 4096, 128),
        ("arctic_train_4k", 256, 56, 4096, 128),
    ]:
        orc, kern = bwd_transient(b, h, s, dh)
        emit(f"flash_ft/bwd_transient_{name}", float("nan"),
             f"oracle={orc/2**30:.1f}GiB kernel={kern/2**30:.3f}GiB "
             f"reduction_x={orc/max(kern,1):.0f}")

    # HBM traffic model at the assigned shapes (per layer, global)
    for name, b, h, s, dh in [
        ("qwen2_train_4k", 256, 28, 4096, 128),
        ("qwen2_prefill_32k", 32, 28, 32768, 128),
        ("arctic_train_4k", 256, 56, 4096, 128),
    ]:
        unf, fus = traffic(b, h, s, dh)
        emit(f"flash_ft/{name}", float("nan"),
             f"unfused={unf/2**30:.1f}GiB fused={fus/2**30:.2f}GiB "
             f"reduction_x={unf/fus:.0f}")
