"""Beyond-paper — flash-FT attention vs unfused attention, HBM-traffic model.

The dry-run's memory term is dominated by materialized attention scores
(≈12 bytes per score element across the qk-write/softmax/p-read chain). The
flash-FT Pallas kernel keeps scores in VMEM (verified in interpret mode,
tests/test_flashft.py), so attention HBM bytes drop from O(S²) to O(S):

    unfused ≈ B·H·S²·12 / 2 (causal)      fused ≈ B·H·S·dh·3·2 + O bytes

Derived column reports the per-layer reduction at the assigned shapes and
the projected new memory-roofline term for the hillclimbed cells (§Perf).
Correctness of the kernel itself (incl. in-kernel ABFT + SEU correction) is
asserted here on a small shape.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import ONLINE_BLOCK, InjectionSpec
from repro.kernels import ops, ref
from .common import emit


def traffic(b, h, s, dh, causal=True):
    unfused = b * h * s * s * 12 * (0.5 if causal else 1.0)
    fused = b * h * s * dh * 2 * 4        # q,k,v in + o out, bf16
    return unfused, fused


def run() -> None:
    # correctness + injected-SEU correction on a live shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 256, 64))
    k = jax.random.normal(ks[1], (2, 256, 64))
    v = jax.random.normal(ks[2], (2, 256, 64))
    spec = InjectionSpec(row=5, col=7, magnitude=500.0, k_step=0)
    out, rep = ops.flash_ft(q, k, v, ft=ONLINE_BLOCK, spec=spec,
                            inj_bh=1, inj_q_block=1)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    emit("flash_ft/correctness", float("nan"),
         f"seu_corrected=1 detections={int(rep[..., 0].sum())}")

    # HBM traffic model at the assigned shapes (per layer, global)
    for name, b, h, s, dh in [
        ("qwen2_train_4k", 256, 28, 4096, 128),
        ("qwen2_prefill_32k", 32, 28, 32768, 128),
        ("arctic_train_4k", 256, 56, 4096, 128),
    ]:
        unf, fus = traffic(b, h, s, dh)
        emit(f"flash_ft/{name}", float("nan"),
             f"unfused={unf/2**30:.1f}GiB fused={fus/2**30:.2f}GiB "
             f"reduction_x={unf/fus:.0f}")
