"""Fig. 16 (+21) analogue — FT-GEMM under error injection.

The paper injects 1…40 errors per outer-product sub-problem (K step 256,
K up to 10240) and shows (a) all errors are corrected (results match
cuBLAS) and (b) the overhead stays <10% vs. the non-injected FT kernel.

We reproduce both with the jnp online-ABFT path: a K-chunked outer-product
accumulation (the paper's Eq. 4 structure) where every chunk suffers one
injected SEU; final result must equal the clean GEMM; timing vs error count
shows the (branchless) correction cost is error-count-independent — an
improvement over the paper, whose correction cost scales with errors.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ft_verdict_dot
from repro.core.policy import ONLINE_BLOCK, InjectionSpec
from .common import emit, time_fn


def chunked_ft_gemm(a, b, k_chunk: int, inject: bool, key=None):
    """Outer-product accumulation over K chunks; ≤1 SEU per chunk (SEU model,
    one per detection interval — the paper's Fig. 16 setup)."""
    m, k = a.shape
    n = b.shape[1]
    n_chunks = k // k_chunk
    acc = jnp.zeros((m, n), jnp.float32)
    for c in range(n_chunks):
        ac = a[:, c * k_chunk:(c + 1) * k_chunk]
        bc = b[c * k_chunk:(c + 1) * k_chunk, :]
        spec = None
        if inject:
            spec = InjectionSpec(row=(7 * c) % m, col=(13 * c) % n,
                                 magnitude=50.0 + c)
        out, v = ft_verdict_dot(ac, bc, ONLINE_BLOCK, spec=spec)
        acc = acc + out
    return acc


def run() -> None:
    m = n = 512
    k_chunk = 256
    rng = np.random.default_rng(0)
    for n_err in (1, 8, 20, 40):
        k = k_chunk * n_err
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        ref = np.asarray(a @ b)

        clean = jax.jit(lambda a, b: chunked_ft_gemm(a, b, k_chunk, False))
        injected = jax.jit(lambda a, b: chunked_ft_gemm(a, b, k_chunk, True))
        out = np.asarray(injected(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)
        us_clean = time_fn(clean, a, b)
        us_inj = time_fn(injected, a, b)
        over = 100.0 * (us_inj / us_clean - 1.0)
        emit(f"error_injection/k{k}_errors{n_err}", us_inj,
             f"all_corrected=1 overhead_vs_clean_ft={over:.1f}% "
             f"(paper: <10%)")
