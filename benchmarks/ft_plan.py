"""FT-planner economics: coverage-vs-overhead Pareto curves per model
config, planned-vs-uniform gate, and the storm-escalation campaign.

Three claims, each asserted (CI runs this suite as a smoke gate):

  1. `core.policy.plan_ft` on a full-size dense config finds a mixed
     per-site policy whose predicted overhead beats uniform-`correct`
     while still covering >= 95% of the protected FLOPs — the
     memory-bound sites (attention / decode cache GEMMs) absorb their
     checksums inside the bandwidth roofline for free, so only the
     compute-bound projections pay, and those can sit one rung lower.
  2. The same holds on the MoE config (grouped + router GEMM mix).
  3. A `StormDetector` alert demonstrably switches the storming site's
     resolved level at runtime: a detect-only site under a stochastic
     SEU campaign is promoted by the `EscalationController` to
     correct/step, after which its *corrected* counter goes nonzero in
     the per-site report (through a `MemoryEmitter` sink).

Site costs are collected with `jax.eval_shape` under
`policy.record_site_costs` — shapes only, no FLOPs are executed, so the
full-size configs are traced even in CI smoke mode. Rows:

    ft_plan/<cfg>/budget<frac>,NaN,coverage=..;overhead=..%
    ft_plan/<cfg>/uniform_correct,NaN,overhead=..%
    ft_plan/<cfg>/gate,NaN,planned<uniform@cov>=0.95
    ft_plan/escalation,NaN,promoted=..;corrected=..

The chosen plan for each config is dumped to
``benchmarks/ft_plan_<cfg>.json`` (`FTPlan.to_json`) — render it with
``python -m repro.tools.report --policy benchmarks/ft_plan_<cfg>.json``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import policy, telemetry
from repro.core.policy import FTPolicy, ONLINE_BLOCK
from repro.models import blocks, transformer
from repro.tools import metrics as metrics_lib

from .common import emit

#: Pareto sweep budgets (fractions of the un-protected roofline step time).
BUDGETS = (0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1)
MIN_COVERAGE = 0.95


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def _site_costs(cfg, batch: int, seq: int):
    """Trace one forward abstractly and collect per-site GEMM populations.
    `jax.eval_shape` never executes compute, so full-size configs are fine;
    layer-scanned sites are recorded once per scan body (uniform
    undercount — relative site weights inside the scan are exact)."""
    ctx = blocks.Ctx(ft=ONLINE_BLOCK, key=None, dtype=jnp.bfloat16)
    toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    with policy.record_site_costs() as costs:
        params = jax.eval_shape(
            lambda k: transformer.init(cfg, k, jnp.bfloat16),
            jax.random.PRNGKey(0))
        jax.eval_shape(lambda p, t: transformer.forward(p, t, cfg, ctx),
                       params, toks)
    return list(costs.values())


def _plan_config(name: str, cfg, batch: int, seq: int) -> None:
    costs = _site_costs(cfg, batch, seq)
    uniform = policy.uniform_overhead_s(costs)
    base_s = sum(c.times("off", "final")[0] for c in costs)
    curve = policy.pareto_curve(costs, BUDGETS)
    for plan in curve:
        emit(f"ft_plan/{name}/budget{plan.budget_frac:g}", float("nan"),
             f"coverage={plan.coverage:.3f};"
             f"overhead={100 * plan.overhead_frac:.3f}%")
    emit(f"ft_plan/{name}/uniform_correct", float("nan"),
         f"overhead={100 * uniform / base_s:.3f}%")

    # The gate plan: the MINIMAL swept budget reaching >= 95% coverage —
    # where compute-bound sites still sit below correct/step, so the
    # planned overhead is strictly cheaper than the uniform bar.
    gated = next((p for p in curve if p.coverage >= MIN_COVERAGE), None)
    assert gated is not None, (
        f"{name}: no swept budget reaches {MIN_COVERAGE:.0%} coverage "
        f"(max {max(p.coverage for p in curve):.3f}) — planner regression")
    assert gated.overhead_s < uniform, (
        f"{name}: planned policy at {gated.coverage:.1%} coverage costs "
        f"{gated.overhead_s:.3e}s, not below uniform-correct "
        f"{uniform:.3e}s — the roofline budget brings no saving")
    emit(f"ft_plan/{name}/gate", float("nan"),
         f"planned={100 * gated.overhead_s / base_s:.3f}%"
         f"<uniform={100 * uniform / base_s:.3f}%"
         f"@cov={gated.coverage:.3f}")
    out = os.path.join(os.path.dirname(__file__), f"ft_plan_{name}.json")
    with open(out, "w") as f:
        f.write(gated.to_json())


def _escalation_campaign() -> None:
    """Storm → promote → corrected-counter-nonzero round trip on a smoke
    dense model (xla backend, jnp stochastic injector, CPU-friendly)."""
    from repro.configs.phi4_mini_38b import SMOKE as cfg

    params = transformer.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    target = "wq"
    # Detect-only at the target: SDCs are *seen* but not corrected, so the
    # corrected counter stays zero until the controller promotes the site.
    base = FTPolicy(rules=((target, ONLINE_BLOCK.replace(
        action="detect", verify="final", inject_rate=1.0)),),
        default=ONLINE_BLOCK)
    sink = metrics_lib.MetricsSink(
        emitters=[mem := metrics_lib.MemoryEmitter()],
        detector=telemetry.StormDetector(window=4, min_detections=3.0))
    esc = policy.EscalationController(base, cooldown_steps=8).attach(sink)

    def run_step(step: int) -> dict:
        ctx = blocks.Ctx(ft=esc.current_policy(),
                         key=jax.random.fold_in(jax.random.PRNGKey(7), step),
                         dtype=jnp.float32, inject_sites=(target,))
        _, aux = transformer.forward(params, toks, cfg, ctx)
        sink.record_ft(jax.tree_util.tree_map(jax.device_get, aux.ft),
                       step=step)
        rec = sink.step_end(step)
        esc.step_end(step)
        return rec

    promoted_at = None
    corrected_after = 0.0
    for step in range(12):
        rec = run_step(step)
        if promoted_at is None and target in esc.promoted_sites:
            promoted_at = step
            lvl = esc.current_policy().resolve(target)
            assert lvl.corrects and lvl.verify == "step", lvl
        if promoted_at is not None:
            for row in rec.get("ft_sites", ()):
                if row["site"] == target:
                    corrected_after += row["corrected"]
    assert promoted_at is not None, (
        "storm campaign never tripped the detector — escalation gate "
        f"cannot run (alerts={sink.detector.alerts})")
    assert corrected_after > 0, (
        f"site {target!r} was promoted at step {promoted_at} but its "
        f"corrected counter stayed zero — the promoted level did not "
        f"reach the dispatch front")
    assert any(r.get("alerts") for r in mem.records), \
        "MemoryEmitter saw no storm alert record"
    emit("ft_plan/escalation", float("nan"),
         f"promoted_step={promoted_at};corrected={corrected_after:.0f}")


def run() -> None:
    from repro.configs.phi4_mini_38b import CONFIG as dense_cfg
    from repro.configs.qwen3_moe_235b import CONFIG as moe_cfg

    seq = 512 if _smoke() else 4096
    _plan_config("dense", dense_cfg, 1, seq)
    _plan_config("moe", moe_cfg, 1, seq)
    _escalation_campaign()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
