"""FT serving engine benchmark + CI gates (PR 9).

Four claims, each emitted as a CSV row and asserted in place so a serving
regression fails CI rather than a dashboard:

  1. **Paged ≡ dense** — the continuous-batching engine (paged KV, per-row
     ragged flashft decode) produces EXACTLY the greedy token streams of
     the slot-based dense baseline (`train.serve.generate`) with ABFT on.
     Greedy argmax equality over every step is the token-level form of the
     logits-allclose gate (the numeric form lives in
     tests/test_serve_engine.py).
  2. **Throughput + TTFT** — tokens/s/slot and submit→first-token latency
     under synthetic multi-request traffic, engine vs the dense baseline.
     CPU wall time (Pallas decode in interpret mode, compile included — a
     fresh engine retraces) is a *trend* row; the structural rows are what
     transfer to TPU.
  3. **HBM per slot** — the paged pool's bytes-per-slot vs the dense
     n_slots × max_len stripe, from `kv_cache.PagePlan` accounting;
     asserts paged ≤ dense (strictly < when a page < max_len exists).
  4. **Decode-path SEU campaign** — in-kernel stochastic SEUs injected at
     the `dec_flash` site through `paged_decode_step`, fed to a real
     `MetricsSink`: the corrected-SEU counters must be NONZERO and every
     detection must attribute to `dec_flash` only.

``REPRO_BENCH_SMOKE=1`` shrinks shapes/traffic. Run via
``python -m benchmarks.run --only serve_engine``.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import telemetry
from repro.core.policy import FTConfig
from repro.models import transformer as tfm
from repro.models.blocks import Ctx
from repro.tools import metrics as metrics_lib
from repro.train import kv_cache as kvc
from repro.train import serve
from repro.train.engine import EngineConfig, ServeEngine
from .common import emit

FT = FTConfig(action="correct", level="block", backend="pallas")


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def _setup(smoke: bool):
    if smoke:
        cfg = ModelConfig(arch_id="serve-bench", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=256, head_dim=128)
        traffic = dict(n_req=4, prompt_len=12, max_new=6, n_slots=2,
                       max_len=32, page_size=8)
    else:
        cfg = ModelConfig(arch_id="serve-bench", family="dense", n_layers=4,
                          d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                          vocab_size=2048, head_dim=128)
        traffic = dict(n_req=8, prompt_len=64, max_new=16, n_slots=4,
                       max_len=128, page_size=16)
    run = RunConfig(model=cfg, ft=FT, dtype="float32")
    params = tfm.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, run, params, traffic


def _prompts(t, vocab):
    rng = np.random.default_rng(7)
    return rng.integers(1, vocab, (t["n_req"], t["prompt_len"]))


# ---------------------------------------------------------------------------
# 1 + 2: paged ≡ dense token streams, tokens/s/slot, TTFT
# ---------------------------------------------------------------------------

def _engine_pass(cfg, run, params, t, prompts, sink=None):
    ec = EngineConfig(max_len=t["max_len"], n_slots=t["n_slots"],
                      page_size=t["page_size"],
                      max_new_tokens=t["max_new"])
    eng = ServeEngine(params, cfg, run, ec, sink=sink)
    for p in prompts:
        eng.submit(p)
    t0 = time.perf_counter()
    res = eng.run()
    dt = time.perf_counter() - t0
    return eng, res, dt


def _paged_vs_dense(cfg, run, params, t) -> None:
    prompts = _prompts(t, cfg.vocab_size)
    sc = serve.ServeConfig(max_len=t["max_len"],
                           batch_slots=t["n_req"])
    t0 = time.perf_counter()
    dense_toks = serve.generate(params, prompts, cfg, run, sc,
                                max_new_tokens=t["max_new"])
    dt_dense = time.perf_counter() - t0
    eng, res, dt_eng = _engine_pass(cfg, run, params, t, prompts)

    # the gate: greedy streams identical, every page back on the free list
    assert len(res) == t["n_req"]
    for i, r in enumerate(res):
        assert r.tokens == dense_toks[i].tolist(), (
            f"paged/dense divergence at rid {i}: "
            f"{r.tokens} vs {dense_toks[i].tolist()}")
    assert eng.alloc.n_free == eng.plan.n_pages - 1
    emit("serve_engine/paged_vs_dense_tokens", float("nan"),
         f"requests={t['n_req']} tokens_per_req={t['max_new']} "
         f"exact_match=1 pages_conserved=1")

    n_tok = sum(len(r.tokens) for r in res)
    tps_slot = n_tok / dt_eng / t["n_slots"]
    tps_dense = n_tok / dt_dense / t["n_req"]   # baseline: 1 slot per req
    ttft = [r.ttft_s for r in res]
    emit("serve_engine/engine_throughput", dt_eng * 1e6,
         f"tok_per_s_per_slot={tps_slot:.1f} slots={t['n_slots']} "
         f"tokens={n_tok}")
    emit("serve_engine/dense_baseline_throughput", dt_dense * 1e6,
         f"tok_per_s_per_slot={tps_dense:.1f} slots={t['n_req']}")
    emit("serve_engine/ttft", float("nan"),
         f"mean_s={np.mean(ttft):.4f} max_s={np.max(ttft):.4f} "
         f"queued_requests={t['n_req'] - t['n_slots']}")


# ---------------------------------------------------------------------------
# 3: HBM per slot — paged pool vs dense stripe
# ---------------------------------------------------------------------------

def _hbm_per_slot(cfg, t) -> None:
    plan = kvc.plan_pages(cfg, FT, n_slots=t["n_slots"],
                          max_len=t["max_len"], dtype=jnp.float32,
                          page_size=t["page_size"])
    paged = plan.hbm_bytes_per_slot(cfg, dtype_bytes=4)
    dense = plan.dense_hbm_bytes_per_slot(cfg, dtype_bytes=4)
    assert paged <= dense, (paged, dense)
    # at slack=1 every slot can reach max_len so per-slot parity with dense
    # is the ceiling; the paged win is oversubscription — a pool sized for
    # *average* occupancy (slack=0.5 here) while dense must provision peak:
    over = kvc.plan_pages(cfg, FT, n_slots=t["n_slots"],
                          max_len=t["max_len"], dtype=jnp.float32,
                          page_size=t["page_size"], slack=0.5)
    over_b = over.hbm_bytes_per_slot(cfg, dtype_bytes=4)
    assert over_b < dense, (over_b, dense)
    emit("serve_engine/hbm_per_slot", float("nan"),
         f"paged_bytes={paged} dense_bytes={dense} "
         f"ratio={paged / dense:.3f} oversub_bytes={over_b} "
         f"oversub_ratio={over_b / dense:.3f} pages={plan.n_pages} "
         f"page_size={plan.page_size}")


# ---------------------------------------------------------------------------
# 4: decode-path SEU campaign through the sink
# ---------------------------------------------------------------------------

def _seu_campaign(cfg, params, t, n_steps: int = 6) -> None:
    ft = FT.replace(inject_rate=1.0)
    page, mp = t["page_size"], -(-t["max_len"] // t["page_size"])
    b = t["n_slots"]
    n_pages = 1 + b * mp
    alloc = kvc.PageAllocator(n_pages, b, mp, page)
    cache = kvc.init_paged_cache(cfg.n_layers, n_pages, b, mp,
                                 cfg.n_kv_heads, page, cfg.head_dim,
                                 jnp.float32)
    rng = np.random.default_rng(3)
    lengths = np.full((b,), t["prompt_len"], np.int32)
    for slot in range(b):
        s, _ = alloc.alloc_slot(int(lengths[slot]))
        shape = (cfg.n_layers, int(lengths[slot]), cfg.n_kv_heads,
                 cfg.head_dim)
        cache = kvc.write_prefill(
            cache, s, jnp.asarray(alloc.page_table[s]),
            jnp.asarray(rng.standard_normal(shape), jnp.float32),
            jnp.asarray(rng.standard_normal(shape), jnp.float32),
            int(lengths[slot]))
        alloc.ensure(s, int(lengths[slot]) + n_steps + 1)  # capacity upfront
    cache["page_table"] = jnp.asarray(alloc.page_table)
    cache["length"] = jnp.asarray(lengths)

    @jax.jit
    def step(p, tok, pcache, key):
        ctx = Ctx(ft=ft, key=key, dtype=jnp.float32,
                  inject_sites=("dec_flash",))
        (logits, nc), rep = telemetry.scoped(
            lambda: tfm.paged_decode_step(p, tok, pcache, cfg, ctx))
        return logits, nc, rep

    path = os.path.join(tempfile.mkdtemp(prefix="serve_bench_"),
                        "serve_metrics.jsonl")
    sink = metrics_lib.MetricsSink([metrics_lib.JsonlEmitter(path)])
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, 1)), jnp.int32)
    for i in range(n_steps):
        logits, cache, rep = step(params, tok, cache,
                                  jax.random.PRNGKey(50 + i))
        sink.record_ft(rep, step=i)
        sink.gauge("phase", "decode")
        sink.step_end(i)
        tok = jnp.argmax(logits.reshape(b, -1), -1).astype(jnp.int32)[:, None]
    sink.close()

    records = metrics_lib.read_jsonl(path)
    assert len(records) == n_steps
    agg = metrics_lib.aggregate_sites(records)
    hit = {s: a for s, a in agg.items() if a["detected"] > 0}
    assert "dec_flash" in hit, f"no decode-path detections: {agg}"
    assert set(hit) == {"dec_flash"}, (
        f"detections leaked beyond dec_flash: {hit}")
    corrected = hit["dec_flash"]["corrected"]
    assert corrected > 0, f"SEUs detected but none corrected: {hit}"
    emit("serve_engine/decode_seu_campaign", float("nan"),
         f"site=dec_flash detected={hit['dec_flash']['detected']:.0f} "
         f"corrected={corrected:.0f} steps={n_steps} jsonl_ok=1")


def run() -> None:
    cfg, run_cfg, params, traffic = _setup(_smoke())
    _paged_vs_dense(cfg, run_cfg, params, traffic)
    _hbm_per_slot(cfg, traffic)
    _seu_campaign(cfg, params, traffic)
