"""Fig. 9 analogue — step-wise GEMM optimization ladder.

The paper climbs: naive → threadblock tiling → thread tiling → warp tiling →
vectorized → prefetch (611 → 4654 GFLOPS on a T4). The TPU ladder collapses
several rungs into the Pallas/Mosaic model (DESIGN.md §2), so ours is:

  r0  XLA jnp.dot           — the "vendor library" baseline (cuBLAS analogue)
  r1  naive Pallas          — one output block, whole-K operands in VMEM
  r2  tiled Pallas          — (bm,bn,bk) BlockSpec grid + f32 VMEM accumulator
  r3  autotuned Pallas      — shape-class params (§3.2 codegen)

Derived metrics that transfer to TPU: VMEM working set (must fit 16 MiB) and
HBM traffic factor = bytes moved / minimum. Wall time is interpret-mode
(correctness path) for kernels, XLA-CPU for r0.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import autotune, gemm, ops
from .common import emit, time_fn


def hbm_traffic_factor(m, n, k, bm, bn, bk):
    """Bytes moved from HBM relative to the compulsory minimum.
    Tiled GEMM re-reads A once per column-block and B once per row-block."""
    reads = m * k * (n // bn) + k * n * (m // bm) + m * n
    return reads / (m * k + k * n + m * n)


def run() -> None:
    m = n = k = 512
    a = jnp.asarray(np.random.default_rng(0).normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).normal(size=(k, n)), jnp.float32)

    r0 = jax.jit(lambda a, b: a @ b)
    emit("stepwise/r0_xla_dot", time_fn(r0, a, b), "baseline")

    out_naive = gemm.naive_gemm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out_naive), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)
    vmem_naive = (128 * k + k * 128 + 128 * 128) * 4
    emit("stepwise/r1_naive_pallas", float("nan"),
         f"vmem={vmem_naive/2**20:.2f}MiB(scales with K — OOVMEM beyond "
         f"K~16k; no k-pipeline) correct=1")

    p = autotune.KernelParams(128, 128, 128)
    out_tiled = ops.matmul(a, b, params=p, interpret=True)
    np.testing.assert_allclose(np.asarray(out_tiled), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)
    emit("stepwise/r2_tiled_pallas", float("nan"),
         f"vmem={p.vmem_bytes(4)/2**20:.2f}MiB traffic_x"
         f"={hbm_traffic_factor(m, n, k, p.bm, p.bn, p.bk):.1f} correct=1")

    pa = autotune.build_params(m, n, k)
    out_auto = ops.matmul(a, b, params=pa, interpret=True)
    np.testing.assert_allclose(np.asarray(out_auto), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)
    emit("stepwise/r3_autotuned_pallas", float("nan"),
         f"class={pa.shape_class} vmem={pa.vmem_bytes(4)/2**20:.2f}MiB "
         f"traffic_x={hbm_traffic_factor(m, n, k, pa.bm, pa.bn, pa.bk):.1f} "
         f"correct=1")
