"""Mamba2-780m — SSD (state-space duality), attention-free. [arXiv:2405.21060]
48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    attention_free=True, subquadratic=True,
)

SMOKE = ModelConfig(
    arch_id="mamba2-780m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=512,
    ssm=SSMConfig(state=16, head_dim=16, expand=2, conv_width=4, chunk=32),
    attention_free=True, subquadratic=True,
)
