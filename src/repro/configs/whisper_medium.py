"""Whisper-medium — encoder-decoder; conv/mel frontend is a STUB input
(precomputed frame embeddings). [arXiv:2212.04356]
24+24L d_model=1024 16H d_ff=4096 vocab=51865."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=51865, n_audio_frames=1500,
)

SMOKE = ModelConfig(
    arch_id="whisper-medium-smoke", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, n_audio_frames=32,
)
