"""Snowflake Arctic 480B — dense-MoE hybrid: every layer has a parallel dense
residual FFN plus a 128-expert top-2 MoE FFN. [hf:Snowflake/snowflake-arctic-base]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, expert_d_ff=4864,
                  dense_d_ff=4864, group_size=512),
)

SMOKE = ModelConfig(
    arch_id="arctic-480b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=96, dense_d_ff=96,
                  group_size=64),
)
