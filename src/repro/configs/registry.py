"""Architecture registry: ``--arch <id>`` resolution for launchers, tests,
benchmarks, and the dry-run."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

from . import (arctic_480b, codeqwen15_7b, mamba2_780m, minitron_4b,
               phi3_vision_42b, phi4_mini_38b, qwen2_7b, qwen3_moe_235b,
               whisper_medium, zamba2_27b)

_MODULES = {
    "arctic-480b": arctic_480b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "qwen2-7b": qwen2_7b,
    "codeqwen1.5-7b": codeqwen15_7b,
    "phi4-mini-3.8b": phi4_mini_38b,
    "minitron-4b": minitron_4b,
    "mamba2-780m": mamba2_780m,
    "phi-3-vision-4.2b": phi3_vision_42b,
    "whisper-medium": whisper_medium,
    "zamba2-2.7b": zamba2_27b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """All 40 assigned (arch × shape) cells."""
    return tuple((a, s) for a in ARCH_IDS for s in SHAPES)
