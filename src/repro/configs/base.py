"""Dataclass config system.

One `ModelConfig` describes any architecture in the zoo (dense / MoE / SSM /
hybrid / enc-dec / VLM); `RunConfig` adds step-shape + policy knobs. Every
assigned architecture contributes a module `repro/configs/<id>.py` exposing
`CONFIG` (the exact assignment numbers) and `SMOKE` (a reduced same-family
variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.policy import FTConfig, ONLINE_BLOCK


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    #: Arctic-style parallel dense residual FFN width (0 = none).
    dense_d_ff: int = 0
    #: GShard dispatch group size (tokens). Smaller ⇒ less dispatch-einsum
    #: FLOPs overhead but more capacity variance. Hillclimb lever.
    #: (padded dispatch only — the grouped path has no capacity geometry.)
    group_size: int = 512
    capacity_factor: float = 1.25
    #: Expert dispatch: "grouped" (PR 3 default — ragged ft_grouped_matmul
    #: over a row-sorted token buffer, zero capacity padding, no dropped
    #: tokens) or "padded" (the GShard capacity-einsum baseline, kept for
    #: the moe_dispatch benchmark comparison).
    dispatch: str = "grouped"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 128          # N — SSM state dimension
    head_dim: int = 64        # P — channels per SSD head
    expand: int = 2           # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256          # SSD chunk length (training scan)
    n_groups: int = 1         # B/C groups


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    #: hybrid: one shared attention block applied every `attn_every` SSM blocks
    attn_every: int = 6
    #: encdec: encoder depth (n_layers counts decoder); audio frame count
    enc_layers: int = 0
    n_audio_frames: int = 1500
    #: vlm: number of prepended image-patch embeddings (stub frontend)
    n_patches: int = 576
    #: attention-free archs have no KV cache / quadratic attention
    attention_free: bool = False
    #: supports sub-quadratic long-context decode (SSM / hybrid)
    subquadratic: bool = False

    @property
    def qkv_dims(self) -> Tuple[int, int]:
        return (self.n_heads * self.head_dim,
                self.n_kv_heads * self.head_dim)

    def padded_vocab(self, multiple: int = 256) -> int:
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape × step-kind) cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    ft: FTConfig = ONLINE_BLOCK
    dtype: str = "bfloat16"
    # training
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    grad_clip: float = 1.0
    #: optimizer state dtype: "f32" (AdamW), "q8" (int8 m/v — memory-sharded
    #: huge models; see DESIGN.md on arctic-480b fitting a 256-chip pod)
    opt_state: str = "f32"
    remat: str = "full"       # "none" | "full"
    microbatch: int = 0       # 0 = no gradient accumulation
    # attention sharding scheme: "heads" (TP over heads, GSPMD-padded when
    # head count ∤ mesh) | "none" (batch-only). Hillclimb lever.
    attn_shard: str = "heads"
    attn_chunk: int = 512     # query-chunk for flash-style attention scan
    # attention core: "auto" (flashft kernel on the pallas FT backend,
    # chunked-jnp scan elsewhere) | "flash" | "chunked" (force the oracle).
    attn_impl: str = "auto"
    seed: int = 0
