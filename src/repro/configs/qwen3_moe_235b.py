"""Qwen3-MoE 235B-A22B — 128 experts, top-8 routing. [hf:Qwen/Qwen3-30B-A3B
family] 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=1536,
                  dense_d_ff=0, group_size=256),
)

SMOKE = ModelConfig(
    arch_id="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=4, expert_d_ff=64, dense_d_ff=0,
                  group_size=64),
)
