"""Phi-3-vision 4.2B — phi3-mini backbone + CLIP frontend (patch embeddings
are a STUB input per the assignment). [hf:microsoft/Phi-3-vision-128k-instruct]
32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064, n_patches=576,
)

SMOKE = ModelConfig(
    arch_id="phi3-vision-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, n_patches=16,
)
