"""Zamba2-2.7B — Mamba2 backbone + shared attention block every 6 SSM blocks.
[arXiv:2411.15242] 54L d_model=2560 32H (MHA kv=32) d_ff=10240 ssm_state=64."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, attn_every=6,
    ssm=SSMConfig(state=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    subquadratic=True,
)

SMOKE = ModelConfig(
    arch_id="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, attn_every=2,
    ssm=SSMConfig(state=16, head_dim=16, expand=2, conv_width=4, chunk=32),
    subquadratic=True,
)
