"""Production mesh construction (assignment-mandated shapes).

A FUNCTION, not a module constant — importing this module never touches JAX
device state (device count is locked at first backend init, and only
dryrun.py is allowed to force 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod over ("data","model"); multi-pod adds a leading
    pod axis: (2,16,16) = 512 chips over ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / single-host training)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
