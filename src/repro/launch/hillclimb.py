import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run named variants of the three chosen cells and
log (hypothesis, change, before, after) to benchmarks/hillclimb_results.json.

    PYTHONPATH=src python -m repro.launch.hillclimb [--only qwen2_train]
"""
import argparse
import dataclasses
import json
import traceback

from repro.configs.base import SSMConfig
from repro.core.policy import ONLINE_BLOCK
from repro.launch import dryrun

#: variant = (cell_key, arch, shape, run_over, cfg_over, hypothesis)
VARIANTS = [
    # ---- Cell A: qwen2-7b × train_4k (paper-representative, memory-bound)
    ("qwen2_train/v1_head_shard", "qwen2-7b", "train_4k", {}, None,
     "explicit Megatron-SP head constraints in attention remove GSPMD "
     "reshard pathologies vs v0 propagation; collective term drops"),
    ("qwen2_train/v2_remat_dots", "qwen2-7b", "train_4k",
     {"remat": "dots"}, None,
     "save GEMM outputs instead of full remat: recompute FLOPs −~30%, "
     "bytes −~25% at higher peak memory"),
    ("qwen2_train/v3_static_tau", "qwen2-7b", "train_4k",
     {"remat": "dots", "ft": ONLINE_BLOCK.replace(static_tau=0.5)}, None,
     "calibrated static ABFT threshold removes two operand max-reduction "
     "passes per protected GEMM: memory term −few %"),
    ("qwen2_train/v4_no_ft_reference", "qwen2-7b", "train_4k",
     {"remat": "dots", "ft": None}, None,
     "FT-off reference isolates the total ABFT cost at scale (paper's "
     "8.9% overhead claim, roofline version)"),
    ("qwen2_train/v5_no_attn_ft", "qwen2-7b", "train_4k",
     {"ft": ONLINE_BLOCK.replace(static_tau=0.5, protect_attention=False)},
     None,
     "most of the jnp-path ABFT memory cost is checksum passes over the "
     "(chunk,S) attention score matrices; keeping ABFT on every projection "
     "but not the attention core (the paper's own scope: GEMM library "
     "calls) recovers most of the no-FT memory term"),
    # ---- Cell B: arctic-480b × decode_32k (most collective-bound)
    ("arctic_decode/v1_2d_weights", "arctic-480b", "decode_32k", {}, None,
     "2D weight-stationary serving sharding (experts ff over data, no "
     "FSDP gather) turns 76 GB/step weight all-gathers into MB-scale "
     "activation psums: collective term −>10×"),
    ("arctic_decode/v2_tokens_grouping", "arctic-480b", "decode_32k",
     {"microbatch": 0}, {"moe": None}, None),   # placeholder — filled below
    # ---- Cell C: mamba2-780m × train_4k (worst roofline fraction)
    ("mamba2_train/v1_baseline_fixed", "mamba2-780m", "train_4k", {}, None,
     "re-measure under v1 code (loops/sharding fixes)"),
    ("mamba2_train/v2_chunk128", "mamba2-780m", "train_4k", {},
     {"ssm": SSMConfig(state=128, head_dim=64, expand=2, conv_width=4,
                       chunk=128)},
     "SSD chunk 256→128 halves the intra-chunk quadratic work "
     "(decay/CBᵀ tensors scale with Q²·nc = Q·L): compute & memory drop"),
    ("mamba2_train/v3_chunk512", "mamba2-780m", "train_4k", {},
     {"ssm": SSMConfig(state=128, head_dim=64, expand=2, conv_width=4,
                       chunk=512)},
     "counter-hypothesis: bigger chunks amortize state passes better"),
]
# drop the placeholder
VARIANTS = [v for v in VARIANTS if v[5] is not None]

#: (cell_key, arch, shape, run_over, cfg_over, rules_over, hypothesis)
VARIANTS_R = [
    ("mamba2_train/v4_batch_only_shard", "mamba2-780m", "train_4k", {},
     {"ssm": SSMConfig(state=128, head_dim=64, expand=2, conv_width=4,
                       chunk=512)},
     {"seq": None, "batch": ("pod", "data", "model")},
     "the 24-28s collective term comes from SSD chunk reshapes fighting "
     "the seq-sharding; batch=256 divides the full 256-chip mesh, so "
     "batch-only sharding makes every SSD reshape local — collective "
     "term should collapse to FSDP gathers + grad reduce only"),
    ("arctic_decode/v2_capacity_floor", "arctic-480b", "decode_32k", {},
     None, None,
     "decode groups are 8 tokens; the old capacity floor of 4 made "
     "n_grp·E·C = 8192 expert slots for 256 routed tokens (32× dispatch "
     "waste) — floor 1 cuts the memory term further"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="benchmarks/hillclimb_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    results = {}
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    todo = [v + (None,) for v in VARIANTS] + \
        [(k, a, s, r, c, h, ro) for k, a, s, r, c, ro, h in VARIANTS_R]
    for key, arch, shape, run_over, cfg_over, hypo, rules_over in todo:
        if args.only and not key.startswith(args.only):
            continue
        if key in results and results[key].get("status") == "ok" \
                and not args.force:
            print(f"[cached] {key}")
            continue
        ft_on = True
        ro = dict(run_over)
        if ro.get("ft", "unset") is None:
            ft_on = False
            ro.pop("ft")
        print(f"=== {key}: {hypo}")
        try:
            res = dryrun.run_cell(arch, shape, multi_pod=False, ft_on=ft_on,
                                  run_over=ro or None, cfg_over=cfg_over,
                                  rules_over=rules_over, probes=True)
            res["hypothesis"] = hypo
            results[key] = res
        except Exception as e:                    # noqa: BLE001
            traceback.print_exc()
            results[key] = {"status": "error", "error": str(e)[:2000],
                            "hypothesis": hypo}
        json.dump(results, open(args.out, "w"), indent=1)
    print("done →", args.out)


if __name__ == "__main__":
    main()
