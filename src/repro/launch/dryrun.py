import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
512 placeholder host devices, proving the distribution config is coherent
without hardware (deliverable (e)).

For every cell:
    with mesh:
        lowered = jax.jit(step_fn).lower(**input ShapeDtypeStructs w/ shardings)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes → results JSON

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Results accumulate incrementally in --out (default benchmarks/dryrun_results.json).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.policy import ONLINE_BLOCK, FT_OFF
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo
from repro.models.blocks import Ctx
from repro.optim import adamw
from repro.tools import roofline
from repro.train import train_loop

#: per-shape logical-rule overrides (DESIGN.md §4)
RULES_BY_SHAPE = {
    "train_4k": {},
    "prefill_32k": {},
    # decode: cache sharded batch×seq (KV seq over "model" — a 32k MHA
    # cache at batch 128 is TB-scale, batch sharding alone leaves >100GB/
    # dev); weights 2D-stationary (TP over model + expert-ff over data) so
    # no per-step FSDP weight all-gathers — partial-sum psums instead
    "decode_32k": {"seq": None, "tokens": ("pod", "data"),
                   "kv_seq": "model",
                   "embed_param": None, "moe_ff": "data"},
    # single-sequence long-context decode: shard the KV/state over the
    # model axis; no batch to shard
    "long_500k": {"seq": None, "batch": None, "kv_seq": "model",
                  "tokens": None, "exp_tokens": None,
                  "embed_param": None, "moe_ff": "data"},
}

#: per-arch run-config overrides (memory fits — DESIGN.md §4)
RUN_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "arctic-480b": {"opt_state": "q8"},
    "qwen3-moe-235b-a22b": {"opt_state": "q8"},
}


def run_config(arch: str, ft_on: bool = True) -> RunConfig:
    cfg = registry.get_config(arch)
    over = RUN_OVERRIDES.get(arch, {})
    return RunConfig(model=cfg, ft=ONLINE_BLOCK if ft_on else FT_OFF, **over)


# ---------------------------------------------------------------------------
# abstract inputs with shardings
# ---------------------------------------------------------------------------

def _with_sharding(struct_tree, spec_tree, mesh):
    def attach(s, spec):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(attach, struct_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_specs(batch_struct, mesh):
    def spec(s):
        if s.ndim >= 1:
            return shd.logical_to_spec(["batch"] + [None] * (s.ndim - 1))
        return P()
    return jax.tree.map(spec, batch_struct,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_params(cfg: ModelConfig, mesh, dtype=jnp.bfloat16):
    mod = model_zoo.module_for(cfg)
    struct = jax.eval_shape(
        lambda: mod.init(cfg, jax.random.PRNGKey(0), dtype))
    specs = shd.param_specs(struct)
    return _with_sharding(struct, specs, mesh), specs


def abstract_opt_state(params_struct, param_specs, opt_cfg, tc, mesh):
    struct = jax.eval_shape(
        lambda p: train_loop.init_opt_state(p, opt_cfg, tc), params_struct)
    if opt_cfg.q8:
        # q8 moments are block-quantized to (n_blocks, 256) int8 + per-block
        # scale vectors — the block dim has no tensor meaning, so shard it
        # over EVERY mesh axis (ZeRO-3 over the whole chip count; the v0
        # baseline sharded over data only → 16× state memory, see §Perf).
        # Input shardings must divide evenly ⇒ degrade through candidates.
        axes_all = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.axis_names)

        def spec_of(s):
            if s.ndim >= 1:
                candidates = [axes_all, ("data", "model"), ("data",), ()]
                for axes in candidates:
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    if s.shape[0] % size == 0:
                        lead = axes if len(axes) != 1 else axes[0]
                        return (P(lead, *([None] * (s.ndim - 1)))
                                if axes else P())
            return P()

        def attach(s):
            return jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, spec_of(s)))

        out = {"adam": jax.tree.map(attach, struct["adam"])}
        if tc.compress_grads:
            out["ef_error"] = _with_sharding(struct["ef_error"],
                                             param_specs, mesh)
        return out
    specs = {"adam": {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }}
    if tc.compress_grads:
        specs["ef_error"] = param_specs
    return _with_sharding(struct, specs, mesh)


def _cache_specs_tree(cache_struct, cfg: ModelConfig, shape: ShapeConfig):
    """Logical specs for KV/SSM caches: leading layer dim unsharded, then
    named dims depending on family (see model cache layouts)."""

    def spec(path_str, s):
        leaf = path_str.split("/")[-1]
        if leaf == "length":
            return shd.logical_to_spec(["batch"])
        if leaf in ("k", "v", "xk", "xv"):
            return shd.logical_to_spec(
                [None, "batch", "kv_seq", "kv_heads", None])
        if leaf == "ssm":
            return shd.logical_to_spec([None, "batch", "state", None, None])
        if leaf == "conv":
            return shd.logical_to_spec([None, "batch", None, "mlp"])
        return P()

    def visit(path, leaf):
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        return spec(ps, leaf)

    return jax.tree_util.tree_map_with_path(visit, cache_struct)


# ---------------------------------------------------------------------------
# per-kind lowering builders
# ---------------------------------------------------------------------------

def build_lowered(arch: str, shape_name: str, mesh, *, ft_on: bool = True,
                  run_over: Optional[Dict] = None, cfg_override=None,
                  rules_over: Optional[Dict] = None):
    cfg = cfg_override if cfg_override is not None \
        else registry.get_config(arch)
    shape = registry.get_shape(shape_name)
    run = run_config(arch, ft_on)
    if run_over:
        import dataclasses as dc
        run = dc.replace(run, **run_over)
    mod = model_zoo.module_for(cfg)
    rules = dict(RULES_BY_SHAPE[shape_name])
    if rules_over:
        rules.update(rules_over)

    with shd.use_mesh(mesh, rules):
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig(q8=(run.opt_state == "q8"))
            tc = train_loop.TrainConfig()
            p_struct, p_specs = abstract_params(cfg, mesh)
            o_struct = abstract_opt_state(p_struct, p_specs, opt_cfg, tc,
                                          mesh)
            b_struct = model_zoo.train_batch_specs(cfg, shape)
            b_struct = _with_sharding(b_struct, _batch_specs(b_struct, mesh),
                                      mesh)
            step = train_loop.make_train_step(cfg, run, opt_cfg, tc)
            fn = lambda p, o, b, s: step(p, o, b, s, None)
            lowered = jax.jit(fn).lower(
                p_struct, o_struct, b_struct,
                jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            p_struct, _ = abstract_params(cfg, mesh)
            b = model_zoo.prefill_specs(cfg, shape)
            b = _with_sharding(b, _batch_specs(b, mesh), mesh)
            c_struct = model_zoo.cache_specs(cfg, shape)
            c_struct = _with_sharding(
                c_struct, _cache_specs_tree(c_struct, cfg, shape), mesh)
            ctx = Ctx(ft=run.ft, key=None, dtype=jnp.bfloat16,
                      attn_shard=run.attn_shard,
                      attn_impl=run.attn_impl)

            def fn(params, cache, **binputs):
                extra = binputs.get("patches", binputs.get("frames"))
                kw = {}
                if cfg.family == "vlm":
                    kw["extra_embeds"] = extra
                if cfg.family == "encdec":
                    kw["frames"] = extra
                return mod.prefill(params, binputs["tokens"], cache, cfg,
                                   ctx, chunk=run.attn_chunk, **kw)

            lowered = jax.jit(fn).lower(p_struct, c_struct, **b)
        else:  # decode
            p_struct, _ = abstract_params(cfg, mesh)
            t_struct = model_zoo.decode_specs(cfg, shape)
            t_struct = _with_sharding(t_struct, _batch_specs(t_struct, mesh),
                                      mesh)
            c_struct = model_zoo.cache_specs(cfg, shape)
            c_struct = _with_sharding(
                c_struct, _cache_specs_tree(c_struct, cfg, shape), mesh)
            ctx = Ctx(ft=run.ft, key=None, dtype=jnp.bfloat16,
                      attn_shard=run.attn_shard,
                      attn_impl=run.attn_impl)

            def fn(params, token, cache):
                return mod.decode_step(params, token, cache, cfg, ctx)

            lowered = jax.jit(fn).lower(p_struct, t_struct["token"], c_struct)
    return lowered, cfg, shape


# ---------------------------------------------------------------------------
# depth-probe cost extrapolation
#
# XLA's cost_analysis (and the HLO text) count a while/scan BODY once, not
# × trip count — so a 94-layer scanned model would report ~1 layer of FLOPs
# and collectives. We therefore compile two shallow probes of the same cell
# (1 and 2 layer-groups, full width, same mesh/shardings/remat): the delta is
# the exact per-layer-group cost including its collectives, and
#     total = probe1 + delta × (n_groups − 1).
# The full-depth compile is still performed for memory analysis and to prove
# the cell compiles (deliverable (e)); probes only feed §Roofline.
# ---------------------------------------------------------------------------

def _probe_depths(cfg: ModelConfig):
    """(shallow cfg, deeper cfg, repetitions at full depth)."""
    import dataclasses as dc
    if cfg.family == "hybrid":
        one = dc.replace(cfg, n_layers=cfg.attn_every)
        two = dc.replace(cfg, n_layers=2 * cfg.attn_every)
        reps = cfg.n_layers // cfg.attn_every
    elif cfg.family == "encdec":
        one = dc.replace(cfg, n_layers=1, enc_layers=1)
        two = dc.replace(cfg, n_layers=2, enc_layers=2)
        reps = cfg.n_layers          # enc_layers == n_layers for whisper
    else:
        one = dc.replace(cfg, n_layers=1)
        two = dc.replace(cfg, n_layers=2)
        reps = cfg.n_layers
    return one, two, reps


def _cell_cost(arch, shape_name, mesh, cfg_override, *, ft_on, run_over,
               rules_over=None):
    """(flops, bytes, coll_bytes, coll_breakdown) for one probe compile.
    Probes lower with every model scan UNROLLED so cost_analysis and the
    HLO text see each layer/chunk body (cost counts loop bodies once)."""
    from repro.core import loops
    with loops.unrolled_scans():
        lowered, _, _ = build_lowered(arch, shape_name, mesh, ft_on=ft_on,
                                      run_over=run_over,
                                      cfg_override=cfg_override,
                                      rules_over=rules_over)
    compiled = lowered.compile()
    cost = roofline.cost_dict(compiled)
    cb, breakdown = roofline.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), float(cb), breakdown)


def probe_costs(arch, shape_name, mesh, *, ft_on, run_over,
                cfg_base=None, rules_over=None):
    cfg = cfg_base if cfg_base is not None else registry.get_config(arch)
    one, two, reps = _probe_depths(cfg)
    f1, b1, c1, bd1 = _cell_cost(arch, shape_name, mesh, one,
                                 ft_on=ft_on, run_over=run_over,
                                 rules_over=rules_over)
    f2, b2, c2, bd2 = _cell_cost(arch, shape_name, mesh, two,
                                 ft_on=ft_on, run_over=run_over,
                                 rules_over=rules_over)
    df, db, dc_ = max(f2 - f1, 0.0), max(b2 - b1, 0.0), max(c2 - c1, 0.0)
    total = {
        "flops": f1 + df * (reps - 1),
        "bytes accessed": b1 + db * (reps - 1),
        "coll_bytes": c1 + dc_ * (reps - 1),
    }
    breakdown = {k: bd1.get(k, 0) + (bd2.get(k, 0) - bd1.get(k, 0))
                 * (reps - 1) for k in set(bd1) | set(bd2)}
    per_layer = {"flops": df, "bytes": db, "coll_bytes": dc_}
    return total, breakdown, per_layer


def _tokens_of(cfg: ModelConfig, shape: ShapeConfig) -> float:
    if shape.kind == "train":
        t = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            t += shape.global_batch * cfg.n_audio_frames
        if cfg.family == "vlm":
            t += shape.global_batch * cfg.n_patches
        return float(t)
    if shape.kind == "prefill":
        t = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            t += shape.global_batch * cfg.n_audio_frames
        return float(t)
    return float(shape.global_batch)      # decode: one token per sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             ft_on: bool = True, run_over: Optional[Dict] = None,
             cfg_over: Optional[Dict] = None,
             rules_over: Optional[Dict] = None,
             probes: bool = True, verbose: bool = True) -> Dict[str, Any]:
    import dataclasses as dc
    cfg = registry.get_config(arch)
    if cfg_over:
        cfg = dc.replace(cfg, **cfg_over)
    shape = registry.get_shape(shape_name)
    if not model_zoo.supports_shape(cfg, shape):
        return {"status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(full-attention arch; DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered, cfg, shape = build_lowered(arch, shape_name, mesh, ft_on=ft_on,
                                        run_over=run_over,
                                        cfg_override=cfg if cfg_over else None,
                                        rules_over=rules_over)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            mem_d[attr] = int(getattr(mem, attr))
    cost_raw = roofline.cost_dict(compiled)
    result = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_d,
        "cost_raw": {k: cost_raw.get(k) for k in ("flops", "bytes accessed")
                     if k in cost_raw},
        "ft": ft_on,
    }
    del compiled, lowered

    if probes:
        # depth-probe extrapolation (scan bodies count once in XLA cost
        # analysis — see module docstring above probe_costs)
        total, breakdown, per_layer = probe_costs(
            arch, shape_name, mesh, ft_on=ft_on, run_over=run_over,
            cfg_base=cfg if cfg_over else None, rules_over=rules_over)
        tokens = _tokens_of(cfg, shape)
        mf_per_tok = model_zoo.model_flops_per_token(cfg)
        # 6·N·D is the *training* figure (fwd 2ND + bwd 4ND); fwd-only
        # steps (prefill/decode) use 2·N·D.
        kind_mult = 1.0 if shape.kind == "train" else (1.0 / 3.0)
        model_flops_dev = tokens * mf_per_tok * kind_mult / n_chips
        rl = roofline.analyze(
            {"flops": total["flops"], "bytes accessed":
             total["bytes accessed"]}, "", model_flops_dev)
        rl.coll_bytes = total["coll_bytes"]
        rl.collective_s = total["coll_bytes"] / roofline.LINK_BW
        rl.coll_breakdown = {k: int(v) for k, v in breakdown.items()}
        result["roofline"] = rl.to_dict()
        result["per_layer"] = per_layer

    if verbose:
        peak = (mem_d.get("argument_size_in_bytes", 0)
                + mem_d.get("temp_size_in_bytes", 0)
                + mem_d.get("output_size_in_bytes", 0))
        line = (f"[{arch} × {shape_name} × {result['mesh']}] "
                f"compile {t_compile:.0f}s  mem/dev {peak / 2**30:.2f}GiB")
        if probes:
            rd = result["roofline"]
            line += (f"  flops/dev {rd['hlo_flops']:.3e}  "
                     f"coll/dev {rd['coll_bytes'] / 2**20:.1f}MiB "
                     f"→ {rd['bottleneck']}-bound "
                     f"(useful {rd['useful_ratio']:.2f}, "
                     f"roofline {rd['roofline_fraction']:.2f})")
        print(line)
        print("  memory_analysis:", mem_d)
        print("  cost_analysis(raw,body-once):", result["cost_raw"])
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=list(registry.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-ft", action="store_true")
    ap.add_argument("--out", default="benchmarks/dryrun_results.json")
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    args = ap.parse_args()

    results: Dict[str, Any] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    cells = (registry.all_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            key = f"{arch}|{shape}|{'multi' if mp else 'single'}" \
                  + ("" if not args.no_ft else "|noft")
            if key in results and results[key].get("status") in ("ok",
                                                                 "skipped") \
                    and not args.force:
                print(f"[cached] {key}")
                continue
            try:
                # multi-pod pass proves the pod axis shards; the roofline
                # table (probes) is single-pod only per the assignment
                results[key] = run_cell(arch, shape, multi_pod=mp,
                                        ft_on=not args.no_ft,
                                        probes=not mp)
            except Exception as e:          # noqa: BLE001 — record & continue
                traceback.print_exc()
                results[key] = {"status": "error", "error": str(e)[:2000]}
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors → {args.out}")


if __name__ == "__main__":
    main()
