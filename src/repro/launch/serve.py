"""Serving launcher: batched generation with the FT-protected decode path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b-smoke \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.core.policy import ONLINE_BLOCK, FT_OFF
from repro.models import model_zoo
from repro.train import serve as serve_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--no-ft", action="store_true")
    args = ap.parse_args()

    if args.arch.endswith("-smoke"):
        cfg = registry.get_smoke(args.arch[:-len("-smoke")])
    else:
        cfg = registry.get_config(args.arch)
    run = RunConfig(model=cfg, ft=FT_OFF if args.no_ft else ONLINE_BLOCK,
                    dtype="float32", attn_chunk=64)
    mod = model_zoo.module_for(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "vlm":
        extra = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        extra = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_audio_frames, cfg.d_model)), jnp.float32)
    sc = serve_lib.ServeConfig(max_len=args.max_len,
                               temperature=args.temperature)
    t0 = time.time()
    out = serve_lib.generate(params, prompts, cfg, run, sc,
                             max_new_tokens=args.new_tokens, extra=extra)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
