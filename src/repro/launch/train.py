"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b-smoke \
        --steps 200 --batch 8 --seq 128 [--ckpt-dir /tmp/ckpt --resume]

On a real TPU slice this same entry point runs under
`jax.distributed.initialize()` with the production mesh; on CPU it runs the
smoke-size configs (full configs are exercised via dryrun.py only).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.policy import ONLINE_BLOCK, FT_OFF
from repro.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="arch id (append '-smoke' for the reduced config)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--no-ft", action="store_true")
    ap.add_argument("--inject-every", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if args.arch.endswith("-smoke"):
        cfg = registry.get_smoke(args.arch[:-len("-smoke")])
    else:
        cfg = registry.get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, ft=FT_OFF if args.no_ft else ONLINE_BLOCK,
                    learning_rate=args.lr, microbatch=args.microbatch,
                    attn_chunk=min(128, args.seq))
    tc = train_loop.TrainConfig(
        total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
        ckpt_every=args.ckpt_every, inject_every=args.inject_every,
        compress_grads=args.compress_grads)
    out = train_loop.train(cfg, run, shape, tc, ckpt_dir=args.ckpt_dir,
                           resume=args.resume)
    print(f"finished at step {out['final_step']}; "
          f"final loss {out['history'][-1]['loss']:.4f}; "
          f"stragglers {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
