"""Host-side metrics sink: the step-boundary consumer of the per-site FT
telemetry (`core.telemetry`) plus ordinary run metrics.

One `MetricsSink` per run. The training/serving loop calls:

    sink.record_ft(report, step=step)        # a materialized FTReport
    sink.count("tokens", n)                  # monotonic counters
    sink.gauge("step_time_s", dt)            # last-value gauges
    sink.histogram("max_residual", x)        # log2-bucketed histograms
    sink.step_end(step)                      # flush one JSON record

`step_end` emits ONE record per step to every attached emitter:

    {"step": int, "t": float,
     "gauges": {...}, "counters": {...}, "deltas": {...},
     "hists": {name: {"<=2^k": count, ...}},
     "ft": {"detected": float, "corrected": float, "max_residual": float},
     "ft_sites": [{"site","layer","detected","corrected","max_residual"}],
     "alerts": [{"site","step","rate",...}]}

Emitters are pluggable and trivially small — `JsonlEmitter` (the file the
analysis tooling reads, `tools/report.py --metrics`), `MemoryEmitter`
(tests), `StdoutEmitter` (interactive runs). A custom emitter is any object
with ``emit(record: dict)`` (and optionally ``close()``).

The sink owns a `core.telemetry.StormDetector` and feeds it every step's
per-site detection counts; fired `StormAlert`s are attached to the step
record and forwarded to callbacks registered via `sink.on_storm(cb)` — the
subscription point for the adaptive-FT policy arc.

Everything here is host-side pure Python: the sink never sees tracers, only
materialized per-step reports, so it adds zero ops (and zero pallas
launches) to the compiled step — `benchmarks/telemetry_overhead.py` gates
that claim.
"""
from __future__ import annotations

import json
import math
import sys
import time
from typing import Any, Callable, Dict, IO, List, Optional

from repro.core import telemetry


# ---------------------------------------------------------------------------
# emitters
# ---------------------------------------------------------------------------


class JsonlEmitter:
    """One JSON object per line. The canonical on-disk format the report
    tooling consumes."""

    def __init__(self, path: str):
        self.path = path
        self._f: Optional[IO[str]] = open(path, "a")

    def emit(self, record: Dict[str, Any]) -> None:
        assert self._f is not None, "emitter closed"
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class MemoryEmitter:
    """Keeps records in a list — test assertions read `.records`."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def emit(self, record: Dict[str, Any]) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass


class StdoutEmitter:
    """Compact one-line-per-step summary for interactive runs."""

    def __init__(self, stream: Optional[IO[str]] = None):
        self.stream = stream or sys.stdout

    def emit(self, record: Dict[str, Any]) -> None:
        ft = record.get("ft") or {}
        parts = [f"step {record.get('step')}"]
        for k, v in (record.get("gauges") or {}).items():
            parts.append(f"{k} {v:.4g}" if isinstance(v, float) else f"{k} {v}")
        if ft:
            parts.append(f"sdc_det {ft['detected']:.0f}"
                         f" sdc_fix {ft['corrected']:.0f}")
        for a in record.get("alerts") or ():
            parts.append(f"[SDC-STORM {a['site']} rate={a['rate']:.3g}/step]")
        print(" ".join(parts), file=self.stream)

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# sink
# ---------------------------------------------------------------------------


def _log2_bucket(x: float) -> str:
    """Histogram bucket label: power-of-two upper edge ("<=2^k"), with
    dedicated buckets for zero and non-finite values."""
    if x != x or x in (float("inf"), float("-inf")):
        return "nonfinite"
    if x == 0.0:
        return "0"
    return f"<=2^{math.ceil(math.log2(abs(x)))}"


class MetricsSink:
    """Step-boundary metrics aggregator with pluggable emitters.

    Counters are cumulative across the run; each step record also carries
    the per-step `deltas`. Gauges are last-value-wins within a step.
    Histograms accumulate log2-bucket counts across the run (distributions
    like per-site max-residual magnitudes — what a calibrated fault model
    fits against).
    """

    def __init__(self, emitters: Optional[List[Any]] = None,
                 detector: Optional[telemetry.StormDetector] = None,
                 clock: Callable[[], float] = time.time):
        self.emitters = list(emitters) if emitters else []
        self.detector = detector or telemetry.StormDetector()
        self._clock = clock
        self._counters: Dict[str, float] = {}
        self._prev_counters: Dict[str, float] = {}
        self._gauges: Dict[str, Any] = {}
        self._hists: Dict[str, Dict[str, int]] = {}
        self._ft_totals: Optional[Dict[str, float]] = None
        self._ft_sites: List[Dict[str, Any]] = []
        self._alerts: List[telemetry.StormAlert] = []
        self.detector.on_alert(self._alerts.append)

    # -- producers ---------------------------------------------------------

    def count(self, name: str, value: float = 1.0) -> None:
        self._counters[name] = self._counters.get(name, 0.0) + float(value)

    def gauge(self, name: str, value: Any) -> None:
        self._gauges[name] = (float(value) if isinstance(value, (int, float))
                              else value)

    def histogram(self, name: str, value: float) -> None:
        h = self._hists.setdefault(name, {})
        b = _log2_bucket(float(value))
        h[b] = h.get(b, 0) + 1

    def record_ft(self, report: telemetry.FTReport, *, step: int) -> None:
        """Consume one step's materialized FTReport: site rows decode
        against the registry labels, totals become counters, residual
        magnitudes feed the histogram, and the per-site detection counts
        feed the storm detector (alerts attach to this step's record)."""
        det = float(report.detected)
        cor = float(report.corrected)
        mr = float(report.max_residual)
        self._ft_totals = {"detected": det, "corrected": cor,
                           "max_residual": mr}
        self.count("sdc_detected", det)
        self.count("sdc_corrected", cor)
        if mr > 0.0:
            self.histogram("ft_max_residual", mr)
        rows = telemetry.site_rows(report)
        self._ft_sites = rows
        site_counts: Dict[str, float] = {}
        for r in rows:
            site_counts[r["site"]] = (site_counts.get(r["site"], 0.0)
                                      + r["detected"])
            if r["max_residual"] > 0.0:
                self.histogram(f"ft_max_residual/{r['site']}",
                               r["max_residual"])
        self.detector.observe(step, site_counts)

    def on_storm(self, cb: Callable[[telemetry.StormAlert], None]) -> None:
        self.detector.on_alert(cb)

    # -- step boundary -----------------------------------------------------

    def step_end(self, step: int, **gauges: Any) -> Dict[str, Any]:
        """Flush one step record to every emitter (and return it)."""
        for k, v in gauges.items():
            self.gauge(k, v)
        deltas = {k: v - self._prev_counters.get(k, 0.0)
                  for k, v in self._counters.items()}
        record: Dict[str, Any] = {
            "step": int(step),
            "t": self._clock(),
            "gauges": dict(self._gauges),
            "counters": dict(self._counters),
            "deltas": deltas,
            "hists": {k: dict(v) for k, v in self._hists.items()},
        }
        if self._ft_totals is not None:
            record["ft"] = dict(self._ft_totals)
            record["ft_sites"] = list(self._ft_sites)
        if self._alerts:
            record["alerts"] = [vars(a) for a in self._alerts]
        for e in self.emitters:
            e.emit(record)
        self._prev_counters = dict(self._counters)
        self._gauges = {}
        self._ft_totals = None
        self._ft_sites = []
        # in-place: the detector callback holds a reference to this list
        # (`on_alert(self._alerts.append)`) — rebinding would orphan it.
        self._alerts.clear()
        return record

    def close(self) -> None:
        for e in self.emitters:
            close = getattr(e, "close", None)
            if close:
                close()


# ---------------------------------------------------------------------------
# JSONL analysis helpers (tools/report.py uses these)
# ---------------------------------------------------------------------------


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def aggregate_sites(records: List[Dict[str, Any]]) -> Dict[str, Dict[str, float]]:
    """Fold a run's step records into per-site totals:
    {site: {detected, corrected, max_residual, steps_seen}}. Layer rows of
    the same site are summed together (the per-layer split stays available
    in the raw records)."""
    agg: Dict[str, Dict[str, float]] = {}
    for rec in records:
        for row in rec.get("ft_sites") or ():
            a = agg.setdefault(row["site"], {"detected": 0.0,
                                             "corrected": 0.0,
                                             "max_residual": 0.0,
                                             "steps_seen": 0.0})
            a["detected"] += row["detected"]
            a["corrected"] += row["corrected"]
            a["max_residual"] = max(a["max_residual"], row["max_residual"])
            a["steps_seen"] += 1.0
    return agg
