"""Thin tracing-span API over `jax.profiler`.

Two kinds of spans, one import site:

  * `span(name)` — host-side wall-clock span (`jax.profiler.TraceAnnotation`
    when a profiler trace is active; otherwise a no-op-cost context). Wraps
    train-step *phases* in the host loop: data load, step dispatch,
    checkpoint, metrics flush.
  * `traced_span(name)` — trace-time annotation (`jax.named_scope`): names a
    region of the jaxpr so kernel dispatches are attributable in
    Perfetto/XLA profiles. Wraps the kernel-dispatch entry points
    (`kernels.ops`, `kernels.flashft`, `kernels.grouped.dispatch`).

  * `trace_dump(dir)` — capture a Perfetto-compatible profiler trace of the
    enclosed block (`jax.profiler.start_trace`/`stop_trace`);
    `benchmarks/run.py --trace-dir` wraps suites with it.

All three degrade gracefully: if the running jax build lacks a profiler
symbol, spans become plain no-op contexts rather than failing the run.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable, Iterator

import jax


@contextlib.contextmanager
def _noop() -> Iterator[None]:
    yield


def span(name: str):
    """Host-side span around a step phase (shows as a named slice on the
    host track of a profiler trace)."""
    ann = getattr(jax.profiler, "TraceAnnotation", None)
    return ann(name) if ann is not None else _noop()


def traced_span(name: str):
    """Trace-time span: names the enclosed jaxpr region (device track)."""
    ns = getattr(jax, "named_scope", None)
    return ns(name) if ns is not None else _noop()


def traced(name: str) -> Callable:
    """Decorator form of `traced_span` — the kernel dispatch entry points
    wear this so every pallas launch shows up under a stable name in
    Perfetto/XLA profiles."""
    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with traced_span(name):
                return fn(*args, **kwargs)
        return wrapper
    return deco


@contextlib.contextmanager
def trace_dump(log_dir: str) -> Iterator[None]:
    """Capture a Perfetto-compatible profiler trace of the enclosed block
    into `log_dir` (open with ui.perfetto.dev or TensorBoard's profile
    plugin)."""
    start = getattr(jax.profiler, "start_trace", None)
    stop = getattr(jax.profiler, "stop_trace", None)
    if start is None or stop is None:
        yield
        return
    start(log_dir)
    try:
        yield
    finally:
        stop()
