"""Three-term roofline analysis from compiled dry-run artifacts.

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). Collective bytes
are NOT in cost_analysis — we parse the post-SPMD HLO (compiled.as_text())
and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

Note on units: with XLA SPMD, cost_analysis and the partitioned module are
**per-device**, so dividing by `chips` again would double-count; we therefore
use per-device quantities directly against per-chip peak rates (numerically
identical to the assignment's global-total formulation).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (3D-torus links assumed usable one axis at a time, conservative).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
LINK_BW = 50e9              # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %x = bf16[16,512,128]{2,1,0} all-gather(...)   (tuple results are
# handled exclusively by _TUPLE_RE — no leading "(" allowed here)
_OP_RE = re.compile(
    r"=\s([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")[\(\.]")
# tuple-result collectives:  %x = (f32[4,8]{1,0}, f32[4,8]{1,0}) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^()]+)\)\s*(" + "|".join(_COLLECTIVES) + r")\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum per-device result bytes of every collective op in partitioned HLO.
    Returns (total, per-op-kind breakdown)."""
    per: Dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        per[kind] = per.get(kind, 0) + _bytes_of(dtype, dims)
    for m in _TUPLE_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        for sm in _SHAPE_RE.finditer(shapes):
            per[kind] = per.get(kind, 0) + _bytes_of(sm.group(1), sm.group(2))
    return sum(per.values()), per


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device
    model_flops: float          # useful (6·N_active·D), per device
    coll_breakdown: Dict[str, int]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap model: step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modelled step
        time: (useful FLOPs / step_time) / peak."""
        if self.step_time_s == 0:
            return 0.0
        return (self.model_flops / self.step_time_s) / PEAK_FLOPS

    def to_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes, "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
            "coll_breakdown": self.coll_breakdown,
        }


def cost_dict(compiled) -> Dict:
    """`compiled.cost_analysis()` normalized across jax versions: some
    return a flat dict, others a one-element list of dicts."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def kernel_time_s(flops: float, hbm_bytes: float,
                  peak_flops: float = PEAK_FLOPS,
                  hbm_bw: float = HBM_BW) -> float:
    """Single-kernel roofline: perfect-overlap time for a kernel that
    executes `flops` and moves `hbm_bytes` through HBM. This is the
    analytical scoring model the autotuner (`kernels.search`) falls back to
    when candidates cannot be timed on hardware — same constants as the
    whole-model roofline above, so benchmark and tuner numbers agree."""
    return max(flops / peak_flops, hbm_bytes / hbm_bw)


def analyze(cost: Dict, hlo_text: str, model_flops_per_device: float
            ) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes, breakdown = collective_bytes(hlo_text)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cbytes / LINK_BW,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=float(cbytes),
        model_flops=model_flops_per_device,
        coll_breakdown=breakdown,
    )
