"""Protection audit: walk a jaxpr and account every GEMM's FLOPs.

The end-to-end claim of the backward-FT work (PR 4) is structural: a train
step on the pallas FT backend contains **no large `dot_general` outside
registry-emitted kernels** — every GEMM above a size threshold runs inside a
`pallas_call` (where online ABFT is fused with the MACs) or not at all.
FT-BLAS's argument is that fault tolerance must cover every BLAS call on the
critical path to claim end-to-end protection; this module is the mechanized
version of that audit for our jaxprs, used by

  * `tests/test_backward_ft.py::test_protection_audit_*` — the regression
    gate (zero unprotected large dot_generals for a dense and a MoE
    optimizer step);
  * `benchmarks/backward_path.py` — the before/after fraction of train-step
    GEMM FLOPs running under in-kernel ABFT.

Accounting model: the walk recurses into every sub-jaxpr (custom_vjp calls,
remat/checkpoint, scan/while/cond bodies, jit calls) EXCEPT the kernel body
of a `pallas_call` — dot_generals there are the registry-emitted MACs and
checksum GEMVs, classified as "kernel". Loop trip counts are not multiplied
in (the audit is structural, not a cost model): a dot_general inside a
scanned layer counts once, which is exactly what the zero-unprotected gate
needs, and close enough for the benchmark's fraction when layers are
homogeneous.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class DotRecord:
    """One dot_general occurrence: FLOPs, operand shapes, and whether it
    sits inside a pallas_call kernel body ("kernel") or in open XLA code
    ("open")."""
    flops: float
    lhs_shape: Tuple[int, ...]
    rhs_shape: Tuple[int, ...]
    where: str                 # "kernel" | "open"
    primitive: str = "dot_general"


def _dot_flops(eqn) -> Tuple[float, Tuple[int, ...], Tuple[int, ...]]:
    """2 · batch · M · N · K FLOPs of one dot_general eqn from its operand
    avals and dimension_numbers (any rank, any batching)."""
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = np.prod([lhs.shape[d] for d in lb], dtype=np.float64) if lb else 1.0
    contract = (np.prod([lhs.shape[d] for d in lc], dtype=np.float64)
                if lc else 1.0)
    lhs_free = np.prod([s for d, s in enumerate(lhs.shape)
                        if d not in lc and d not in lb], dtype=np.float64)
    rhs_free = np.prod([s for d, s in enumerate(rhs.shape)
                        if d not in rc and d not in rb], dtype=np.float64)
    return (2.0 * batch * contract * lhs_free * rhs_free,
            tuple(lhs.shape), tuple(rhs.shape))


def _sub_jaxprs(params: dict):
    """Yield every jaxpr stored in an eqn's params (call_jaxpr, branches,
    scan/while bodies, custom_vjp fwd/bwd thunks, …)."""
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if isinstance(item, jax.extend.core.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jax.extend.core.Jaxpr):
                yield item


def collect_dots(jaxpr, _in_kernel: bool = False) -> List[DotRecord]:
    """Every dot_general in `jaxpr` (recursively), tagged by whether it is
    inside a pallas_call kernel body. Accepts a ClosedJaxpr or Jaxpr."""
    if isinstance(jaxpr, jax.extend.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out: List[DotRecord] = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops, ls, rs = _dot_flops(eqn)
            out.append(DotRecord(flops, ls, rs,
                                 "kernel" if _in_kernel else "open"))
            continue
        kernelish = _in_kernel or name == "pallas_call"
        for sub in _sub_jaxprs(eqn.params):
            out.extend(collect_dots(sub, _in_kernel=kernelish))
    return out


def count_primitives(fn, *args, primitive: str = "pallas_call",
                     **make_jaxpr_kwargs) -> int:
    """Count call-site occurrences of `primitive` in `fn(*args)`'s jaxpr,
    recursing through every sub-jaxpr. Unlike `str(jaxpr).count(...)`, this
    counts each *call site*: the printer let-binds repeated identical
    sub-jaxprs once, so string counts undercount launches."""
    jaxpr = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)

    def walk(j) -> int:
        if isinstance(j, jax.extend.core.ClosedJaxpr):
            j = j.jaxpr
        c = 0
        for eqn in j.eqns:
            if eqn.primitive.name == primitive:
                c += 1
            for sub in _sub_jaxprs(eqn.params):
                c += walk(sub)
        return c

    return walk(jaxpr)


def pallas_call_names(fn, *args, **make_jaxpr_kwargs) -> List[str]:
    """Kernel names of every pallas_call site in `fn(*args)`'s jaxpr, in
    traversal order (recursing through every sub-jaxpr — custom_vjp
    branches, scan bodies, …). The name is the kernel body's function name
    (e.g. ``_flash_ft_kernel``, ``gemm_block_batched``), which is how tests
    assert that a campaign's jaxpr contains the kernels it claims to
    exercise — e.g. that a stochastic-injection attention step runs the
    flash kernels rather than silently falling back to the oracle."""
    jaxpr = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)
    names: List[str] = []

    def walk(j):
        if isinstance(j, jax.extend.core.ClosedJaxpr):
            j = j.jaxpr
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                info = eqn.params.get("name_and_src_info")
                names.append(getattr(info, "name", None)
                             or str(eqn.params.get("name", "")))
            for sub in _sub_jaxprs(eqn.params):
                walk(sub)

    walk(jaxpr)
    return names


def unprotected_dots(fn, *args, min_flops: float = 0.0,
                     **make_jaxpr_kwargs) -> List[DotRecord]:
    """Trace `fn(*args)` and return the open (outside-kernel) dot_generals
    with FLOPs ≥ `min_flops` — the audit's violation list (empty = the step
    is fully covered by registry-emitted kernels above the threshold)."""
    jaxpr = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)
    return [d for d in collect_dots(jaxpr)
            if d.where == "open" and d.flops >= min_flops]


def flop_accounting(fn, *args, **make_jaxpr_kwargs) -> dict:
    """GEMM-FLOP accounting of `fn(*args)`'s jaxpr: total dot FLOPs inside
    pallas kernels vs in open XLA code, and the in-kernel fraction."""
    jaxpr = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)
    dots = collect_dots(jaxpr)
    kernel = sum(d.flops for d in dots if d.where == "kernel")
    open_ = sum(d.flops for d in dots if d.where == "open")
    total = kernel + open_
    return {
        "kernel_flops": kernel,
        "open_flops": open_,
        "total_flops": total,
        "kernel_fraction": kernel / total if total else 1.0,
        "n_kernel_dots": sum(1 for d in dots if d.where == "kernel"),
        "n_open_dots": sum(1 for d in dots if d.where == "open"),
        "records": dots,
    }
