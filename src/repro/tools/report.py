"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from
benchmarks/dryrun_results.json.

    PYTHONPATH=src python -m repro.tools.report [--json benchmarks/dryrun_results.json]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.2f}GiB"
    return f"{b/2**20:.1f}MiB"


def dryrun_table(results: Dict) -> str:
    rows = ["| arch | shape | mesh | status | compile s | mem/dev | "
            "raw flops/dev | notes |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        v = results[key]
        arch, shape, mesh = key.split("|")[:3]
        if v["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | {mesh} | SKIP | — | — | — | "
                        f"{v['reason'][:60]} |")
            continue
        if v["status"] != "ok":
            rows.append(f"| {arch} | {shape} | {mesh} | ERROR | — | — | — | "
                        f"{v.get('error','')[:60]} |")
            continue
        mem = v.get("memory", {})
        peak = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0))
        raw = v.get("cost_raw", {}).get("flops", 0)
        rows.append(
            f"| {arch} | {shape} | {v['mesh']} | OK | {v['compile_s']} | "
            f"{fmt_bytes(peak)} | {raw:.2e} | |")
    return "\n".join(rows)


def roofline_table(results: Dict) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL_FLOPs/dev | useful | roofline |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        v = results[key]
        if v.get("status") != "ok" or "roofline" not in v:
            continue
        if not key.endswith("|single"):
            continue
        arch, shape, _ = key.split("|")[:3]
        r = v["roofline"]
        rows.append(
            f"| {arch} | {shape} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def summarize(results: Dict) -> str:
    ok = sum(1 for v in results.values() if v.get("status") == "ok")
    skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    err = sum(1 for v in results.values() if v.get("status") == "error")
    return f"{ok} ok / {skip} skipped / {err} errors"


def perf_table(hc: Dict, baseline: Dict) -> str:
    """Hillclimb variants vs their cell's baseline."""
    rows = ["| variant | compute s | memory s | collective s | bottleneck | "
            "roofline | Δ dominant term | hypothesis → verdict |",
            "|---|---|---|---|---|---|---|---|"]
    cell_of = {"qwen2_train": "qwen2-7b|train_4k|single",
               "arctic_decode": "arctic-480b|decode_32k|single",
               "mamba2_train": "mamba2-780m|train_4k|single"}
    for key in sorted(hc):
        v = hc[key]
        if v.get("status") != "ok":
            rows.append(f"| {key} | — | — | — | ERROR | — | — | "
                        f"{v.get('error','')[:40]} |")
            continue
        r = v["roofline"]
        cell = cell_of.get(key.split("/")[0])
        base = baseline.get(cell, {}).get("roofline") if cell else None
        delta = ""
        verdict = ""
        if base:
            dom = base["bottleneck"]
            b0 = base[f"{dom}_s"]
            b1 = r[f"{dom}_s"]
            delta = f"{100 * (b1 / b0 - 1):+.1f}% ({dom})"
            verdict = "CONFIRMED" if b1 < b0 * 0.95 else (
                "~neutral" if b1 < b0 * 1.05 else "REFUTED")
        hypo = v.get("hypothesis", "")[:80]
        rows.append(
            f"| {key} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['bottleneck']} | "
            f"{r['roofline_fraction']:.3f} | {delta} | {hypo} → {verdict} |")
    return "\n".join(rows)


def ft_site_table(metrics_path: str, top_n: int = 10) -> str:
    """Per-site FT telemetry table from a metrics JSONL (the file a
    `tools.metrics.JsonlEmitter` writes): top-N sites by detection rate,
    with correction counts, worst residual, and any storm alerts."""
    from repro.tools import metrics as metrics_lib

    records = metrics_lib.read_jsonl(metrics_path)
    n_steps = max(1, len({r["step"] for r in records}))
    agg = metrics_lib.aggregate_sites(records)
    alerts: Dict[str, int] = {}
    for rec in records:
        for a in rec.get("alerts") or ():
            alerts[a["site"]] = alerts.get(a["site"], 0) + 1
    rows = ["| site | detections | det/step | corrected | max residual | "
            "storms |",
            "|---|---|---|---|---|---|"]
    ranked = sorted(agg.items(), key=lambda kv: -kv[1]["detected"])[:top_n]
    for site, a in ranked:
        rows.append(
            f"| {site} | {a['detected']:.0f} | "
            f"{a['detected'] / n_steps:.3f} | {a['corrected']:.0f} | "
            f"{a['max_residual']:.3g} | {alerts.get(site, 0)} |")
    if not ranked:
        rows.append("| (no detections recorded) | — | — | — | — | — |")
    return "\n".join(rows)


def policy_table(plan: Dict, metrics_path: str | None = None) -> str:
    """Resolved per-site FT plan table from an `FTPlan.to_json` dump
    (benchmarks/ft_plan.py writes one per config). With ``metrics_path``,
    each planned site's row is joined with the PR-8 per-site counters from
    the metrics JSONL, so the planned level sits next to what the level
    actually caught."""
    agg: Dict[str, Dict] = {}
    if metrics_path:
        from repro.tools import metrics as metrics_lib
        agg = metrics_lib.aggregate_sites(
            metrics_lib.read_jsonl(metrics_path))
    rows = ["| site | level | verify | GFLOPs | pred. overhead µs | "
            "detections | corrected |",
            "|---|---|---|---|---|---|---|"]
    for s in sorted(plan.get("sites", ()),
                    key=lambda s: -float(s.get("flops", 0.0))):
        a = agg.get(s["site"], {})
        det = f"{a['detected']:.0f}" if a else "—"
        cor = f"{a['corrected']:.0f}" if a else "—"
        rows.append(
            f"| {s['site']} | {s['action']} | {s['verify']} | "
            f"{s['flops'] / 1e9:.3f} | {s['overhead_s'] * 1e6:.2f} | "
            f"{det} | {cor} |")
    rows.append(
        f"\ncoverage {100 * plan.get('coverage', 0.0):.1f}% of site FLOPs, "
        f"predicted overhead {100 * plan.get('overhead_frac', 0.0):.2f}% "
        f"(budget {100 * plan.get('budget_frac', 0.0):.1f}%)")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="benchmarks/dryrun_results.json")
    ap.add_argument("--hillclimb", default="benchmarks/hillclimb_results.json")
    ap.add_argument("--metrics", default=None,
                    help="metrics JSONL (tools.metrics JsonlEmitter output) "
                         "— renders the per-site FT telemetry table")
    ap.add_argument("--policy", default=None,
                    help="FTPlan JSON (core.policy.FTPlan.to_json / "
                         "benchmarks/ft_plan.py output) — renders the "
                         "resolved per-site level table, joined with "
                         "--metrics counters when both are given")
    args = ap.parse_args()
    import os
    if args.policy:
        with open(args.policy) as f:
            plan = json.load(f)
        print("## Planned FT policy (resolved per-site levels)\n")
        print(policy_table(plan, args.metrics))
        if not args.metrics and not os.path.exists(args.json):
            return
        print()
    if args.metrics:
        print("## Per-site FT telemetry\n")
        print(ft_site_table(args.metrics))
        if not os.path.exists(args.json):
            return
        print()
    with open(args.json) as f:
        results = json.load(f)
    print("## Dry-run matrix\n")
    print(summarize(results) + "\n")
    print(dryrun_table(results))
    print("\n## Roofline (single-pod 16×16 = 256 chips)\n")
    print(roofline_table(results))
    if os.path.exists(args.hillclimb):
        with open(args.hillclimb) as f:
            hc = json.load(f)
        print("\n## Perf hillclimb\n")
        print(perf_table(hc, results))


if __name__ == "__main__":
    main()
