"""Mixture-of-Experts layer with two dispatch regimes.

Default (PR 3): **grouped ragged dispatch** — tokens are routed per
(token, slot) assignment and the expert FFN GEMMs run as
`core.ft_grouped_matmul` over a group-sorted token buffer (CSR-style, see
`kernels.grouped`): zero capacity padding, zero dropped tokens, and online
ABFT per expert group (an SEU in one expert's rows cannot contaminate a
neighbor). The only overhead over the ragged FLOP floor is ≤ E·(bm-1)
row-tile alignment rows — the moe_dispatch benchmark gates this at ≤1.25×.

Baseline (``MoEConfig.dispatch="padded"``): the GShard/Switch-style
capacity-based one-hot dispatch — expert-parallel over the "model" mesh
axis (GSPMD inserts the all-to-alls from the dispatch/combine einsums).
Kept as the comparison point: its dispatch einsums cost ≈ 4·E·C·d FLOPs per
token and every expert pads (and drops) to the same capacity C.

Design notes (DESIGN.md §4/§5):
  * dispatch/combine data movement is not ABFT-protected (memory-class
    faults are ECC-covered per the paper's fault model); expert FFN GEMMs
    are protected via ft-protected grouped/batched matmuls.
  * aux load-balance loss (Switch): E · Σ_e f_e · P_e — identical in both
    regimes.
  * the grouped path is shard-local today (tokens sharded over data axes);
    expert-parallel all-to-all for the grouped buffer is a ROADMAP item.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import (ft_batched_dot, ft_grouped_matmul_buffer,
                        grouped_row_tile)
from repro.configs.base import MoEConfig
from repro.distributed.sharding import shard
from repro.kernels.grouped import layout as glayout
from .blocks import Ctx, dense_init


def init_moe(key, d: int, mc: MoEConfig, n_layers: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    e, f = mc.n_experts, mc.expert_d_ff
    scale = 0.02
    down_scale = scale / (2 * n_layers) ** 0.5
    return {
        "router": dense_init(ks[0], d, e, jnp.float32, scale),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   * down_scale).astype(dtype),
    }


def capacity(group: int, mc: MoEConfig) -> int:
    c = max(1, -(-int(group * mc.top_k * mc.capacity_factor)
                 // mc.n_experts))
    # lane-align only when it doesn't dominate (decode groups are tiny and
    # a hard floor of 4 cost 32x dispatch waste at batch-128 decode — §Perf)
    return ((c + 3) // 4) * 4 if c >= 4 else c


def _group_geometry(b: int, s: int, mc: MoEConfig) -> int:
    """Pick the dispatch group size (padded regime). Groups are built by
    reshaping the (B, S) token grid, so group boundaries align with the
    (batch→data, seq→model) activation sharding: GSPMD then lowers the
    expert reshard as one all-to-all instead of a full rematerialization
    (the 'involuntary full remat' pathology the v0 baseline exhibited — see
    EXPERIMENTS §Perf). Prefer ≥16 groups along seq so the group dim can
    carry the model axis."""
    g = min(mc.group_size, b * s)
    if s >= 2:
        n_seq = s // g if g and s % g == 0 else 0
        if n_seq == 0 or (n_seq < 16 and s >= 16 and s % 16 == 0):
            g = max(s // 16, 1)
        if s % g != 0:
            g = s                       # ragged smoke shapes: 1 group/row
    else:
        g = min(g, b)
        if b % g != 0:
            g = b
    return g


def _routing(xt: jax.Array, router: jax.Array, mc: MoEConfig):
    """Shared router math. xt: (T, d) → (gate_vals (T, k), idx (T, k),
    aux loss). The aux loss is the Switch load-balance term E·Σ f_e·P_e."""
    e = mc.n_experts
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, mc.top_k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return gate_vals, idx, aux


def apply_moe(p: Dict[str, Any], x: jax.Array, mc: MoEConfig,
              ctx: Ctx) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss)."""
    if mc.dispatch == "padded":
        return apply_moe_padded(p, x, mc, ctx)
    if mc.dispatch != "grouped":
        raise ValueError(f"MoEConfig.dispatch must be 'grouped' or "
                         f"'padded', got {mc.dispatch!r}")
    return apply_moe_grouped(p, x, mc, ctx)


# ---------------------------------------------------------------------------
# grouped ragged dispatch (default) — zero capacity padding
# ---------------------------------------------------------------------------

def apply_moe_grouped(p: Dict[str, Any], x: jax.Array, mc: MoEConfig,
                      ctx: Ctx) -> Tuple[jax.Array, jax.Array]:
    """Route every (token, slot) assignment to its expert's ragged group and
    run the three expert-FFN GEMMs through the grouped FT path — one
    protected grouped kernel each on the pallas backend, the segment-
    checksum jnp path elsewhere. No capacity: nothing is padded to a
    per-expert quota and nothing is dropped.

    The routing decides ONE group layout, so the whole FFN stays in buffer
    space: scatter the assignment rows once, run gate/up/down on the
    group-sorted buffer (`ft_grouped_matmul_buffer` — the silu·up combine
    is elementwise, so dead buffer rows stay zero), gather once."""
    b, s, d = x.shape
    e, f = mc.n_experts, mc.expert_d_ff
    xt = shard(x, "batch", "seq", "embed").reshape(b * s, d)
    gate_vals, idx, aux = _routing(xt, p["router"], mc)
    t, k = idx.shape

    # One row per (token, slot) assignment, grouped by expert; one layout
    # and one scatter shared by all three GEMMs.
    expert_ids = idx.reshape(t * k)                          # (T·k,)
    rows = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)     # source token
    # The layout's row tile must match the level the first buffer GEMM will
    # resolve to — pass its site so a policy picks the same variant.
    bm = grouped_row_tile(t * k, f, d, x.dtype, e, ctx.ft, site="moe_gate")
    lay = glayout.make_layout(expert_ids, e, bm)
    buf = glayout.scatter_rows(xt[rows], lay)                # (t_buf, d)

    def ffn(name, a, w):
        return ft_grouped_matmul_buffer(a, w, lay.gid, lay.row_end,
                                        ft=ctx.ft, key=ctx.subkey(name),
                                        site=name)

    gate_h = ffn("moe_gate", buf, p["w_gate"])
    up_h = ffn("moe_up", buf, p["w_up"])
    h = (jax.nn.silu(gate_h.astype(jnp.float32))
         * up_h.astype(jnp.float32)).astype(x.dtype)
    y_buf = ffn("moe_down", h, p["w_down"])                  # (t_buf, d)
    ya = glayout.gather_rows(y_buf, lay)                     # (T·k, d)

    # Combine: weighted sum of each token's k slot outputs.
    y = jnp.sum(ya.reshape(t, k, d).astype(jnp.float32)
                * gate_vals[..., None], axis=1).astype(x.dtype)
    y = shard(y.reshape(b, s, d), "batch", "seq", "embed")
    return y, aux


# ---------------------------------------------------------------------------
# padded capacity dispatch (GShard baseline)
# ---------------------------------------------------------------------------

def apply_moe_padded(p: Dict[str, Any], x: jax.Array, mc: MoEConfig,
                     ctx: Ctx) -> Tuple[jax.Array, jax.Array]:
    """The capacity-based one-hot dispatch baseline: every expert is padded
    to the same capacity C (and overflow tokens are dropped)."""
    b, s, d = x.shape
    e = mc.n_experts
    g = _group_geometry(b, s, mc)
    n_grp = (b * s) // g
    # token-grid-aligned grouping: (B, S, d) → (B·S/g, g, d) keeps the
    # merged leading dim sharded over (pod, data[, model]) with no data
    # movement; see _group_geometry
    xg = x.reshape(n_grp, g, d)
    xg = shard(xg, "tokens", None, None)
    c = capacity(g, mc)

    # --- routing (f32, shared with the grouped path) ----------------------
    gate_vals, idx, aux = _routing(xg.reshape(-1, d), p["router"], mc)
    gate_vals = gate_vals.reshape(n_grp, g, mc.top_k)
    idx = idx.reshape(n_grp, g, mc.top_k)

    # --- capacity-bounded one-hot dispatch/combine tensors -----------------
    # position of each (token, k) within its expert queue
    combine = jnp.zeros((n_grp, g, e, c), jnp.float32)
    fill = jnp.zeros((n_grp, e), jnp.int32)
    for k in range(mc.top_k):
        oh = jax.nn.one_hot(idx[..., k], e, dtype=jnp.int32)   # (n, g, E)
        pos = fill[:, None, :] + jnp.cumsum(oh, axis=1) - oh   # (n, g, E)
        keep = (pos < c) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, c), c,
                                dtype=jnp.float32)             # (n, g, E, C)
        combine = combine + (pos_oh * oh[..., None]
                             * gate_vals[..., k][..., None, None])
        fill = fill + jnp.sum(oh, axis=1)

    dispatch = (combine > 0).astype(x.dtype)                   # (n, g, E, C)
    dispatch = shard(dispatch, "tokens", None, None, None)

    # --- dispatch → expert FFN (ABFT-protected) → combine -------------------
    # xe constrained (data, experts→model): GSPMD lowers the token→expert
    # reshard as one all-to-all over "model"
    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg)
    xe = shard(xe, "exp_tokens", "experts", None, None)
    xe2 = xe.transpose(1, 0, 2, 3).reshape(e, n_grp * c, d)
    gate_h = ft_batched_dot(xe2, p["w_gate"], ft=ctx.ft,
                            key=ctx.subkey("moe_gate"), site="moe_gate")
    up_h = ft_batched_dot(xe2, p["w_up"], ft=ctx.ft,
                          key=ctx.subkey("moe_up"), site="moe_up")
    yh = ft_batched_dot((jax.nn.silu(gate_h) * up_h).astype(x.dtype),
                        p["w_down"], ft=ctx.ft, key=ctx.subkey("moe_down"),
                        site="moe_down")
    ye = yh.reshape(e, n_grp, c, d).transpose(1, 0, 2, 3)      # (n, E, C, d)
    ye = shard(ye, "exp_tokens", "experts", None, None)
    y = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), ye)
    y = shard(y, "tokens", None, None)
    return y.reshape(b, s, d), aux
