"""GShard/Switch-style Mixture-of-Experts layer with capacity-based one-hot
dispatch — expert-parallel over the "model" mesh axis (GSPMD inserts the
all-to-alls from the dispatch/combine einsums).

Design notes (DESIGN.md §4/§5):
  * dispatch/combine one-hot einsums are *data movement*, not protected by
    ABFT (memory-class faults are ECC-covered per the paper's fault model);
    expert FFN GEMMs are protected via ft-protected grouped einsums.
  * `group_size` bounds the dispatch-einsum FLOPs overhead
    (≈ 4·E·C·d / (6·k·d·f) of the expert FLOPs, C ∝ group_size); it is a
    per-arch knob and a §Perf hillclimb lever.
  * aux load-balance loss (Switch): E · Σ_e f_e · P_e.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import ft_batched_dot
from repro.configs.base import MoEConfig
from repro.distributed.sharding import shard
from .blocks import Ctx, dense_init


def init_moe(key, d: int, mc: MoEConfig, n_layers: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    e, f = mc.n_experts, mc.expert_d_ff
    scale = 0.02
    down_scale = scale / (2 * n_layers) ** 0.5
    return {
        "router": dense_init(ks[0], d, e, jnp.float32, scale),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   * down_scale).astype(dtype),
    }


def capacity(group: int, mc: MoEConfig) -> int:
    c = max(1, -(-int(group * mc.top_k * mc.capacity_factor)
                 // mc.n_experts))
    # lane-align only when it doesn't dominate (decode groups are tiny and
    # a hard floor of 4 cost 32x dispatch waste at batch-128 decode — §Perf)
    return ((c + 3) // 4) * 4 if c >= 4 else c


def _group_geometry(b: int, s: int, mc: MoEConfig) -> int:
    """Pick the dispatch group size. Groups are built by reshaping the
    (B, S) token grid, so group boundaries align with the (batch→data,
    seq→model) activation sharding: GSPMD then lowers the expert reshard as
    one all-to-all instead of a full rematerialization (the 'involuntary
    full remat' pathology the v0 baseline exhibited — see EXPERIMENTS §Perf).
    Prefer ≥16 groups along seq so the group dim can carry the model axis."""
    g = min(mc.group_size, b * s)
    if s >= 2:
        n_seq = s // g if g and s % g == 0 else 0
        if n_seq == 0 or (n_seq < 16 and s >= 16 and s % 16 == 0):
            g = max(s // 16, 1)
        if s % g != 0:
            g = s                       # ragged smoke shapes: 1 group/row
    else:
        g = min(g, b)
        if b % g != 0:
            g = b
    return g


def apply_moe(p: Dict[str, Any], x: jax.Array, mc: MoEConfig,
              ctx: Ctx) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss)."""
    b, s, d = x.shape
    e = mc.n_experts
    g = _group_geometry(b, s, mc)
    n_grp = (b * s) // g
    # token-grid-aligned grouping: (B, S, d) → (B·S/g, g, d) keeps the
    # merged leading dim sharded over (pod, data[, model]) with no data
    # movement; see _group_geometry
    xg = x.reshape(n_grp, g, d)
    xg = shard(xg, "tokens", None, None)
    c = capacity(g, mc)

    # --- routing (f32) ----------------------------------------------------
    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, mc.top_k)          # (n, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # aux load-balance loss: fraction routed vs mean prob (Switch eq. 4)
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    onehot_top1 = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # --- capacity-bounded one-hot dispatch/combine tensors -----------------
    # position of each (token, k) within its expert queue
    combine = jnp.zeros((n_grp, g, e, c), jnp.float32)
    fill = jnp.zeros((n_grp, e), jnp.int32)
    for k in range(mc.top_k):
        oh = jax.nn.one_hot(idx[..., k], e, dtype=jnp.int32)   # (n, g, E)
        pos = fill[:, None, :] + jnp.cumsum(oh, axis=1) - oh   # (n, g, E)
        keep = (pos < c) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, c), c,
                                dtype=jnp.float32)             # (n, g, E, C)
        combine = combine + (pos_oh * oh[..., None]
                             * gate_vals[..., k][..., None, None])
        fill = fill + jnp.sum(oh, axis=1)

    dispatch = (combine > 0).astype(x.dtype)                   # (n, g, E, C)
    dispatch = shard(dispatch, "tokens", None, None, None)

    # --- dispatch → expert FFN (ABFT-protected) → combine -------------------
    # xe constrained (data, experts→model): GSPMD lowers the token→expert
    # reshard as one all-to-all over "model"
    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg)
    xe = shard(xe, "exp_tokens", "experts", None, None)
    xe2 = xe.transpose(1, 0, 2, 3).reshape(e, n_grp * c, d)
    gate_h = ft_batched_dot(xe2, p["w_gate"], ft=ctx.ft,
                            key=ctx.subkey("moe_gate"))
    up_h = ft_batched_dot(xe2, p["w_up"], ft=ctx.ft, key=ctx.subkey("moe_up"))
    yh = ft_batched_dot((jax.nn.silu(gate_h) * up_h).astype(x.dtype),
                        p["w_down"], ft=ctx.ft, key=ctx.subkey("moe_down"))
    ye = yh.reshape(e, n_grp, c, d).transpose(1, 0, 2, 3)      # (n, E, C, d)
    ye = shard(ye, "exp_tokens", "experts", None, None)
    y = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), ye)
    y = shard(y, "tokens", None, None)
    return y.reshape(b, s, d), aux
