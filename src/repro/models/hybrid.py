"""Zamba2-style hybrid: a Mamba-2 backbone with a **shared** attention block
applied every `attn_every` SSM blocks (arXiv:2411.15242).

Simplification vs. the HF checkpoint (noted in DESIGN.md): the shared block
reuses identical weights at every application (Zamba2 adds per-application
LoRA adapters on top of the shared weights — an orthogonal detail).

Structure: n_layers mamba blocks in `n_groups = n_layers // attn_every`
groups; after each group the shared transformer block (attention + MLP)
runs. Decode keeps 54 SSM states + one KV cache per shared-block application.
The shared block's training attention rides `blocks.chunked_attention` and
therefore the flashft kernel on the pallas FT backend (PR 4).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import telemetry
from repro.core import loops
from repro.distributed.sharding import shard
from . import blocks as B
from . import mamba2 as M
from .blocks import Ctx, rmsnorm


def n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k_emb, k_blocks, k_shared, k_head = jax.random.split(key, 4)
    ng = n_groups(cfg)
    keys = jax.random.split(k_blocks, ng * cfg.attn_every
                            ).reshape(ng, cfg.attn_every, 2)

    def one(k):
        return {"ssm": M.init_block(k, cfg, dtype),
                "pre_norm": jnp.ones((cfg.d_model,), jnp.float32)}

    inner = jax.vmap(jax.vmap(one))(keys)
    ks1, ks2 = jax.random.split(k_shared)
    v = cfg.padded_vocab()
    return {
        "embed": {"table": B.embed_init(k_emb, v, cfg.d_model, dtype)},
        "groups": {"inner": inner},
        "shared": {
            "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": B.init_attention(ks1, cfg, dtype),
            "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": B.init_mlp(ks2, cfg.d_model, cfg.d_ff, cfg.n_layers,
                              dtype),
        },
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": {"table": B.dense_init(k_head, cfg.d_model, v, dtype)},
    }


def _shared_block(sp, x, cfg, ctx: Ctx, chunk: int):
    h = rmsnorm(x, sp["attn_norm"], cfg.norm_eps)
    x = x + B.attention(sp["attn"], h, cfg, ctx, causal=True, chunk=chunk)
    h = rmsnorm(x, sp["ffn_norm"], cfg.norm_eps)
    return x + B.mlp(sp["mlp"], h, ctx)


def forward(params, tokens, cfg: ModelConfig, ctx: Ctx, *, remat=True,
            chunk: int = 512, extra_embeds=None):
    x = B.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    x = shard(x, "batch", "seq", "embed")
    shared = params["shared"]
    ng = n_groups(cfg)

    def mamba_fn(lp, h, idx):
        lctx = ctx.fold(idx)
        return telemetry.scoped(
            lambda: h + M.apply_block(lp["ssm"],
                                      rmsnorm(h, lp["pre_norm"],
                                              cfg.norm_eps),
                                      cfg, lctx))

    mamba_fn_ck = B.make_remat(mamba_fn, remat)

    def group_fn(carry, scanned):
        h, rep = carry
        gp, gidx = scanned

        def inner_body(cc, s):
            hh, rr = cc
            lp, idx = s
            lnum = gidx * cfg.attn_every + idx
            hh, rep_l = mamba_fn_ck(lp, hh, lnum)
            return (hh, rr.merge_at(rep_l, lnum + 1)), None

        (h, rep), _ = loops.scan(inner_body, (h, rep),
                                   (gp, jnp.arange(cfg.attn_every)))

        def shared_fn(hh, gi):
            return telemetry.scoped(
                lambda: _shared_block(shared, hh, cfg, ctx.fold(1000 + gi),
                                      chunk))

        sb = B.make_remat(shared_fn, remat)
        h, rep_s = sb(h, gidx)
        # Shared attention blocks get rows after all mamba layers.
        return (h, rep.merge_at(rep_s, 1 + ng * cfg.attn_every + gidx)), None

    (x, rep), _ = loops.scan(
        group_fn,
        (x, telemetry.FTReport.empty(rows=1 + ng * (cfg.attn_every + 1))),
        (params["groups"]["inner"], jnp.arange(ng)))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits, rep_h = telemetry.scoped(
        lambda: ctx.dot("lm_head", x, params["head"]["table"]))
    ctx.check_inject_sites()
    from .transformer import AuxOut
    return logits, AuxOut(jnp.zeros((), jnp.float32), rep.merge(rep_h))


def loss_fn(params, batch, cfg: ModelConfig, ctx: Ctx, *, remat=True,
            chunk: int = 512):
    logits, aux = forward(params, batch["tokens"], cfg, ctx, remat=remat,
                          chunk=chunk)
    ce = B.cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": aux.balance, "ft": aux.ft}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, **_) -> Dict[str, Any]:
    ng = n_groups(cfg)
    state = M.init_state(cfg, batch)
    kv_shape = (ng, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "ssm": jnp.zeros((cfg.n_layers,) + state["ssm"].shape, jnp.float32),
        "conv": jnp.zeros((cfg.n_layers,) + state["conv"].shape,
                          jnp.bfloat16),
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def _shard_cache(cache):
    for key in ("k", "v"):
        cache[key] = shard(cache[key], None, "batch", "kv_seq", "kv_heads",
                           None)
    cache["ssm"] = shard(cache["ssm"], None, "batch", "state", None, None)
    return cache


def decode_step(params, token, cache, cfg: ModelConfig, ctx: Ctx):
    cache = _shard_cache(dict(cache))
    x = B.embed(token, params["embed"]["table"]).astype(ctx.dtype)
    bsz = token.shape[0]
    ng = n_groups(cfg)
    ae = cfg.attn_every
    pos = cache["length"]
    shared = params["shared"]
    ssm = cache["ssm"].reshape((ng, ae) + cache["ssm"].shape[1:])
    conv = cache["conv"].reshape((ng, ae) + cache["conv"].shape[1:])

    # Serve-path telemetry gate, like transformer.decode_step: per-layer
    # scoping only when the caller opened an ft_scope. Row layout matches
    # forward: mamba layer lnum → row 1 + lnum, shared application gidx →
    # row 1 + ng·ae + gidx.
    want_ft = telemetry.current_scope() is not None

    def group_body(carry, scanned):
        h, rep = carry
        gp, ssm_g, conv_g, k_g, v_g, gidx = scanned

        def mamba_step(lp, hh, ssm_s, conv_s, idx):
            lctx = ctx.fold(gidx * ae + idx)
            out, ns = M.decode_block(
                lp["ssm"], rmsnorm(hh, lp["pre_norm"], cfg.norm_eps),
                {"ssm": ssm_s, "conv": conv_s}, cfg, lctx)
            return hh + out, (ns["ssm"], ns["conv"])

        def inner_body(cc, s):
            hh, rr = cc
            lp, ssm_s, conv_s, idx = s
            if want_ft:
                (hh, st), rep_l = telemetry.scoped(
                    lambda: mamba_step(lp, hh, ssm_s, conv_s, idx))
                rr = rr.merge_at(rep_l, gidx * ae + idx + 1)
            else:
                hh, st = mamba_step(lp, hh, ssm_s, conv_s, idx)
            return (hh, rr), st

        (h, rep), (ssm_new, conv_new) = loops.scan(
            inner_body, (h, rep), (gp, ssm_g, conv_g, jnp.arange(ae)))

        def shared_step(h, k_g, v_g):
            # shared attention block (single-token step vs this group's KV)
            lctx = ctx.fold(1000 + gidx)
            hn = rmsnorm(h, shared["attn_norm"], cfg.norm_eps)
            q = lctx.dot("wq", hn, shared["attn"]["wq"])
            k_new = lctx.dot("wk", hn, shared["attn"]["wk"])
            v_new = lctx.dot("wv", hn, shared["attn"]["wv"])
            q = q.reshape(bsz, 1, cfg.n_heads, cfg.head_dim)
            k_new = k_new.reshape(bsz, 1, cfg.n_kv_heads, cfg.head_dim)
            v_new = v_new.reshape(bsz, 1, cfg.n_kv_heads, cfg.head_dim)
            q = B.apply_rope(q, pos[:, None], cfg.rope_theta)
            k_new = B.apply_rope(k_new, pos[:, None], cfg.rope_theta)
            oh = jax.nn.one_hot(pos, k_g.shape[1], dtype=k_g.dtype)
            k_g = k_g + oh[:, :, None, None] * k_new
            v_g = v_g + oh[:, :, None, None] * v_new
            att = B.decode_attention(q, k_g, v_g, pos + 1, lctx)
            h = h + lctx.dot("wo", att.reshape(bsz, 1, -1),
                             shared["attn"]["wo"])
            hn = rmsnorm(h, shared["ffn_norm"], cfg.norm_eps)
            h = h + B.mlp(shared["mlp"], hn, lctx)
            return h, (k_g, v_g)

        if want_ft:
            (h, (k_g, v_g)), rep_s = telemetry.scoped(
                lambda: shared_step(h, k_g, v_g))
            rep = rep.merge_at(rep_s, 1 + ng * ae + gidx)
        else:
            h, (k_g, v_g) = shared_step(h, k_g, v_g)
        return (h, rep), (ssm_new, conv_new, k_g, v_g)

    (x, rep), (ssm_n, conv_n, k_n, v_n) = loops.scan(
        group_body,
        (x, telemetry.FTReport.empty(rows=1 + ng * (ae + 1))),
        (params["groups"]["inner"], ssm, conv, cache["k"], cache["v"],
         jnp.arange(ng)))
    if want_ft:
        telemetry.record_report(rep)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = ctx.dot("lm_head", x, params["head"]["table"])
    new_cache = {
        "ssm": ssm_n.reshape(cache["ssm"].shape),
        "conv": conv_n.reshape(cache["conv"].shape),
        "k": k_n, "v": v_n,
        "length": cache["length"] + 1,
    }
    return logits, _shard_cache(new_cache)


def prefill(params, tokens, cache, cfg: ModelConfig, ctx: Ctx, *,
            chunk: int = 512, remat: bool = True):
    """Prompt pass: run forward once per token chunk is overkill here; we
    reuse forward for logits and rebuild caches by a single pass collecting
    per-group KV + final SSM states."""
    cache = _shard_cache(dict(cache))
    x = B.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    bsz, s = tokens.shape
    ng = n_groups(cfg)
    ae = cfg.attn_every
    shared = params["shared"]
    positions = jnp.arange(s)
    sc = cfg.ssm
    d_inner, h_heads, n, g = M.dims(cfg)

    def mamba_prefill(lp, hh, idx):
        lctx = ctx.fold(idx)
        p = lp["ssm"]
        hidden = rmsnorm(hh, lp["pre_norm"], cfg.norm_eps)
        zxbcdt = lctx.dot("in_proj", hidden, p["in_proj"])
        z, xx, b_mat, c_mat, dt = M._split_proj(zxbcdt, cfg)
        xbc = jnp.concatenate([xx, b_mat, c_mat], axis=-1)
        conv_tail = xbc[:, -(sc.conv_width - 1):, :].astype(jnp.bfloat16)
        xbc = jax.nn.silu(M._causal_conv(xbc, p["conv_w"], p["conv_b"]))
        xx, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + g * n], -1)
        xx = xx.reshape(bsz, s, h_heads, sc.head_dim)
        b_mat = b_mat.reshape(bsz, s, g, n)
        c_mat = c_mat.reshape(bsz, s, g, n)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        a = -jnp.exp(p["A_log"])
        y, h_last = M.ssd_chunked(xx, dt, a, b_mat, c_mat, p["D"], sc, lctx)
        y = y.reshape(bsz, s, d_inner)
        y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    p["norm_w"], cfg.norm_eps)
        return hh + lctx.dot("out_proj", y, p["out_proj"]), \
            (h_last, conv_tail)

    # Same telemetry gate as decode_step; scoping sits INSIDE the remat
    # wrappers (records cannot cross a checkpoint region), row layout
    # matches forward.
    want_ft = telemetry.current_scope() is not None

    def mamba_wrapped(lp, hh, lnum):
        return telemetry.scoped(lambda: mamba_prefill(lp, hh, lnum))

    mamba_prefill_ck = B.make_remat(
        mamba_wrapped if want_ft else mamba_prefill, remat)

    def group_body(carry, scanned):
        h, rep = carry
        gp, gidx = scanned

        def inner_body(cc, sc_):
            hh, rr = cc
            lp, idx = sc_
            lnum = gidx * ae + idx
            if want_ft:
                (hh, st), rep_l = mamba_prefill_ck(lp, hh, lnum)
                rr = rr.merge_at(rep_l, lnum + 1)
            else:
                hh, st = mamba_prefill_ck(lp, hh, lnum)
            return (hh, rr), st

        (h, rep), (ssm_g, conv_g) = loops.scan(inner_body, (h, rep),
                                               (gp, jnp.arange(ae)))

        def shared_step(h):
            lctx = ctx.fold(1000 + gidx)
            hn = rmsnorm(h, shared["attn_norm"], cfg.norm_eps)
            q = lctx.dot("wq", hn, shared["attn"]["wq"])
            k = lctx.dot("wk", hn, shared["attn"]["wk"])
            v = lctx.dot("wv", hn, shared["attn"]["wv"])
            q = q.reshape(bsz, s, cfg.n_heads, cfg.head_dim)
            k = k.reshape(bsz, s, cfg.n_kv_heads, cfg.head_dim)
            v = v.reshape(bsz, s, cfg.n_kv_heads, cfg.head_dim)
            q = B.apply_rope(q, positions, cfg.rope_theta)
            k = B.apply_rope(k, positions, cfg.rope_theta)
            att = B.chunked_attention(q, k, v, causal=True, chunk=chunk,
                                      ctx=lctx)
            h = h + lctx.dot("wo", att.reshape(bsz, s, -1),
                             shared["attn"]["wo"])
            hn = rmsnorm(h, shared["ffn_norm"], cfg.norm_eps)
            h = h + B.mlp(shared["mlp"], hn, lctx)
            return h, (k, v)

        if want_ft:
            (h, (k, v)), rep_s = telemetry.scoped(lambda: shared_step(h))
            rep = rep.merge_at(rep_s, 1 + ng * ae + gidx)
        else:
            h, (k, v) = shared_step(h)
        return (h, rep), (ssm_g, conv_g, k, v)

    (x, rep), (ssm_s, conv_s, ks, vs) = loops.scan(
        group_body, (x, telemetry.FTReport.empty(rows=1 + ng * (ae + 1))),
        (params["groups"]["inner"], jnp.arange(ng)))
    if want_ft:
        telemetry.record_report(rep)
    max_len = cache["k"].shape[2]
    pad = max_len - s
    k_full = jnp.pad(ks.astype(cache["k"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_full = jnp.pad(vs.astype(cache["v"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = ctx.dot("lm_head", x, params["head"]["table"])[:, 0]
    new_cache = {
        "ssm": ssm_s.reshape(cache["ssm"].shape),
        "conv": conv_s.reshape(cache["conv"].shape),
        "k": k_full, "v": v_full,
        "length": jnp.full((bsz,), s, jnp.int32),
    }
    return logits, _shard_cache(new_cache)
