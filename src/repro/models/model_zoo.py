"""Architecture dispatch + ShapeDtypeStruct input specs.

`module_for(cfg)` returns the family module exposing the uniform interface:
    init(cfg, key, dtype)                         → params
    forward(params, tokens, cfg, ctx, …)          → (logits, aux)
    loss_fn(params, batch, cfg, ctx, …)           → (loss, metrics)
    init_cache(cfg, batch, max_len, dtype)        → cache
    prefill(params, tokens, cache, cfg, ctx, …)   → (logits, cache)
    decode_step(params, token, cache, cfg, ctx)   → (logits, cache)

`input_specs(cfg, shape, kind)` builds weak-type-correct ShapeDtypeStruct
stand-ins for every model input — the dry-run lowers against these without
allocating anything (multi-pod requirement #2).
"""
from __future__ import annotations

from types import ModuleType
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import hybrid, mamba2, transformer, whisper


def module_for(cfg: ModelConfig) -> ModuleType:
    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,     # phi3-vision = backbone + patch stub inputs
        "ssm": mamba2,
        "hybrid": hybrid,
        "encdec": whisper,
    }[cfg.family]


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic attention (assignment rule)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      batch: int = None) -> Dict[str, Any]:
    b = batch if batch is not None else shape.global_batch
    s = shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return specs


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig,
                  batch: int = None) -> Dict[str, Any]:
    b = batch if batch is not None else shape.global_batch
    s = shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 batch: int = None) -> Dict[str, Any]:
    b = batch if batch is not None else shape.global_batch
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, batch: int = None,
                dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct pytree matching init_cache (for decode dry-runs).
    VLM caches cover the prepended patch positions too."""
    b = batch if batch is not None else shape.global_batch
    max_len = shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)
    mod = module_for(cfg)
    return jax.eval_shape(
        lambda: mod.init_cache(cfg, b, max_len, dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, kind: str = None,
                batch: int = None) -> Dict[str, Any]:
    kind = kind or shape.kind
    if kind == "train":
        return train_batch_specs(cfg, shape, batch)
    if kind == "prefill":
        return prefill_specs(cfg, shape, batch)
    if kind == "decode":
        return decode_specs(cfg, shape, batch)
    raise ValueError(kind)


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS = 6·N·D with N = active params (MoE: routed top-k only) —
    the §Roofline useful-compute yardstick."""
    d, l = cfg.d_model, cfg.n_layers
    qd, kvd = cfg.qkv_dims
    attn = d * (qd + 2 * kvd) + qd * d
    if cfg.moe is not None:
        ffn = 3 * d * cfg.moe.expert_d_ff * cfg.moe.top_k
        ffn += 3 * d * cfg.moe.dense_d_ff
        ffn += d * cfg.moe.n_experts          # router
    elif cfg.family == "ssm":
        d_inner = cfg.ssm.expand * d
        g, n = cfg.ssm.n_groups, cfg.ssm.state
        ffn = d * (2 * d_inner + 2 * g * n + d_inner // cfg.ssm.head_dim) \
            + d_inner * d
        attn = 0
    elif cfg.family == "encdec":
        ffn = 2 * d * cfg.d_ff
        attn = attn * 2                        # self + cross
    else:
        ffn = 3 * d * cfg.d_ff
    if cfg.family == "hybrid":
        d_inner = cfg.ssm.expand * d
        g, n = cfg.ssm.n_groups, cfg.ssm.state
        ssm_p = d * (2 * d_inner + 2 * g * n + d_inner // cfg.ssm.head_dim) \
            + d_inner * d
        ng = l // cfg.attn_every
        active = l * ssm_p + ng * (attn + 3 * d * cfg.d_ff)
    else:
        layers = l + (cfg.enc_layers if cfg.family == "encdec" else 0)
        active = layers * (attn + ffn)
    active += 2 * cfg.padded_vocab() * d       # embed + head
    return 6.0 * active
