"""Whisper-style encoder-decoder (arXiv:2212.04356) — transformer backbone
only; the conv/mel audio frontend is a STUB per the assignment:
`input_specs()` supplies precomputed frame embeddings (B, n_audio_frames, d).

Faithful structure: bidirectional encoder over audio frames (sinusoidal
positions), causal decoder with learned positions, per-layer cross-attention
into the encoder output, GELU MLPs. Norm is RMSNorm (simplification vs.
LayerNorm — noted in DESIGN.md).

Both the causal decoder self-attention and the non-causal cross-attention
(decoder queries over 1500 audio-frame KVs — the cross-length case) route
through `blocks.chunked_attention`, i.e. since PR 4 the `kernels.flashft`
kernel on the pallas FT backend; the chunked-jnp scan stays available as
the oracle behind `Ctx.attn_impl="chunked"`.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import telemetry
from repro.core import loops
from repro.distributed.sharding import shard
from . import blocks as B
from .blocks import Ctx, rmsnorm

MAX_DEC_POS = 65_536   # covers decode_32k


def _sinusoid(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def init_gelu_mlp(key, d: int, d_ff: int, n_layers: int, dtype):
    k1, k2 = jax.random.split(key)
    return {"w1": B.dense_init(k1, d, d_ff, dtype),
            "w2": B.dense_init(k2, d_ff, d, dtype,
                               scale=0.02 / (2 * n_layers) ** 0.5)}


def gelu_mlp(p, x, ctx: Ctx):
    h = ctx.dot_fused("w1", x, p["w1"], act="gelu")  # fused epilogue spec
    return ctx.dot("w2", h, p["w2"])


def _init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": B.init_attention(k1, cfg, dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, cfg.n_layers, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": B.init_attention(k1, cfg, dtype),
        "cross_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "cross": B.init_attention(k2, cfg, dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, cfg.n_layers, dtype),
    }


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k_emb, k_enc, k_dec, k_head, k_pos = jax.random.split(key, 5)
    v = cfg.padded_vocab()
    enc_keys = jax.random.split(k_enc, cfg.enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": {"table": B.embed_init(k_emb, v, cfg.d_model, dtype)},
        "dec_pos": (jax.random.normal(k_pos, (MAX_DEC_POS, cfg.d_model),
                                      jnp.float32) * 0.01).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype)
                               )(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype)
                               )(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": {"table": B.dense_init(k_head, cfg.d_model, v, dtype)},
    }


def encode(params, frames: jax.Array, cfg: ModelConfig, ctx: Ctx, *,
           remat: bool = True, chunk: int = 512) -> jax.Array:
    """frames: (B, T_a, d) precomputed embeddings (conv-frontend stub)."""
    x = frames.astype(ctx.dtype) + _sinusoid(frames.shape[1], cfg.d_model
                                             ).astype(ctx.dtype)
    x = shard(x, "batch", "seq", "embed")

    def layer_fn(lp, h, idx):
        def inner():
            lctx = ctx.fold(idx)
            hn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
            h2 = h + B.attention(lp["attn"], hn, cfg, lctx, causal=False,
                                 chunk=chunk)
            hn = rmsnorm(h2, lp["ffn_norm"], cfg.norm_eps)
            return h2 + gelu_mlp(lp["mlp"], hn, lctx)
        return telemetry.scoped(inner)

    fn = B.make_remat(layer_fn, remat)

    def body(carry, scanned):
        h, rep = carry
        lp, idx = scanned
        h, rep_l = fn(lp, h, idx)
        return (h, rep.merge_at(rep_l, idx + 1)), None

    (x, rep), _ = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=cfg.enc_layers + 1)),
        (params["enc_layers"], jnp.arange(cfg.enc_layers)))
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps), rep


def _dec_layer(lp, h, enc_out, cfg, ctx: Ctx, chunk: int):
    hn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
    h = h + B.attention(lp["attn"], hn, cfg, ctx, causal=True, chunk=chunk)
    hn = rmsnorm(h, lp["cross_norm"], cfg.norm_eps)
    h = h + B.attention(lp["cross"], hn, cfg, ctx, causal=False,
                        kv=enc_out, chunk=chunk)
    hn = rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
    return h + gelu_mlp(lp["mlp"], hn, ctx)


def forward(params, batch_or_tokens, cfg: ModelConfig, ctx: Ctx, *,
            remat: bool = True, chunk: int = 512, frames=None,
            extra_embeds=None):
    """tokens (B, S) + frames (B, T_a, d) → (logits, aux)."""
    tokens = batch_or_tokens
    enc_out, rep = encode(params, frames, cfg, ctx, remat=remat, chunk=chunk)
    x = B.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    x = x + params["dec_pos"][:tokens.shape[1]].astype(ctx.dtype)
    x = shard(x, "batch", "seq", "embed")

    def layer_fn(lp, h, idx):
        return telemetry.scoped(
            lambda: _dec_layer(lp, h, enc_out, cfg, ctx.fold(100 + idx),
                               chunk))

    fn = B.make_remat(layer_fn, remat)

    # Decoder layers get their own rows after the encoder's (row
    # 1 + enc_layers + idx), so (layer, site) stays unambiguous across the
    # two stacks; the carried encoder report is pre-expanded to the final
    # row count (scan carries must be shape-invariant).
    rep = rep.expand_rows(1 + cfg.enc_layers + cfg.n_layers)

    def body(carry, scanned):
        h, rr = carry
        lp, idx = scanned
        h, rep_l = fn(lp, h, idx)
        return (h, rr.merge_at(rep_l, 1 + cfg.enc_layers + idx)), None

    (x, rep), _ = loops.scan(body, (x, rep),
                               (params["dec_layers"],
                                jnp.arange(cfg.n_layers)))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits, rep_h = telemetry.scoped(
        lambda: ctx.dot("lm_head", x, params["head"]["table"]))
    ctx.check_inject_sites()
    from .transformer import AuxOut
    return logits, AuxOut(jnp.zeros((), jnp.float32), rep.merge(rep_h))


def loss_fn(params, batch, cfg: ModelConfig, ctx: Ctx, *, remat=True,
            chunk: int = 512):
    logits, aux = forward(params, batch["tokens"], cfg, ctx, remat=remat,
                          chunk=chunk, frames=batch["frames"])
    ce = B.cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": aux.balance, "ft": aux.ft}


# ---------------------------------------------------------------------------
# serving: cross-KV computed at prefill; self-KV cache grows per step
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, **_) -> Dict[str, Any]:
    kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    xkv = (cfg.n_layers, batch, cfg.n_audio_frames, cfg.n_kv_heads,
           cfg.head_dim)
    return {
        "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
        "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, tokens, cache, cfg: ModelConfig, ctx: Ctx, *,
            frames=None, chunk: int = 512, remat: bool = True):
    """Encode audio, pre-compute cross-KV, run the decoder prompt."""
    bsz, s = tokens.shape
    enc_out, enc_rep = encode(params, frames, cfg, ctx, remat=remat,
                              chunk=chunk)
    x = B.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    x = x + params["dec_pos"][:s].astype(ctx.dtype)
    positions = jnp.arange(s)

    def layer_fn(lp, h, idx):
        lctx = ctx.fold(100 + idx)
        hn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q = lctx.dot("wq", hn, lp["attn"]["wq"])
        k = lctx.dot("wk", hn, lp["attn"]["wk"])
        v = lctx.dot("wv", hn, lp["attn"]["wv"])
        q = q.reshape(bsz, s, cfg.n_heads, cfg.head_dim)
        k = k.reshape(bsz, s, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(bsz, s, cfg.n_kv_heads, cfg.head_dim)
        q = B.apply_rope(q, positions, cfg.rope_theta)
        k = B.apply_rope(k, positions, cfg.rope_theta)
        att = B.chunked_attention(q, k, v, causal=True, chunk=chunk,
                                  ctx=lctx)
        h = h + lctx.dot("wo", att.reshape(bsz, s, -1), lp["attn"]["wo"])
        # cross attention + its cacheable KV
        hn = rmsnorm(h, lp["cross_norm"], cfg.norm_eps)
        xk = lctx.dot("xwk", enc_out, lp["cross"]["wk"])
        xv = lctx.dot("xwv", enc_out, lp["cross"]["wv"])
        ta = enc_out.shape[1]
        xk4 = xk.reshape(bsz, ta, cfg.n_kv_heads, cfg.head_dim)
        xv4 = xv.reshape(bsz, ta, cfg.n_kv_heads, cfg.head_dim)
        qx = lctx.dot("xwq", hn, lp["cross"]["wq"]
                      ).reshape(bsz, s, cfg.n_heads, cfg.head_dim)
        attx = B.chunked_attention(qx, xk4, xv4, causal=False, chunk=chunk,
                                   ctx=lctx)
        h = h + lctx.dot("xwo", attx.reshape(bsz, s, -1), lp["cross"]["wo"])
        hn = rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
        h = h + gelu_mlp(lp["mlp"], hn, lctx)
        return h, (k, v, xk4, xv4)

    # Serve-path telemetry gate, like transformer.prefill: per-layer scoping
    # only when the caller opened an ft_scope, INSIDE the remat wrapper.
    # Row layout matches forward (encoder rows 1..enc_layers from the
    # encode() report, decoder layer idx at row 1 + enc_layers + idx).
    want_ft = telemetry.current_scope() is not None

    def wrapped(lp, h, idx):
        return telemetry.scoped(lambda: layer_fn(lp, h, idx))

    fn = B.make_remat(wrapped if want_ft else layer_fn, remat)
    rep0 = enc_rep.expand_rows(1 + cfg.enc_layers + cfg.n_layers)

    def body(carry, scanned):
        h, rr = carry
        lp, idx = scanned
        if want_ft:
            (h, kv), rep_l = fn(lp, h, idx)
            rr = rr.merge_at(rep_l, 1 + cfg.enc_layers + idx)
        else:
            h, kv = fn(lp, h, idx)
        return (h, rr), kv

    (x, rep), (ks, vs, xks, xvs) = loops.scan(
        body, (x, rep0), (params["dec_layers"], jnp.arange(cfg.n_layers)))
    if want_ft:
        telemetry.record_report(rep)
    max_len = cache["k"].shape[2]
    pad = max_len - s
    k_full = jnp.pad(ks.astype(cache["k"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_full = jnp.pad(vs.astype(cache["v"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = ctx.dot("lm_head", x, params["head"]["table"])[:, 0]
    new_cache = {"k": k_full, "v": v_full, "xk": xks, "xv": xvs,
                 "length": jnp.full((bsz,), s, jnp.int32)}
    return logits, new_cache


def decode_step(params, token, cache, cfg: ModelConfig, ctx: Ctx):
    x = B.embed(token, params["embed"]["table"]).astype(ctx.dtype)
    bsz = token.shape[0]
    pos = cache["length"]
    x = x + jnp.take(params["dec_pos"], pos, axis=0)[:, None, :
                                                     ].astype(ctx.dtype)

    def layer_fn(lp, h, k_c, v_c, xk_c, xv_c, idx):
        lctx = ctx.fold(100 + idx)
        hn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q = lctx.dot("wq", hn, lp["attn"]["wq"])
        k_new = lctx.dot("wk", hn, lp["attn"]["wk"])
        v_new = lctx.dot("wv", hn, lp["attn"]["wv"])
        q = q.reshape(bsz, 1, cfg.n_heads, cfg.head_dim)
        k_new = k_new.reshape(bsz, 1, cfg.n_kv_heads, cfg.head_dim)
        v_new = v_new.reshape(bsz, 1, cfg.n_kv_heads, cfg.head_dim)
        q = B.apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = B.apply_rope(k_new, pos[:, None], cfg.rope_theta)
        oh = jax.nn.one_hot(pos, k_c.shape[1], dtype=k_c.dtype)
        k_c = k_c + oh[:, :, None, None] * k_new
        v_c = v_c + oh[:, :, None, None] * v_new
        att = B.decode_attention(q, k_c, v_c, pos + 1, lctx)
        h = h + lctx.dot("wo", att.reshape(bsz, 1, -1), lp["attn"]["wo"])
        hn = rmsnorm(h, lp["cross_norm"], cfg.norm_eps)
        qx = lctx.dot("xwq", hn, lp["cross"]["wq"]
                      ).reshape(bsz, 1, cfg.n_heads, cfg.head_dim)
        ta = xk_c.shape[1]
        # Cross-attention over the cached encoder KV is its own site
        # population ("xdec_*"): full 1500-frame KV span every step, priced
        # separately from the growing self-attention cache ("dec_*").
        attx = B.decode_attention(qx, xk_c, xv_c,
                                  jnp.full((bsz,), ta, jnp.int32), lctx,
                                  site_prefix="xdec")
        h = h + lctx.dot("xwo", attx.reshape(bsz, 1, -1), lp["cross"]["wo"])
        hn = rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
        h = h + gelu_mlp(lp["mlp"], hn, lctx)
        return h, (k_c, v_c)

    # Serve-path telemetry gate, like transformer.decode_step: decoder layer
    # idx records at row 1 + enc_layers + idx (forward's layout — encoder
    # rows stay zero, no encoder work happens in a decode step).
    want_ft = telemetry.current_scope() is not None
    rows = 1 + cfg.enc_layers + cfg.n_layers

    def body(carry, scanned):
        h, rep = carry
        lp, k_c, v_c, xk_c, xv_c, idx = scanned
        if want_ft:
            (h, kv), rep_l = telemetry.scoped(
                lambda: layer_fn(lp, h, k_c, v_c, xk_c, xv_c, idx))
            rep = rep.merge_at(rep_l, 1 + cfg.enc_layers + idx)
        else:
            h, kv = layer_fn(lp, h, k_c, v_c, xk_c, xv_c, idx)
        return (h, rep), kv

    (x, rep), (k_n, v_n) = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=rows)),
        (params["dec_layers"], cache["k"], cache["v"],
         cache["xk"], cache["xv"], jnp.arange(cfg.n_layers)))
    if want_ft:
        telemetry.record_report(rep)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = ctx.dot("lm_head", x, params["head"]["table"])
    new_cache = {"k": k_n, "v": v_n, "xk": cache["xk"], "xv": cache["xv"],
                 "length": cache["length"] + 1}
    return logits, new_cache
