"""Shared model building blocks. Every GEMM routes through repro.core.ft_dot
/ ft_batched_dot so the paper's online ABFT protects the full model.

Conventions:
  * params are nested dicts of jnp arrays (pure-functional modules);
  * `Ctx` carries the FT policy + per-step injection key + compute dtype;
    call sites derive deterministic sub-keys from their name (crc32) so an
    injection campaign exercises every GEMM in the model;
  * training/prefill attention: on the pallas FT backend the core runs the
    `kernels.flashft` ragged-causal kernel (PR 4) — ONE Pallas launch with
    both in-kernel GEMMs ABFT-protected, no O(chunk × S) score transient in
    the forward, GQA served through the K/V index maps (KV never
    repeat-materialized). Since PR 5 the backward is first-class too: the
    forward saves the per-row (m, l) softmax statistics and the backward
    runs the dedicated dQ and dK/dV flash kernels (four ABFT-protected
    backward GEMMs, zero chunked-oracle recompute); stochastic
    `ft.inject_rate` campaigns ride the in-kernel SEU hook in both
    directions. Elsewhere (and under ``Ctx.attn_impl="chunked"``) the
    flash-style query-chunked scan runs end to end — O(chunk × S) transient
    memory, never materializing S×S, in both directions. Required for the
    32k prefill shapes.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ft_dot, ft_dot_fused, ft_batched_dot, telemetry
from repro.core import loops
from repro.core.ft_gemm import _float0
from repro.core.policy import (FTConfig, FTLike, FT_OFF, note_site,
                               resolve_ft)


def named_subkey(key: Optional[jax.Array], name: str) -> Optional[jax.Array]:
    """THE per-call-site key derivation (crc32 of the site name) — shared
    by `Ctx.subkey` and the ctx-free attention cores so every GEMM of an
    injection campaign sees the same deterministic sub-key either way."""
    if key is None:
        return None
    return jax.random.fold_in(key, zlib.crc32(name.encode()))


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Per-call context: FT policy, injection key, activation dtype,
    attention sharding scheme ("heads" = Megatron-SP head-TP inside the
    attention core with seq gathered per layer; "none" = leave placement to
    GSPMD propagation — a §Perf comparison axis).

    ``attn_impl`` selects the training/prefill attention core: "auto"
    (default — the flashft kernel when the FT backend is pallas and the
    geometry is eligible, the chunked scan otherwise), "flash" (force the
    kernel), or "chunked" (force the query-chunked jnp path — the oracle
    the flash path is validated against).

    ``inject_sites`` restricts the stochastic SEU campaign to the named
    telemetry sites: `subkey` returns None (⇒ no injection) for every other
    site, so a campaign can target e.g. one MoE expert GEMM and the per-site
    report must attribute every detection to exactly that site. The site
    *names* are the same labels `dot`/`dot_fused`/`bdot` record telemetry
    under ("wq", "w_gate", "attn_qk", …; the flash kernel is one fused site,
    "attn_flash"). None (default) = campaign covers every GEMM. Call
    `check_inject_sites` once per traced forward to fail loudly on labels
    the registry never saw (a filter that silently matches nothing would
    report a clean run AS the campaign result).

    ``ft`` is either a plain `FTConfig` (uniform — legacy behavior,
    bit-identical) or an `FTPolicy` (PR 10): every GEMM resolves its own
    site label through `ft_for`, so one model trace can mix e.g.
    correct/step on `moe_*` with detect/final on `attn_*` and off on the
    rest."""
    ft: FTLike = FT_OFF
    key: Optional[jax.Array] = None
    dtype: Any = jnp.bfloat16
    attn_shard: str = "heads"
    attn_impl: str = "auto"
    inject_sites: Optional[Tuple[str, ...]] = None

    def ft_for(self, name: Optional[str]) -> FTConfig:
        """THE per-site resolution point on the model side: the site's
        `FTConfig` under this context's policy (identity for a bare
        FTConfig)."""
        return resolve_ft(self.ft, name)

    def site_allowed(self, name: str) -> bool:
        return self.inject_sites is None or name in self.inject_sites

    def check_inject_sites(self) -> None:
        """Validate ``inject_sites`` against the telemetry site registry —
        call at the END of a traced forward (every site has registered by
        then) and raise on labels no GEMM records under, instead of a
        campaign that silently injects nothing (the PR-5 out-of-grid
        failure mode, at the filter layer)."""
        if self.inject_sites is None:
            return
        known = set(telemetry.site_labels())
        unknown = sorted(set(self.inject_sites) - known)
        if unknown:
            raise ValueError(
                f"Ctx.inject_sites names unknown telemetry sites "
                f"{unknown}: no GEMM in this model records under them, so "
                f"the campaign would inject nothing. Known sites: "
                f"{sorted(known)}")

    def subkey(self, name: str) -> Optional[jax.Array]:
        if not self.site_allowed(name):
            return None
        return named_subkey(self.key, name)

    def dot(self, name: str, x: jax.Array, w: jax.Array) -> jax.Array:
        return ft_dot(x, w, ft=self.ft_for(name), key=self.subkey(name),
                      site=name)

    def dot_fused(self, name: str, x: jax.Array, w: jax.Array,
                  bias: Optional[jax.Array] = None,
                  act: Optional[str] = None) -> jax.Array:
        """Projection with a fused epilogue spec: y = act(x @ w + bias) as
        one kernel-level op (no separate bias/activation passes — see
        repro.core.ft_dot_fused / the kernels.templates subsystem)."""
        return ft_dot_fused(x, w, bias=bias, act=act, ft=self.ft_for(name),
                            key=self.subkey(name), site=name)

    def bdot(self, name: str, a: jax.Array, b: jax.Array) -> jax.Array:
        ft = self.ft_for(name)
        ft = ft if ft.protect_attention else FT_OFF
        return ft_batched_dot(a, b, ft=ft, key=self.subkey(name), site=name)

    def fold(self, tag: int) -> "Ctx":
        if self.key is None:
            return self
        return dataclasses.replace(self, key=jax.random.fold_in(self.key, tag))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def make_remat(fn, remat):
    """Remat-policy dispatch (a §Perf lever):
      False/"none" — no remat (saves everything, max memory, min recompute)
      True/"full"  — jax.checkpoint default (saves inputs only)
      "dots"       — save GEMM outputs, recompute elementwise only
                     (jax.checkpoint_policies.checkpoint_dots…): trades
                     activation memory for ~⅓ less recompute FLOPs."""
    if not remat or remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# normalization / rope
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)
            ).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (…, S, dh/2)
    if angles.ndim == 2:                                # (S, dh/2) → (1,S,1,·)
        angles = angles[None, :, None, :]
    else:                                               # (B,S,dh/2) → (B,S,1,·)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    qd, kvd = cfg.qkv_dims
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, qd, dtype),
        "wk": dense_init(ks[1], d, kvd, dtype),
        "wv": dense_init(ks[2], d, kvd, dtype),
        "wo": dense_init(ks[3], qd, d, dtype, scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, h, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, dh)
                            ).reshape(b, s, h * n_rep, dh)


def _chunked_core(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, chunk: int, ft: FTConfig,
                  key: Optional[jax.Array],
                  q_offset: int = 0,
                  inject_sites: Optional[Tuple[str, ...]] = None
                  ) -> Tuple[jax.Array, telemetry.FTReport]:
    """The query-chunked jnp attention core. q: (B,Sq,H,dh); k,v:
    (B,Sk,KVH,dh) → ((B,Sq,H,dh), FTReport). Never materializes (Sq, Sk)
    scores — per chunk only — and GQA is computed as a *grouped* batched
    matmul over (B, KVH) with the rep·chunk rows folded together: KV is
    never repeat-materialized (the v0 baseline paid n_rep× KV bytes;
    §Perf). This is BOTH the oracle the flashft path is validated against
    and the recompute body of the flash custom_vjp's backward — its GEMMs
    ride `ft_batched_dot`, so the attention backward stays ABFT-protected
    on every backend."""
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    n_rep = h // kvh
    scale = dh ** -0.5
    kT = jnp.swapaxes(k, 1, 2).swapaxes(2, 3)           # (B, KVH, dh, Sk)
    vT = jnp.swapaxes(v, 1, 2)                          # (B, KVH, Sk, dh)
    kpos = jnp.arange(sk)

    def subkey(name: str) -> Optional[jax.Array]:
        if inject_sites is not None and name not in inject_sites:
            return None
        return named_subkey(key, name)

    def chunk_fn(qc: jax.Array, qpos: jax.Array):
        # qc: (B, C, H, dh) → grouped scores (B, KVH, rep·C, Sk). FT records
        # are scoped inside the checkpointed body and re-emitted at the
        # caller's trace level (telemetry can't cross remat/scan as a side
        # channel).
        def inner():
            c = qc.shape[1]
            # (B, C, KVH, rep, dh) → (B, KVH, rep·C, dh)
            qg = qc.reshape(b, c, kvh, n_rep, dh).transpose(0, 2, 3, 1, 4)
            qg = qg.reshape(b, kvh, n_rep * c, dh)
            scores = ft_batched_dot(qg, kT, ft=ft, key=subkey("attn_qk"),
                                    site="attn_qk"
                                    ).astype(jnp.float32) * scale
            if causal:
                mask = qpos[:, None] >= kpos[None, :]   # (C, Sk)
                maskg = jnp.tile(mask, (n_rep, 1))      # (rep·C, Sk)
                scores = jnp.where(maskg[None, None], scores, -1e30)
            p = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
            out = ft_batched_dot(p, vT, ft=ft, key=subkey("attn_pv"),
                                 site="attn_pv")
            out = out.reshape(b, kvh, n_rep, c, dh).transpose(0, 3, 1, 2, 4)
            return out.reshape(b, c, h, dh)             # (B, C, H, dh)
        return telemetry.scoped(inner)

    chunk_fn = jax.checkpoint(chunk_fn)
    chunk = min(chunk, sq)
    if sq % chunk != 0:
        chunk = sq  # ragged smoke shapes — single chunk
    n_chunks = sq // chunk
    if n_chunks == 1:
        return chunk_fn(q, q_offset + jnp.arange(sq))

    qs = q.reshape(b, n_chunks, chunk, h, dh).swapaxes(0, 1)
    pos = (q_offset + jnp.arange(sq)).reshape(n_chunks, chunk)

    def body(rep, qp):
        qc, qpos = qp
        out, rep_c = chunk_fn(qc, qpos)
        return rep.merge(rep_c), out

    rep, outs = loops.scan(body, telemetry.FTReport.empty(), (qs, pos))
    return outs.swapaxes(0, 1).reshape(b, sq, h, dh), rep


# ---------------------------------------------------------------------------
# flashft-routed training attention (PR 4; dedicated kernel backward PR 5)
# ---------------------------------------------------------------------------

#: Trace-time switch (PR 5): True — the flash custom_vjp's backward runs the
#: dedicated dQ/dK/dV Pallas kernels over the forward-saved (m, l) softmax
#: statistics (zero chunked-oracle recompute, all four backward GEMMs under
#: in-kernel ABFT). False — the legacy PR-4 path: the backward recomputes
#: through the chunked-jnp oracle (protected batched kernels, but an
#: O(chunk·S) transient and one extra softmax pass). Kept for the
#: before/after benchmark and as an escape hatch.
FLASH_BWD_USE_KERNEL = True


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash_attn_cvjp(ft: FTConfig, causal, chunk, q_offset, q3, k3, v3, key):
    """Flash-kernel attention over head-major 3-D operands: q3 (B·H, Sq,
    dh); k3, v3 (B·KVH, Sk, dh). Forward = ONE `kernels.flashft` launch
    (both in-kernel GEMMs ABFT-protected per kv-step, GQA via the K/V index
    maps, no score transient); backward = the dedicated dQ and dK/dV flash
    kernels over the saved (m, l) statistics — no oracle recompute (see
    `FLASH_BWD_USE_KERNEL`). ``key`` drives the in-kernel stochastic SEU
    hook when ``ft.inject_rate > 0`` — campaigns stay on the kernel path in
    BOTH directions. Returns (out3, det, maxres)."""
    from repro.kernels import ops as kops
    n_rep = q3.shape[0] // k3.shape[0]
    out, rep = kops.flash_ft(q3, k3, v3, ft=ft, causal=causal, n_rep=n_rep,
                             key=key)
    det = jnp.sum(rep[..., 0]).astype(jnp.int32)
    maxres = jnp.max(rep[..., 5])
    return out, det, maxres


def _flash_attn_fwd(ft, causal, chunk, q_offset, q3, k3, v3, key):
    from repro.kernels import ops as kops
    n_rep = q3.shape[0] // k3.shape[0]
    if not FLASH_BWD_USE_KERNEL:
        out = _flash_attn_cvjp(ft, causal, chunk, q_offset, q3, k3, v3, key)
        return out, (q3, k3, v3, None, None, None, key)
    # Multi-output forward: the kernel additionally writes the per-row
    # softmax statistics (m, l) — the saved residual that lets the backward
    # run as dedicated kernels instead of recomputing the whole forward.
    out, m, l, rep = kops.flash_ft(q3, k3, v3, ft=ft, causal=causal,
                                   n_rep=n_rep, save_stats=True, key=key)
    det = jnp.sum(rep[..., 0]).astype(jnp.int32)
    maxres = jnp.max(rep[..., 5])
    return (out, det, maxres), (q3, k3, v3, out, m, l, key)


def _flash_attn_bwd(ft, causal, chunk, q_offset, res, cts):
    g3, _, _ = cts                     # ignore summary cotangents
    q3, k3, v3, o3, m, l, key = res
    bh, sq, dh = q3.shape
    bkvh, sk, _ = k3.shape
    n_rep = bh // bkvh
    if m is not None:
        # Dedicated flash backward (PR 5): TWO Pallas launches (dQ; dK/dV)
        # over the saved statistics + the elementwise di = rowsum(g ∘ o).
        # All four backward GEMMs (dP, dV, dQ, dK) and the in-kernel S
        # recompute carry the forward's checksum-verify + branchless
        # correction; the stochastic campaign key is folded so the backward
        # draws its own SEU stream.
        from repro.kernels import ops as kops
        kb = jax.random.fold_in(key, 0x5B) if key is not None else None
        dq, dk, dv, _, _ = kops.flash_ft_bwd(
            q3, k3, v3, o3, m, l, g3.astype(q3.dtype), ft=ft, causal=causal,
            n_rep=n_rep, key=kb)
        return dq, dk.astype(k3.dtype), dv.astype(v3.dtype), _float0(key)
    # Legacy (FLASH_BWD_USE_KERNEL=False): recompute through the chunked
    # oracle. Fold the GQA repetition into the head axis of a (B'=B·KVH,
    # H'=n_rep, KVH'=1) problem — row (b·KVH + kv)·n_rep + r of q3 is
    # exactly head r of batch b·KVH + kv, so the chunked oracle reproduces
    # the kernel's head→kv-head mapping and its vjp transposes it.
    q4 = q3.reshape(bkvh, n_rep, sq, dh).transpose(0, 2, 1, 3)
    k4 = k3[:, :, None, :]
    v4 = v3[:, :, None, :]

    def ref(q4, k4, v4):
        return _chunked_core(q4, k4, v4, causal=causal, chunk=chunk, ft=ft,
                             key=key, q_offset=q_offset)[0]

    _, vjp = jax.vjp(ref, q4, k4, v4)
    g4 = g3.reshape(bkvh, n_rep, sq, dh).transpose(0, 2, 1, 3)
    dq4, dk4, dv4 = vjp(g4.astype(q3.dtype))
    dq3 = dq4.transpose(0, 2, 1, 3).reshape(bh, sq, dh)
    return dq3, dk4[:, :, 0, :], dv4[:, :, 0, :], _float0(key)


_flash_attn_cvjp.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def _flash_attention(q, k, v, *, causal, chunk, ft, key, q_offset):
    """4-D front: (B,Sq,H,dh) × (B,Sk,KVH,dh) → (B,Sq,H,dh) through the
    flashft kernel, recording the FT summary at the caller's trace level
    (outside the custom_vjp boundary, like ft_dot — exactly once per call,
    even when the call is differentiated; backward-pass corrections are
    applied but not counted, per DESIGN.md)."""
    if ft.inject_rate > 0.0 and key is not None:
        from repro.kernels import flashft as _flashft
        if not _flashft.SUPPORTS_STOCHASTIC_INJECTION:
            # A fault campaign whose injections silently do not happen is
            # worse than a crash: it reports a clean run AS the campaign
            # result (the MPGemmFI injector/kernel-disagreement pitfall).
            raise ValueError(
                "flash attention cannot honor the stochastic injection key "
                f"(ft.inject_rate={ft.inject_rate}): this build's flashft "
                "kernels lack the in-kernel SEU hook. Use "
                "attn_impl='chunked' for the campaign instead of letting a "
                "forced flash path report a clean run.")
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    note_site("attn_flash", "flash", sq, sk, dh, batch=b * h,
              in_bytes=jnp.dtype(q.dtype).itemsize)
    q3 = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    k3 = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, dh)
    v3 = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, dh)
    out3, det, maxres = _flash_attn_cvjp(ft, causal, chunk, q_offset,
                                         q3, k3, v3, key)
    scope = telemetry.current_scope()
    if scope is not None:
        # One fused site: the kernel verifies both in-kernel GEMMs under a
        # single report, so qk/pv are not separable here.
        scope.record_summary(det, maxres, ft.corrects, site="attn_flash")
    return out3.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)


def _use_flash(ctx: Ctx, ft: FTConfig, causal: bool, sq: int, sk: int,
               q_offset: int) -> bool:
    """Resolve the attention core for this call site (see `Ctx.attn_impl`).
    The flash kernel's causal mask is bottom-right aligned on the true
    lengths, so causal dispatch needs q_offset ≡ Sk − Sq (the self-attention
    q_offset=0, Sq=Sk case and the decode convention both satisfy it)."""
    if ctx.attn_impl == "chunked":
        return False
    geometry_ok = not causal or (sk >= sq and sk - sq == q_offset)
    if ctx.attn_impl == "flash":
        if not geometry_ok:
            raise ValueError(
                f"attn_impl='flash' needs bottom-right-aligned causal "
                f"geometry (q_offset == Sk - Sq), got Sq={sq}, Sk={sk}, "
                f"q_offset={q_offset}")
        return True
    # auto: the kernel carries the FT policy in-kernel — including the
    # stochastic SEU hook (PR 5), so key-driven `inject_rate` campaigns
    # stay on the kernel path in both directions instead of falling back
    # to the jnp oracle.
    return ft.enabled and ft.backend == "pallas" and geometry_ok


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int, ctx: Ctx,
                      q_offset: int = 0) -> jax.Array:
    """Training/prefill attention core. q: (B,Sq,H,dh); k,v: (B,Sk,KVH,dh).

    On the pallas FT backend (or ``ctx.attn_impl="flash"``) this routes to
    the `kernels.flashft` ragged-causal kernel: one Pallas launch, both
    in-kernel GEMMs ABFT-protected, GQA via K/V index maps, and no
    O(chunk·Sk) score transient in the forward; the backward runs the
    dedicated dQ/dK/dV flash kernels over the forward-saved (m, l)
    statistics — four ABFT-protected backward GEMMs, zero oracle
    recompute. Otherwise (and under ``ctx.attn_impl="chunked"``) the
    query-chunked jnp scan runs both directions — kept as the oracle."""
    if ctx.attn_shard == "heads":
        # Megatron-SP: seq gathered, heads TP-sharded through the core
        # (GSPMD pads when head count ∤ mesh — measured in §Roofline's
        # useful ratio); o-proj reduce-scatters back to seq sharding.
        from repro.distributed.sharding import shard as _shard
        q = _shard(q, "batch", None, "heads", None)
        k = _shard(k, "batch", None, "kv_heads", None)
        v = _shard(v, "batch", None, "kv_heads", None)
    # Per-site resolution: the flash kernel is one fused site
    # ("attn_flash"); the chunked oracle's qk/pv pair shares one resolution
    # keyed on "attn_qk" (one kernel family, one level — the two GEMMs are
    # not separable on the flash path either).
    fft = ctx.ft_for("attn_flash")
    fft = fft if fft.protect_attention else FT_OFF
    if _use_flash(ctx, fft, causal, q.shape[1], k.shape[1], q_offset):
        # Targeted campaigns: the flash kernel is one fused injection site.
        fkey = ctx.key if ctx.site_allowed("attn_flash") else None
        return _flash_attention(q, k, v, causal=causal, chunk=chunk, ft=fft,
                                key=fkey, q_offset=q_offset)
    cft = ctx.ft_for("attn_qk")
    cft = cft if cft.protect_attention else FT_OFF
    out, rep = _chunked_core(q, k, v, causal=causal, chunk=chunk, ft=cft,
                             key=ctx.key, q_offset=q_offset,
                             inject_sites=ctx.inject_sites)
    telemetry.record_report(rep)
    return out


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, ctx: Ctx, *,
                     site_prefix: str = "dec") -> jax.Array:
    """Single-position attention against a (B, Smax, KVH, dh) cache.
    Positions ≥ length are masked. q: (B, 1, H, dh). GQA is grouped — the
    cache is never repeat-materialized.

    ``site_prefix`` labels the two grouped cache GEMMs in the telemetry
    registry (``{prefix}_qk`` / ``{prefix}_pv``): "dec" for decoder
    self-attention, "xdec" for whisper's cross-attention over the cached
    encoder KV, "dec_page" for the paged-cache fallback — so the planner
    prices each decode population separately instead of one aggregate."""
    b, _, h, dh = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kvh
    qg = q.reshape(b, kvh, n_rep, dh)                    # (B, KVH, rep, dh)
    kT = jnp.swapaxes(k_cache, 1, 2).swapaxes(2, 3)      # (B, KVH, dh, S)
    scores = ctx.bdot(f"{site_prefix}_qk", qg, kT
                      ).astype(jnp.float32) * dh ** -0.5
    mask = jnp.arange(s)[None, :] < length[:, None]      # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = ctx.bdot(f"{site_prefix}_pv", p, jnp.swapaxes(v_cache, 1, 2))
    return out.reshape(b, 1, h, dh)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, lengths: jax.Array,
                           page_table: jax.Array, ctx: Ctx) -> jax.Array:
    """Single-position attention against one layer of a *paged* KV cache
    (train/kv_cache.py). q: (B, 1, H, dh); k_pages, v_pages: (P, KVH, page,
    dh) page pools; lengths: int32 (B,) true kv lengths; page_table: int32
    (B, max_pages) pool-page ids per slot (NULL-padded).

    On the pallas FT backend this is ONE `kernels.flashft` decode launch:
    the page table is scalar-prefetched and consumed by the K/V index maps
    (each grid step streams exactly one pool page — no dense gather, no
    padding traffic), the per-slot ragged lengths ride a prefetched int32
    vector, and both in-kernel GEMMs carry the checksum verify with the
    kv-span clamp folded into the PV tolerance. Recorded as one fused
    telemetry site, "dec_flash". Elsewhere (and under
    ``ctx.attn_impl="chunked"``) the pages are gathered back to the dense
    (B, S, KVH, dh) layout and `decode_attention` runs as the oracle,
    recording under its own "dec_page_qk"/"dec_page_pv" labels (the paged
    cache GEMMs are a different population than the dense decode path —
    the planner prices them separately)."""
    b, _, h, dh = q.shape
    ft = ctx.ft_for("dec_flash")
    ft = ft if ft.protect_attention else FT_OFF
    use_kernel = (ctx.attn_impl != "chunked" and dh % 128 == 0
                  and (ctx.attn_impl == "flash"
                       or (ft.enabled and ft.backend == "pallas")))
    if use_kernel:
        from repro.kernels import ops as kops
        kvh = k_pages.shape[1]
        note_site("dec_flash", "flash", h // kvh,
                  page_table.shape[1] * k_pages.shape[2], dh,
                  batch=b * kvh, in_bytes=jnp.dtype(q.dtype).itemsize)
        fkey = ctx.key if ctx.site_allowed("dec_flash") else None
        out, rep = kops.flash_ft_decode(q[:, 0], k_pages, v_pages, lengths,
                                        page_table, ft=ft, key=fkey)
        scope = telemetry.current_scope()
        if scope is not None:
            det = jnp.sum(rep[..., 0]).astype(jnp.int32)
            maxres = jnp.max(rep[..., 5])
            scope.record_summary(det, maxres, ft.corrects, site="dec_flash")
        return out[:, None]
    from repro.train import kv_cache as _kvc
    kd = _kvc.gather_layer(k_pages, page_table)
    vd = _kvc.gather_layer(v_pages, page_table)
    return decode_attention(q, kd, vd, lengths, ctx, site_prefix="dec_page")


def attention(p: Dict[str, Any], x: jax.Array, cfg, ctx: Ctx, *,
              causal: bool = True, positions: Optional[jax.Array] = None,
              kv: Optional[jax.Array] = None,
              chunk: int = 512) -> jax.Array:
    """Full attention block (self- or cross-). x: (B, S, d)."""
    b, s, d = x.shape
    src = x if kv is None else kv
    # qkv biases ride the projection GEMMs as fused epilogue specs.
    q = ctx.dot_fused("wq", x, p["wq"], bias=p.get("bq"))
    k = ctx.dot_fused("wk", src, p["wk"], bias=p.get("bk"))
    v = ctx.dot_fused("wv", src, p["wv"], bias=p.get("bv"))
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    if positions is None:
        positions = jnp.arange(s)
    if kv is None:  # RoPE on self-attention only
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=causal, chunk=chunk, ctx=ctx)
    return ctx.dot("wo", out.reshape(b, s, -1), p["wo"])


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, n_layers: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, d_ff, dtype),
        "w_up": dense_init(ks[1], d, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d, dtype,
                             scale=0.02 / (2 * n_layers) ** 0.5),
    }


def mlp(p: Dict[str, Any], x: jax.Array, ctx: Ctx) -> jax.Array:
    g = ctx.dot_fused("w_gate", x, p["w_gate"], act="silu")  # fused epilogue
    u = ctx.dot("w_up", x, p["w_up"])
    return ctx.dot("w_down", g * u, p["w_down"])


# ---------------------------------------------------------------------------
# embedding / head / loss
# ---------------------------------------------------------------------------

def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def lm_head(x: jax.Array, table: jax.Array, ctx: Ctx) -> jax.Array:
    return ctx.dot("lm_head", x, table)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore: int = -1) -> jax.Array:
    """Mean CE over positions with label != ignore. logits (…, V).

    GSPMD-friendly: the gold-logit gather is expressed as a masked reduction
    over the vocab dim (fuses to an iota-compare + reduce under a
    vocab-sharded mesh — no all-gather of the logits, no gather op)."""
    logits = logits.astype(jnp.float32)
    mask = (labels != ignore)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == safe[..., None], logits, 0.0),
                   axis=-1)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
