"""Decoder-only transformer LM (dense + MoE families).

Covers: qwen2-7b, codeqwen1.5-7b, phi4-mini, minitron-4b (dense);
arctic-480b, qwen3-moe-235b (MoE — arctic additionally has a parallel dense
residual FFN per layer). Also the backbone for phi-3-vision.

Layers are scanned (stacked params) with optional per-layer remat — keeps
the HLO size O(1) in depth, which the 512-device dry-run depends on.

Training/prefill attention routes through `blocks.chunked_attention`, which
since PR 4 dispatches to the `kernels.flashft` ragged-causal kernel on the
pallas FT backend (one protected Pallas launch, chunked-oracle recompute in
the backward) — so a train-step jaxpr on that backend carries no large
dot_general outside registry-emitted kernels (tests/test_backward_ft.py's
protection audit).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import telemetry
from repro.core import loops
from repro.distributed.sharding import shard
from . import blocks, moe as moe_lib
from .blocks import Ctx


class AuxOut(NamedTuple):
    balance: jax.Array          # MoE load-balance loss
    ft: telemetry.FTReport      # per-step SDC telemetry (DESIGN.md §2.3)


def init_layer(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": blocks.init_attention(ks[0], cfg, dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(ks[1], cfg.d_model, cfg.moe,
                                    cfg.n_layers, dtype)
        if cfg.moe.dense_d_ff:
            p["mlp"] = blocks.init_mlp(ks[2], cfg.d_model, cfg.moe.dense_d_ff,
                                       cfg.n_layers, dtype)
    else:
        p["mlp"] = blocks.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                   cfg.n_layers, dtype)
    return p


def apply_layer(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, ctx: Ctx,
                *, positions: Optional[jax.Array] = None,
                chunk: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm block. Returns (x, aux_loss)."""
    x = shard(x, "batch", "seq", "embed")
    h = blocks.rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    x = x + blocks.attention(p["attn"], h, cfg, ctx, causal=True,
                             positions=positions, chunk=chunk)
    h = blocks.rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        y, aux = moe_lib.apply_moe(p["moe"], h, cfg.moe, ctx)
        if cfg.moe.dense_d_ff:
            y = y + blocks.mlp(p["mlp"], h, ctx)   # arctic parallel residual
        x = x + y
    else:
        x = x + blocks.mlp(p["mlp"], h, ctx)
    return shard(x, "batch", "seq", "embed"), aux


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    v = cfg.padded_vocab()
    params = {
        "embed": {"table": blocks.embed_init(k_emb, v, cfg.d_model, dtype)},
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"table": blocks.dense_init(k_head, cfg.d_model, v,
                                                     dtype)}
    return params


def _scan_layers(params, x, fn, remat: bool):
    """Scan stacked layers carrying (activations, aux-loss, FTReport) — SDC
    telemetry crosses the scan via the carry (telemetry.scoped). Each
    layer's single-row report lands at row 1 + idx of the carried report
    (row 0 stays for un-layered sites), so the step report resolves
    (layer, site) pairs."""

    def wrapped(lp, h, idx):
        return telemetry.scoped(lambda: fn(lp, h, idx))

    body_fn = blocks.make_remat(wrapped, remat)

    def body(carry, scanned):
        h, aux, rep = carry
        lp, idx = scanned
        (h, aux_l), rep_l = body_fn(lp, h, idx)
        return (h, aux + aux_l, rep.merge_at(rep_l, idx + 1)), None

    n = jax.tree.leaves(params)[0].shape[0]
    (x, aux, rep), _ = loops.scan(
        body, (x, jnp.zeros((), jnp.float32),
               telemetry.FTReport.empty(rows=n + 1)),
        (params, jnp.arange(n)))
    return x, aux, rep


def forward(params, tokens: jax.Array, cfg: ModelConfig, ctx: Ctx, *,
            remat: bool = True, chunk: int = 512,
            extra_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) int32 → (logits (B, S', V), aux). If `extra_embeds`
    (B, P, d) is given (VLM patch stubs), it is prepended to the sequence."""
    x = blocks.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(ctx.dtype), x], axis=1)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])

    def layer_fn(lp, h, idx):
        return apply_layer(lp, h, cfg, ctx.fold(idx), positions=positions,
                           chunk=chunk)

    x, aux, rep = _scan_layers(params["layers"], x, layer_fn, remat)
    x = blocks.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = (params["embed"]["table"].T if cfg.tie_embeddings
             else params["head"]["table"])
    logits, rep_h = telemetry.scoped(lambda: blocks.lm_head(x, table, ctx))
    ctx.check_inject_sites()
    # "seq" claims the model axis first ⇒ logits stay sequence-sharded and
    # the CE loss is fully local (only the head table is gathered, once).
    return shard(logits, "batch", "seq", "vocab"), AuxOut(aux,
                                                          rep.merge(rep_h))


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig, ctx: Ctx,
            *, remat: bool = True, chunk: int = 512) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg, ctx, remat=remat,
                          chunk=chunk, extra_embeds=batch.get("patches"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:      # VLM: logits cover patches too
        logits = logits[:, -labels.shape[1]:]
    ce = blocks.cross_entropy(logits, labels)
    total = ce + 0.01 * aux.balance
    return total, {"ce": ce, "aux": aux.balance, "ft": aux.ft}


# ---------------------------------------------------------------------------
# serving: KV cache, prefill, decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               kv_batch_axis: str = "batch") -> Dict[str, Any]:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def _shard_cache(cache):
    cache["k"] = shard(cache["k"], None, "batch", "kv_seq", "kv_heads", None)
    cache["v"] = shard(cache["v"], None, "batch", "kv_seq", "kv_heads", None)
    return cache


def _project_qkv(p, h, cfg: ModelConfig, ctx: Ctx, positions):
    b, s, _ = h.shape
    # qkv biases ride the projection GEMMs as fused epilogue specs.
    q = ctx.dot_fused("wq", h, p["wq"], bias=p.get("bq"))
    k = ctx.dot_fused("wk", h, p["wk"], bias=p.get("bk"))
    v = ctx.dot_fused("wv", h, p["wv"], bias=p.get("bv"))
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = blocks.apply_rope(q, positions, cfg.rope_theta)
    k = blocks.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def decode_step(params, token: jax.Array, cache: Dict[str, Any],
                cfg: ModelConfig, ctx: Ctx) -> Tuple[jax.Array, Dict]:
    """One decode step. token: (B, 1) int32; cache holds `length` tokens.
    Returns (logits (B, 1, V), new cache)."""
    cache = _shard_cache(dict(cache))
    x = blocks.embed(token, params["embed"]["table"]).astype(ctx.dtype)
    pos = cache["length"]                                  # (B,)

    def layer_fn(lp, h, scanned_cache):
        k_c, v_c, idx = scanned_cache
        lctx = ctx.fold(idx)
        hn = blocks.rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = _project_qkv(lp["attn"], hn, cfg, lctx,
                                       pos[:, None])
        # write the new kv at `pos` for every batch row
        b = h.shape[0]
        oh = jax.nn.one_hot(pos, k_c.shape[1], dtype=k_c.dtype)  # (B, S)
        k_c = k_c + oh[:, :, None, None] * k_new
        v_c = v_c + oh[:, :, None, None] * v_new
        att = blocks.decode_attention(q, k_c, v_c, pos + 1, lctx)
        h = h + lctx.dot("wo", att.reshape(b, 1, -1), lp["attn"]["wo"])
        hn = blocks.rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_lib.apply_moe(lp["moe"], hn, cfg.moe, lctx)
            if cfg.moe.dense_d_ff:
                y = y + blocks.mlp(lp["mlp"], hn, lctx)
            h = h + y
        else:
            h = h + blocks.mlp(lp["mlp"], hn, lctx)
        return h, (k_c, v_c)

    # Serve-path telemetry is opt-in: records appended from inside the scan
    # body to an outer-trace scope would leak tracers, so per-layer scoping
    # (and the report carry) only runs when the caller opened an ft_scope
    # (train/serve.py's with_report path) — gate resolved at trace time.
    want_ft = telemetry.current_scope() is not None
    n = cfg.n_layers

    def body(carry, scanned):
        h, rep = carry
        lp, k_c, v_c, idx = scanned
        if want_ft:
            (h, (k_c, v_c)), rep_l = telemetry.scoped(
                lambda: layer_fn(lp, h, (k_c, v_c, idx)))
            rep = rep.merge_at(rep_l, idx + 1)
        else:
            h, (k_c, v_c) = layer_fn(lp, h, (k_c, v_c, idx))
        return (h, rep), (k_c, v_c)

    (x, rep), (new_k, new_v) = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=n + 1)),
        (params["layers"], cache["k"], cache["v"], jnp.arange(n)))
    if want_ft:
        telemetry.record_report(rep)
    x = blocks.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = (params["embed"]["table"].T if cfg.tie_embeddings
             else params["head"]["table"])
    logits = blocks.lm_head(x, table, ctx)
    new_cache = {"k": new_k, "v": new_v, "length": cache["length"] + 1}
    return logits, _shard_cache(new_cache)


def paged_decode_step(params, token: jax.Array, cache: Dict[str, Any],
                      cfg: ModelConfig, ctx: Ctx) -> Tuple[jax.Array, Dict]:
    """One decode step against the *paged* KV cache (train/kv_cache.py —
    the serving engine's layout). token: (B, 1) int32 over the engine's
    slot axis; cache: {"k_pages", "v_pages": (L, P, KVH, page, dh) pools,
    "page_table": int32 (B, max_pages), "length": int32 (B,)}. Returns
    (logits (B, 1, V), new cache).

    The new kv lands via a per-layer page-table-routed scatter
    (`kv_cache.append_layer`) and attention runs through
    `blocks.paged_decode_attention` — on the pallas FT backend one flashft
    decode launch per layer with prefetched ragged lengths, so thousands
    of slots share the pool with zero dense padding. Dead slots (all-NULL
    table rows, length 0) scatter into the reserved null page and produce
    ignored garbage logits; the engine rebuilds `page_table`/`length` from
    the host allocator each step."""
    from repro.train import kv_cache as kv_cache_lib
    x = blocks.embed(token, params["embed"]["table"]).astype(ctx.dtype)
    pos = cache["length"]                                  # (B,)
    table = cache["page_table"]

    def layer_fn(lp, h, scanned_cache):
        k_p, v_p, idx = scanned_cache
        lctx = ctx.fold(idx)
        hn = blocks.rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = _project_qkv(lp["attn"], hn, cfg, lctx,
                                       pos[:, None])
        b = h.shape[0]
        k_p = kv_cache_lib.append_layer(k_p, k_new[:, 0], table, pos)
        v_p = kv_cache_lib.append_layer(v_p, v_new[:, 0], table, pos)
        att = blocks.paged_decode_attention(q, k_p, v_p, pos + 1, table,
                                            lctx)
        h = h + lctx.dot("wo", att.reshape(b, 1, -1), lp["attn"]["wo"])
        hn = blocks.rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_lib.apply_moe(lp["moe"], hn, cfg.moe, lctx)
            if cfg.moe.dense_d_ff:
                y = y + blocks.mlp(lp["mlp"], hn, lctx)
            h = h + y
        else:
            h = h + blocks.mlp(lp["mlp"], hn, lctx)
        return h, (k_p, v_p)

    # Same serve-path telemetry gate as decode_step: per-layer scoping only
    # when the caller opened an ft_scope (resolved at trace time).
    want_ft = telemetry.current_scope() is not None
    n = cfg.n_layers

    def body(carry, scanned):
        h, rep = carry
        lp, k_p, v_p, idx = scanned
        if want_ft:
            (h, (k_p, v_p)), rep_l = telemetry.scoped(
                lambda: layer_fn(lp, h, (k_p, v_p, idx)))
            rep = rep.merge_at(rep_l, idx + 1)
        else:
            h, (k_p, v_p) = layer_fn(lp, h, (k_p, v_p, idx))
        return (h, rep), (k_p, v_p)

    (x, rep), (new_k, new_v) = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=n + 1)),
        (params["layers"], cache["k_pages"], cache["v_pages"],
         jnp.arange(n)))
    if want_ft:
        telemetry.record_report(rep)
    x = blocks.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["head"]["table"])
    logits = blocks.lm_head(x, head, ctx)
    new_cache = {"k_pages": new_k, "v_pages": new_v,
                 "page_table": table, "length": pos + 1}
    return logits, new_cache


def prefill(params, tokens: jax.Array, cache: Dict[str, Any],
            cfg: ModelConfig, ctx: Ctx, *, chunk: int = 512,
            remat: bool = True,
            extra_embeds: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Run the prompt through the model, filling the KV cache.
    `extra_embeds` (B, P, d) — VLM patch stubs prepended to the prompt.
    Returns (last-position logits (B, V), cache)."""
    cache = _shard_cache(dict(cache))
    b = tokens.shape[0]
    x = blocks.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(ctx.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)

    def layer_fn(lp, h, idx):
        lctx = ctx.fold(idx)
        hn = blocks.rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg, lctx, positions)
        att = blocks.chunked_attention(q, k, v, causal=True, chunk=chunk,
                                       ctx=lctx)
        h = h + lctx.dot("wo", att.reshape(b, s, -1), lp["attn"]["wo"])
        hn = blocks.rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_lib.apply_moe(lp["moe"], hn, cfg.moe, lctx)
            if cfg.moe.dense_d_ff:
                y = y + blocks.mlp(lp["mlp"], hn, lctx)
            h = h + y
        else:
            h = h + blocks.mlp(lp["mlp"], hn, lctx)
        return h, (k, v)

    # Like decode_step: per-layer telemetry only when the caller opened an
    # ft_scope — scoping must sit INSIDE the remat wrapper (records cannot
    # cross a checkpoint region as a side channel).
    want_ft = telemetry.current_scope() is not None

    def wrapped(lp, h, idx):
        return telemetry.scoped(lambda: layer_fn(lp, h, idx))

    fn = blocks.make_remat(wrapped if want_ft else layer_fn, remat)

    def body(carry, scanned):
        lp, idx = scanned
        h, rep = carry
        if want_ft:
            (h, (k, v)), rep_l = fn(lp, h, idx)
            rep = rep.merge_at(rep_l, idx + 1)
        else:
            h, (k, v) = fn(lp, h, idx)
        return (h, rep), (k, v)

    (x, rep), (ks, vs) = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=cfg.n_layers + 1)),
        (params["layers"], jnp.arange(cfg.n_layers)))
    if want_ft:
        telemetry.record_report(rep)
    # place prompt KV into the cache buffers
    max_len = cache["k"].shape[2]
    pad = max_len - s
    k_full = jnp.pad(ks.astype(cache["k"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_full = jnp.pad(vs.astype(cache["v"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = blocks.rmsnorm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    table = (params["embed"]["table"].T if cfg.tie_embeddings
             else params["head"]["table"])
    logits = blocks.lm_head(x, table, ctx)[:, 0]
    new_cache = {"k": k_full, "v": v_full,
                 "length": jnp.full((b,), s, jnp.int32)}
    return logits, _shard_cache(new_cache)
