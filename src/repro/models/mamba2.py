"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

Training uses the chunked SSD algorithm: intra-chunk quadratic ("attention-
like") GEMMs + inter-chunk linear state recurrence via lax.scan. The large
intra-chunk GEMMs (C·Bᵀ scores, state contractions) are ABFT-protected with
ft_batched_dot — the paper's technique applied to the GEMM-shaped portion of
an attention-free architecture (DESIGN.md §5). The diagonal decay/recurrence
is element-wise (not a GEMM) and sits outside ABFT's natural scope.

Decode is O(1) per token: h ← exp(dt·A)·h + dt·B·x, y = C·h + D·x.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.core import ft_batched_dot
from repro.core import loops
from repro.distributed.sharding import shard
from .blocks import Ctx, dense_init, rmsnorm


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    n_heads = d_inner // sc.head_dim
    return d_inner, n_heads, sc.state, sc.n_groups


def init_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    sc = cfg.ssm
    d_inner, h, n, g = dims(cfg)
    conv_ch = d_inner + 2 * g * n
    proj_out = 2 * d_inner + 2 * g * n + h          # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (sc.conv_width, conv_ch),
                                     jnp.float32) * 0.02).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, cfg.d_model, dtype,
                               scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_inner, h, n, g = dims(cfg)
    z, x, b_mat, c_mat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + g * n,
                 2 * d_inner + 2 * g * n], axis=-1)
    return z, x, b_mat, c_mat, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B, L, C); w: (W, C)."""
    wlen = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(wlen):           # W=4 — unrolled, fuses to one VPU chain
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, sc: SSMConfig, ctx: Ctx,
                h0=None):
    """Chunked SSD scan.
    x: (B, L, H, P); dt: (B, L, H) post-softplus; a: (H,) < 0;
    b_mat/c_mat: (B, L, G, N). Returns (y (B,L,H,P), h_last (B,H,N,P))."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    q = min(sc.chunk, l)
    if l % q != 0:
        q = l
    nc = l // q
    rep = h // g

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, q, g, n)
    cc = c_mat.reshape(bsz, nc, q, g, n)

    dta = dtc * a                                     # (B,nc,Q,H)
    a_cum = jnp.cumsum(dta, axis=2)                   # within-chunk cumsum
    a_total = a_cum[:, :, -1]                         # (B,nc,H)

    # --- intra-chunk (quadratic, GEMM-shaped → ABFT-protected) -----------
    # scores[b,c,h,qi,qj] = C[qi]·B[qj] * exp(a_cum[qi]-a_cum[qj]) * dt[qj]
    cc_h = jnp.repeat(cc, rep, axis=3)                # (B,nc,Q,H,N)
    bc_h = jnp.repeat(bc, rep, axis=3)
    cb = ft_batched_dot(
        cc_h.transpose(0, 1, 3, 2, 4).reshape(-1, q, n),
        bc_h.transpose(0, 1, 3, 4, 2).reshape(-1, n, q),
        ft=ctx.ft, key=ctx.subkey("ssd_cb"), site="ssd_cb",
    ).reshape(bsz, nc, h, q, q).astype(jnp.float32)
    seg = a_cum.transpose(0, 1, 3, 2)                 # (B,nc,H,Q)
    decay = jnp.exp(jnp.clip(seg[..., :, None] - seg[..., None, :],
                             -60.0, 0.0))
    causal = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(causal, cb * decay, 0.0)
    l_mat = l_mat * dtc.transpose(0, 1, 3, 2)[..., None, :]   # ·dt[qj]
    y_diag = ft_batched_dot(
        l_mat.astype(x.dtype).reshape(-1, q, q),
        xc.transpose(0, 1, 3, 2, 4).reshape(-1, q, p),
        ft=ctx.ft, key=ctx.subkey("ssd_lx"), site="ssd_lx",
    ).reshape(bsz, nc, h, q, p)

    # --- chunk boundary states (GEMM-shaped) ------------------------------
    # S[b,c,h,n,p] = Σ_q B[q]·exp(a_total - a_cum[q])·dt[q]·x[q]
    decay_end = jnp.exp(jnp.clip(a_total[:, :, None] - a_cum, -60.0, 0.0))
    bw = (bc_h.astype(jnp.float32)
          * (decay_end * dtc)[..., None])             # (B,nc,Q,H,N)
    states = ft_batched_dot(
        bw.transpose(0, 1, 3, 4, 2).astype(x.dtype).reshape(-1, n, q),
        xc.transpose(0, 1, 3, 2, 4).reshape(-1, q, p),
        ft=ctx.ft, key=ctx.subkey("ssd_state"), site="ssd_state",
    ).reshape(bsz, nc, h, n, p).astype(jnp.float32)

    # --- inter-chunk recurrence (element-wise scan) -----------------------
    chunk_decay = jnp.exp(jnp.clip(a_total, -60.0, 0.0))     # (B,nc,H)

    def scan_fn(h_prev, inp):
        s_c, dec = inp                                # (B,H,N,P), (B,H)
        h_new = h_prev * dec[:, :, None, None] + s_c
        return h_new, h_prev

    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    h_last, h_prevs = loops.scan(
        scan_fn, h0,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                  # (B,nc,H,N,P)

    # --- inter-chunk output: y_off = C·h_prev·exp(a_cum) ------------------
    y_off = ft_batched_dot(
        cc_h.transpose(0, 1, 3, 2, 4).astype(x.dtype).reshape(-1, q, n),
        h_prevs.astype(x.dtype).reshape(-1, n, p),
        ft=ctx.ft, key=ctx.subkey("ssd_ch"), site="ssd_ch",
    ).reshape(bsz, nc, h, q, p).astype(jnp.float32)
    y_off = y_off * jnp.exp(jnp.clip(a_cum, -60.0, 0.0)
                            ).transpose(0, 1, 3, 2)[..., None]

    y = (y_diag.astype(jnp.float32) + y_off)
    y = y.transpose(0, 1, 3, 2, 4).reshape(bsz, l, h, p)
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_last


def apply_block(p: Dict[str, Any], hidden: jax.Array, cfg: ModelConfig,
                ctx: Ctx) -> jax.Array:
    """Full Mamba-2 block (training / prefill). hidden: (B, L, d)."""
    sc = cfg.ssm
    d_inner, h, n, g = dims(cfg)
    bsz, l, _ = hidden.shape
    zxbcdt = ctx.dot("in_proj", hidden, p["in_proj"])
    z, x, b_mat, c_mat, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, b_mat, c_mat], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    x = x.reshape(bsz, l, h, sc.head_dim)
    x = shard(x, "batch", "seq", None, None)
    b_mat = b_mat.reshape(bsz, l, g, n)
    c_mat = c_mat.reshape(bsz, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(x, dt, a, b_mat, c_mat, p["D"], sc, ctx)
    y = y.reshape(bsz, l, d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_w"], cfg.norm_eps)
    return ctx.dot("out_proj", y, p["out_proj"])


# ---------------------------------------------------------------------------
# decode: O(1) state step
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    sc = cfg.ssm
    d_inner, h, n, g = dims(cfg)
    conv_ch = d_inner + 2 * g * n
    return {
        "ssm": jnp.zeros((batch, h, n, sc.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, sc.conv_width - 1, conv_ch), jnp.bfloat16),
    }


def decode_block(p: Dict[str, Any], hidden: jax.Array, state: Dict[str, Any],
                 cfg: ModelConfig, ctx: Ctx):
    """One-token step. hidden: (B, 1, d). Returns (out, new_state)."""
    sc = cfg.ssm
    d_inner, h, n, g = dims(cfg)
    bsz = hidden.shape[0]
    zxbcdt = ctx.dot("in_proj", hidden, p["in_proj"])
    z, x, b_mat, c_mat, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, b_mat, c_mat], axis=-1)     # (B,1,conv_ch)
    window = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
    conv_out = (jnp.sum(window.astype(jnp.float32)
                        * p["conv_w"].astype(jnp.float32)[None], axis=1)
                + p["conv_b"].astype(jnp.float32))        # (B, conv_ch)
    xbc1 = jax.nn.silu(conv_out)
    x1, b1, c1 = jnp.split(xbc1, [d_inner, d_inner + g * n], axis=-1)
    x1 = x1.reshape(bsz, h, sc.head_dim)
    b1 = jnp.repeat(b1.reshape(bsz, g, n), h // g, axis=1)    # (B,H,N)
    c1 = jnp.repeat(c1.reshape(bsz, g, n), h // g, axis=1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * a)                                  # (B,H)
    ssm = state["ssm"] * decay[:, :, None, None] \
        + (dt1[:, :, None] * b1)[..., None] * x1[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", c1, ssm) \
        + p["D"][None, :, None] * x1
    y = y.reshape(bsz, 1, d_inner).astype(hidden.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_w"], cfg.norm_eps)
    out = ctx.dot("out_proj", y, p["out_proj"])
    new_state = {"ssm": shard(ssm, "batch", "state", None, None),
                 "conv": window[:, 1:].astype(jnp.bfloat16)}
    return out, new_state


# ---------------------------------------------------------------------------
# full LM
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    from . import blocks as B
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.n_layers)

    def one(k):
        kb, kn = jax.random.split(k)
        return {"ssm": init_block(kb, cfg, dtype),
                "pre_norm": jnp.ones((cfg.d_model,), jnp.float32)}

    v = cfg.padded_vocab()
    return {
        "embed": {"table": B.embed_init(k_emb, v, cfg.d_model, dtype)},
        "layers": jax.vmap(one)(keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": {"table": dense_init(k_head, cfg.d_model, v, dtype)},
    }


def forward(params, tokens, cfg: ModelConfig, ctx: Ctx, *, remat=True,
            chunk: int = 512, extra_embeds=None):
    from . import blocks as B
    from repro.core import telemetry
    from .transformer import AuxOut
    x = B.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    x = shard(x, "batch", "seq", "embed")

    def layer_fn(lp, h, idx):
        lctx = ctx.fold(idx)
        return telemetry.scoped(
            lambda: h + apply_block(lp["ssm"],
                                    rmsnorm(h, lp["pre_norm"], cfg.norm_eps),
                                    cfg, lctx))

    from .blocks import make_remat
    fn = make_remat(layer_fn, remat)

    def body(carry, scanned):
        h, rep = carry
        lp, idx = scanned
        h, rep_l = fn(lp, h, idx)
        return (h, rep.merge_at(rep_l, idx + 1)), None

    (x, rep), _ = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=cfg.n_layers + 1)),
        (params["layers"], jnp.arange(cfg.n_layers)))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits, rep_h = telemetry.scoped(
        lambda: ctx.dot("lm_head", x, params["head"]["table"]))
    ctx.check_inject_sites()
    return logits, AuxOut(jnp.zeros((), jnp.float32), rep.merge(rep_h))


def loss_fn(params, batch, cfg: ModelConfig, ctx: Ctx, *, remat=True,
            chunk: int = 512):
    from . import blocks as B
    logits, aux = forward(params, batch["tokens"], cfg, ctx, remat=remat)
    ce = B.cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": aux.balance, "ft": aux.ft}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, **_) -> Dict[str, Any]:
    """SSM 'cache' = per-layer recurrent state (O(1) in max_len)."""
    state = init_state(cfg, batch)
    return {
        "ssm": jnp.zeros((cfg.n_layers,) + state["ssm"].shape, jnp.float32),
        "conv": jnp.zeros((cfg.n_layers,) + state["conv"].shape,
                          jnp.bfloat16),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, token, cache, cfg: ModelConfig, ctx: Ctx):
    from . import blocks as B
    from repro.core import telemetry
    x = B.embed(token, params["embed"]["table"]).astype(ctx.dtype)

    def layer_fn(lp, h, ssm_s, conv_s, idx):
        lctx = ctx.fold(idx)
        out, new_s = decode_block(lp["ssm"],
                                  rmsnorm(h, lp["pre_norm"], cfg.norm_eps),
                                  {"ssm": ssm_s, "conv": conv_s}, cfg, lctx)
        return h + out, (new_s["ssm"], new_s["conv"])

    # Serve-path telemetry gate, like transformer.decode_step: per-layer
    # scoping (and the report carry) only when the caller opened an
    # ft_scope — resolved at trace time.
    want_ft = telemetry.current_scope() is not None
    n = cfg.n_layers

    def body(carry, scanned):
        h, rep = carry
        lp, ssm_s, conv_s, idx = scanned
        if want_ft:
            (h, states), rep_l = telemetry.scoped(
                lambda: layer_fn(lp, h, ssm_s, conv_s, idx))
            rep = rep.merge_at(rep_l, idx + 1)
        else:
            h, states = layer_fn(lp, h, ssm_s, conv_s, idx)
        return (h, rep), states

    (x, rep), (ssm_new, conv_new) = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=n + 1)),
        (params["layers"], cache["ssm"], cache["conv"], jnp.arange(n)))
    if want_ft:
        telemetry.record_report(rep)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = ctx.dot("lm_head", x, params["head"]["table"])
    new_cache = {"ssm": ssm_new, "conv": conv_new,
                 "length": cache["length"] + 1}
    return logits, new_cache


def prefill(params, tokens, cache, cfg: ModelConfig, ctx: Ctx, *,
            chunk: int = 512, remat: bool = True):
    """Prefill = full forward; final SSM states become the cache. For
    simplicity we re-run the chunked scan keeping the last state."""
    from . import blocks as B
    x = B.embed(tokens, params["embed"]["table"]).astype(ctx.dtype)
    sc = cfg.ssm
    d_inner, h, n, g = dims(cfg)

    def layer_fn(lp, hdd, idx):
        lctx = ctx.fold(idx)
        p = lp["ssm"]
        hidden = rmsnorm(hdd, lp["pre_norm"], cfg.norm_eps)
        bsz, l, _ = hidden.shape
        zxbcdt = lctx.dot("in_proj", hidden, p["in_proj"])
        z, xx, b_mat, c_mat, dt = _split_proj(zxbcdt, cfg)
        xbc = jnp.concatenate([xx, b_mat, c_mat], axis=-1)
        conv_tail = xbc[:, -(sc.conv_width - 1):, :].astype(jnp.bfloat16)
        xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
        xx, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + g * n], -1)
        xx = xx.reshape(bsz, l, h, sc.head_dim)
        b_mat = b_mat.reshape(bsz, l, g, n)
        c_mat = c_mat.reshape(bsz, l, g, n)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        a = -jnp.exp(p["A_log"])
        y, h_last = ssd_chunked(xx, dt, a, b_mat, c_mat, p["D"], sc, lctx)
        y = y.reshape(bsz, l, d_inner)
        y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    p["norm_w"], cfg.norm_eps)
        return hdd + lctx.dot("out_proj", y, p["out_proj"]), \
            (h_last, conv_tail)

    from repro.core import telemetry
    from .blocks import make_remat

    # Scoping must sit INSIDE the remat wrapper (records cannot cross a
    # checkpoint region as a side channel) — same gate as decode_step.
    want_ft = telemetry.current_scope() is not None

    def wrapped(lp, hdd, idx):
        return telemetry.scoped(lambda: layer_fn(lp, hdd, idx))

    fn = make_remat(wrapped if want_ft else layer_fn, remat)

    def body(carry, scanned):
        hdd, rep = carry
        lp, idx = scanned
        if want_ft:
            (hdd, states), rep_l = fn(lp, hdd, idx)
            rep = rep.merge_at(rep_l, idx + 1)
        else:
            hdd, states = fn(lp, hdd, idx)
        return (hdd, rep), states

    (x, rep), (ssm_s, conv_s) = loops.scan(
        body, (x, telemetry.FTReport.empty(rows=cfg.n_layers + 1)),
        (params["layers"], jnp.arange(cfg.n_layers)))
    if want_ft:
        telemetry.record_report(rep)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = ctx.dot("lm_head", x, params["head"]["table"])[:, 0]
    b = tokens.shape[0]
    new_cache = {"ssm": ssm_s, "conv": conv_s,
                 "length": jnp.full((b,), tokens.shape[1], jnp.int32)}
    return logits, new_cache
