"""Deterministic, shardable synthetic-token data pipeline.

Production properties this substrate provides:
  * O(1) resume — `batch_at(step)` is a pure function of (seed, step), so a
    restart from checkpoint step N replays exactly the data the failed run
    would have seen (no file offsets to persist);
  * host sharding — each host materializes only its `[host_id::n_hosts]`
    slice of the global batch (what a multi-host TPU pod loader does);
  * background prefetch — a one-slot lookahead thread overlaps host-side
    batch synthesis with device compute.

Tokens are Zipf-distributed (vocab realism for embedding-gather benches);
labels are next-token shifted.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1,
                 n_patches: int = 0, n_frames: int = 0, d_model: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab = vocab_size
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seq = seq_len
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.n_patches = n_patches
        self.n_frames = n_frames
        self.d_model = d_model

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s = self.local_batch, self.seq
        # Zipf-ish: inverse-CDF of a power law over the vocab
        u = rng.random((b, s + 1))
        ranks = np.floor((self.vocab ** u - 1.0)).astype(np.int64)
        tokens = np.clip(ranks, 0, self.vocab - 1).astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.n_patches:
            out["patches"] = rng.standard_normal(
                (b, self.n_patches, self.d_model)).astype(np.float32) * 0.02
        if self.n_frames:
            out["frames"] = rng.standard_normal(
                (b, self.n_frames, self.d_model)).astype(np.float32) * 0.02
        return out

    def iter_from(self, start_step: int, prefetch: int = 1
                  ) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator, resumable at any step."""
        if prefetch <= 0:
            step = start_step
            while True:
                yield self.batch_at(step)
                step += 1
            return
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def for_model(cfg, shape, *, seed: int = 0, host_id: int = 0,
              n_hosts: int = 1, batch: Optional[int] = None) -> TokenPipeline:
    b = batch if batch is not None else shape.global_batch
    return TokenPipeline(
        vocab_size=cfg.vocab_size, global_batch=b, seq_len=shape.seq_len,
        seed=seed, host_id=host_id, n_hosts=n_hosts,
        n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
        n_frames=cfg.n_audio_frames if cfg.family == "encdec" else 0,
        d_model=cfg.d_model)
