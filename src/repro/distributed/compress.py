"""int8 error-feedback gradient compression (cross-pod DCN sync).

At 2+ pods the data-parallel gradient all-reduce crosses the DCN, which is
an order of magnitude slower than ICI. The standard mitigation is 1-byte
quantized sync with error feedback (EF-SGD): quantization residue is carried
into the next step so compression error doesn't accumulate.

This module implements the numerics as an optimizer-level transform:
`compress_decompress` is inserted on the gradients at the pod boundary
(train_loop wires it when `compress_grads=True`), cutting the pod-boundary
collective bytes 4× (visible in §Roofline's collective term for multi-pod).
Convergence parity is validated in tests/test_substrate.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    return jnp.round(x / scale).astype(jnp.int8), scale


def compress_decompress(grads, error):
    """EF int8 round-trip: g' = Q(g + e); e' = (g + e) - g'.
    Returns (decompressed grads, new error feedback)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _q8(gf)
        deq = q.astype(jnp.float32) * s
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def compressed_bytes(params) -> int:
    """Pod-boundary bytes per sync with compression (1B + scale)."""
    return sum(x.size + 4 for x in jax.tree.leaves(params))
