"""Logical-axis sharding (MaxText-style rules).

Model code annotates activations with *logical* axes (`shard(x, "batch",
None, "embed")`); the launcher installs a mesh + a logical→mesh-axis rule
table. Outside any mesh (CPU smoke tests) the annotations are no-ops, so the
exact same model code runs on 1 device and on the 512-chip production mesh.

Parameter shardings are derived from pytree path patterns in
`param_sharding_rules` — FSDP over "data" on the non-TP dim, tensor/expert
parallel over "model" (see DESIGN.md §4).
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


#: logical axis → mesh axis (or tuple of mesh axes). None = replicated.
#: Overridden per shape by the launcher (e.g. long_500k decode swaps batch
#: sharding for head/state sharding — see launch/dryrun.py RULES_BY_SHAPE).
DEFAULT_RULES = {
    "batch": ("pod", "data"),        # data parallel over pod × data
    "embed": None,                   # d_model replicated on activations
    "seq": "model",                  # Megatron-SP: layer-boundary activations
                                     # sequence-sharded over "model"
    "heads": "model",                # attention-head tensor parallel
    "kv_heads": None,                # decode KV replicated over heads
    "mlp": "model",                  # FFN hidden tensor parallel
    "experts": "model",              # expert parallel
    "vocab": "model",
    "embed_param": "data",           # FSDP dim on weights
    "kv_seq": None,                  # decode KV-cache sequence dim
    "state": "model",                # SSM state heads
    # MoE dispatch geometry (models/moe.py): flattened token-group dim
    # carries the full activation sharding; the expert-side token dim keeps
    # only data parallelism so "model" is free for expert parallelism
    "tokens": ("pod", "data", "model"),
    "exp_tokens": ("pod", "data"),
    "moe_ff": None,                  # expert ff dim (decode: "data")
}


def _rules():
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    """Install mesh + logical rules; model `shard()` calls become GSPMD
    constraints. Composes with `jax.set_mesh`/`with mesh`."""
    prev = (_mesh(), _rules())
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh, _state.rules = prev


def logical_to_spec(axes: Sequence) -> P:
    """Map logical axis names to a PartitionSpec under the current rules,
    dropping mesh axes that don't exist on the installed mesh. A mesh axis
    may appear only once per spec — later logical axes mapping to an
    already-used mesh axis degrade to replicated (e.g. logits
    (batch, seq→model, vocab→model) keeps vocab sharding on the earlier
    dim... first occurrence wins)."""
    rules = _rules() or {}
    mesh = _mesh()
    names = set(mesh.axis_names) if mesh is not None else set()
    used: set = set()
    out = []
    for ax in axes:
        mapped = rules.get(ax) if isinstance(ax, str) else ax
        if mapped is None:
            out.append(None)
            continue
        if not isinstance(mapped, tuple):
            mapped = (mapped,)
        keep = tuple(m for m in mapped if m in names and m not in used)
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    return P(*out)


def shard(x: jax.Array, *axes) -> jax.Array:
    """Annotate activation x with logical axes. No-op without a mesh."""
    mesh = _mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter sharding rules (pytree-path regex → logical axes per dim)
# ---------------------------------------------------------------------------

#: (path regex, logical axes for each array dim). First match wins. Scanned
#: (stacked) layer params get a leading None (layer) dim automatically.
PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings / lm head: vocab TP + FSDP on embed dim
    (r"embed/table$",        ("vocab", "embed_param")),
    (r"head/table$",         ("embed_param", "vocab")),
    # attention projections: FSDP on d_model, TP on head dim
    (r"attn.*/w[qkv]$",      ("embed_param", "heads")),
    (r"attn.*/wo$",          ("heads", "embed_param")),
    (r"attn.*/b[qkv]$",      ("heads",)),
    # MLP: TP on hidden
    (r"mlp.*/w_(gate|up)$",  ("embed_param", "mlp")),
    (r"mlp.*/w_down$",       ("mlp", "embed_param")),
    # MoE: expert parallel + FSDP on d_model (train) / on the ff dim
    # (decode override "moe_ff": "data" — 2D weight-stationary serving,
    # partial-sum psum instead of per-step weight all-gathers)
    (r"moe/router$",         ("embed_param", None)),
    (r"moe/w_(gate|up)$",    ("experts", "embed_param", "moe_ff")),
    (r"moe/w_down$",         ("experts", "moe_ff", "embed_param")),
    # Mamba2 / SSD
    (r"ssm/in_proj$",        ("embed_param", "mlp")),
    (r"ssm/out_proj$",       ("mlp", "embed_param")),
    (r"ssm/conv_w$",         (None, "mlp")),
    (r"ssm/conv_b$",         ("mlp",)),
    (r"ssm/(A_log|D|dt_bias)$", (None,)),
    (r"ssm/norm_w$",         ("mlp",)),
    # norms replicated
    (r"(norm|ln)[^/]*$",     (None,)),
    (r".*",                  None),   # fallback: replicate
)


def spec_for_path(path: str, ndim: int, n_stacked: int = 0) -> P:
    """PartitionSpec for a parameter at pytree `path` with `ndim` dims,
    `n_stacked` leading stacked-layer dims (unsharded).

    A matched rule whose rank EXCEEDS the array's raises: silently
    replicating on a rank mismatch (the pre-PR-5 behaviour) meant a
    sharding-rule typo de-sharded a weight with no signal — the array kept
    training, just all-gathered everywhere. Missing leading dims are still
    filled with None (scanned stacks, vmapped prefixes)."""
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            if axes is None:
                return P()
            body = list(axes)
            lead = [None] * n_stacked
            want = lead + body
            if len(want) < ndim:           # extra leading dims → replicate
                want = [None] * (ndim - len(want)) + want
            if len(want) != ndim:
                raise ValueError(
                    f"sharding rule {pat!r} names {len(body)} dims "
                    f"(+{n_stacked} stacked) for param {path!r}, but the "
                    f"array has ndim={ndim} — a rank-mismatched rule would "
                    f"silently replicate (de-shard) this weight; fix the "
                    f"PARAM_RULES entry or the n_stacked inference")
            return logical_to_spec(want)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, n_stacked_fn=None):
    """Pytree of PartitionSpec matching `params`. `n_stacked_fn(path) → int`
    tells how many leading dims are stacked layers (default: infer — arrays
    under a 'layers'/'blocks' subtree get 1 stacked dim)."""

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        if n_stacked_fn is not None:
            n_stk = n_stacked_fn(ps)
        else:
            n_stk = 0
            if re.search(r"(layers|blocks|groups)/", ps):
                n_stk = 1
            if re.search(r"groups/.*inner/", ps):
                n_stk = 2
        return spec_for_path(ps, leaf.ndim, n_stk)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def named_shardings(params, mesh: Mesh, n_stacked_fn=None):
    specs = param_specs(params, n_stacked_fn)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
