"""Checkpointing: atomic, async, sharding-aware, reshardable.

Fail-stop fault tolerance for the framework (the layer the paper assumes
exists around ABFT):

  * atomic   — writes land in `step_XXXXXX.tmp/` then a single rename; a
               crash mid-save can never corrupt the latest checkpoint;
  * async    — `save_async` snapshots to host (device_get) synchronously
               (cheap) and writes to disk on a background thread, overlapping
               I/O with the next training steps;
  * reshard  — `restore(..., shardings=...)` device_puts each leaf with the
               *target* sharding, so a checkpoint taken on mesh A restarts on
               mesh B (elastic rescale after node loss);
  * retention— keep the newest `keep` checkpoints.

Format: one .npz of raw leaves (bf16 stored as uint16 views) + a JSON
manifest (paths, shapes, logical dtypes, step, metadata).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def visit(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _np_safe(x: np.ndarray) -> Tuple[np.ndarray, str]:
    dt = str(x.dtype)
    if dt == "bfloat16":
        return x.view(np.uint16), "bfloat16"
    return x, dt


def _np_restore(x: np.ndarray, logical: str) -> np.ndarray:
    if logical == "bfloat16":
        return x.view(jnp.bfloat16.dtype)
    return x


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, host_tree: Dict[str, np.ndarray],
               meta: Dict[str, Any]) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        arrays, manifest = {}, {}
        for i, (key, leaf) in enumerate(sorted(host_tree.items())):
            arr, logical = _np_safe(np.asarray(leaf))
            arrays[f"a{i}"] = arr
            manifest[key] = {"idx": f"a{i}", "dtype": logical,
                             "shape": list(arr.shape)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest, "meta": meta}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, tree, meta: Optional[Dict] = None) -> str:
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        return self._write(step, host, meta or {})

    def save_async(self, step: int, tree, meta: Optional[Dict] = None) -> None:
        """Snapshot to host now; write to disk in the background."""
        self.wait()
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        self._thread = threading.Thread(
            target=self._write, args=(step, host, meta or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int, Dict]:
        """Restore into the structure of `template`. `shardings` (matching
        pytree of jax.sharding.Sharding, or None) controls placement — pass
        shardings built for the *current* mesh to reshard elastically."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_template = _flatten(template)
        flat_shardings = _flatten(shardings) if shardings is not None else {}
        restored = {}
        for key, spec in manifest["leaves"].items():
            if key not in flat_template:
                continue
            arr = _np_restore(data[spec["idx"]], spec["dtype"])
            sh = flat_shardings.get(key)
            restored[key] = (jax.device_put(arr, sh) if sh is not None
                             else jnp.asarray(arr))
        missing = set(flat_template) - set(restored)
        if missing:
            raise KeyError(f"checkpoint at step {step} missing leaves: "
                           f"{sorted(missing)[:5]}…")
        # rebuild the pytree in template order
        leaves, treedef = jax.tree.flatten(template)
        keys = list(_flatten(template).keys())
        new_leaves = [restored[k] for k in keys]
        return (jax.tree.unflatten(treedef, new_leaves), manifest["step"],
                manifest.get("meta", {}))
