"""Pallas TPU kernels for the paper's compute hot-spot: GEMM.

Since PR 2 the kernel layer is a *generator*, not a collection of
hand-written bodies — the paper's template-based code generation (§3.2)
grown into a declarative pipeline:

    spec  →  template  →  autotune  →  launch

  1. **spec** (`templates/spec.py`) — a `KernelSpec` names one variant:
     FT level (off/inner/tile/block) × masked-vs-plain dispatch × an
     epilogue chain (bias-add, activation, residual-add from the
     `templates/epilogues.py` registry) × accumulate/output dtypes ×
     **extra outputs** (PR 4 — multi-output kernels: "act_grad" writes the
     derivative of the chain's nonlinear activation at the pre-activation
     as a second VMEM output, computed from the verified/corrected
     accumulator). A `BatchedKernelSpec` (PR 3/4) extends the space with a
     leading batch axis: uniform batched (B, M, K) × (B, K, N) (or a
     shared (K, N) right operand), CSR-style *grouped* dispatch
     (row-sorted token buffer + per-group B selected by a
     scalar-prefetched tile→group map, per-group checksums, ragged group
     edges masked in-kernel — zero capacity padding), and the **tgmm**
     variant — the grouped *transpose* GEMM dw[g] = X_gᵀ G_g of the MoE
     backward, output-stationary over (G, K, N).
  2. **template** (`templates/emit.py`) — `render(spec, …)` composes the
     staged emitter (prologue / K-loop MAC + running checksums / fused
     epilogue + writeback) into ONE Pallas kernel body; `render_tgmm` is
     the one structurally different body (its grid walks row tiles as the
     reduction axis; the accumulator + per-group checksums flush when the
     scalar-prefetched group id changes between consecutive tiles). Fused
     epilogues apply to the VMEM-resident accumulator before the single
     HBM writeback, with linear ops folded into the ABFT checksum
     comparison so detection/correction still works post-epilogue — and
     extra outputs are written from the *corrected* accumulator, so a
     forward SEU never reaches a saved residual.
  3. **autotune** (`autotune.py` + `search.py` + `tune_cache.py`) — the
     candidate search enumerates MXU-aligned tiles under the variant-aware
     VMEM model, now owned by the spec (`KernelSpec.vmem_bytes`): fused
     epilogues add aux-operand buffers, extra outputs add their (bm, bn)
     output block, and the tgmm variant swaps in its transposed geometry
     ((bm,bk)+(bm,bn) operand tiles, (bk,bn) accumulator, bk-row checksum
     scratch). `search.predicted_time_s` models each the same way (the
     tgmm branch streams X once per N-block column, G once per K-block
     row, writes dw once per group in f32, and charges the G·(bm-1)
     reduction-dim alignment rows). Cache keys include the variant
     (`KernelSpec.variant_key()` — e.g. ``/v_tgmm``, ``/v_xo_act_grad``)
     plus the pow2-bucketed ``/b_*``/``/g_*`` count component; existing
     keys are unchanged so older caches stay valid.
  4. **launch** (`templates/registry.py`, `ops.py`) — `ops.gemm_call(spec,
     a, b, …)` is the 2-D front door (multi-output specs return
     ((C, extra…), report)) and `ops.grouped_gemm_call` its
     batched/grouped sibling, rank-dispatching: 3-D a → uniform batched;
     2-D a + 3-D b + group_ids → grouped; 2-D a + 2-D b + group_ids +
     n_groups → tgmm. `ops.matmul` / `ops.ft_matmul_report` /
     `ops.fused_matmul(..., save_act_grad=True)` are thin specializations;
     `core.ft_batched_dot` / `core.ft_grouped_matmul` / `core.ft_dot_fused`
     are the policy-level fronts the model zoo calls — since PR 4 their
     custom_vjps keep the *backward* GEMMs on registry kernels too
     (dx/dw/dbuf on the 2-D/grouped kernels, the grouped dw on tgmm, and
     ft_dot_fused consuming the saved act_grad residual instead of
     recomputing the pre-activation GEMM).

Worked example — protecting an MoE expert FFN end to end, BOTH directions
(what `models/moe.py` + `core.ft_grouped_matmul` run)::

    import jax, jax.numpy as jnp
    from repro.core import ft_grouped_matmul
    from repro.core.policy import FTConfig

    # tokens (T, d) each routed to one of G experts; weights (G, d, f).
    ft = FTConfig(level="block", backend="pallas")
    loss = lambda w: jnp.sum(ft_grouped_matmul(tokens, w, expert_ids,
                                               ft=ft))
    dw = jax.grad(loss)(w_gate)
    # forward: the CSR-style grouped kernel (per-group checksums).
    # backward: d_buf reruns the grouped kernel on wᵀ; dw runs the
    # OUTPUT-STATIONARY TGMM KERNEL — grid walks the buffer's row tiles,
    # dw[g] accumulates in VMEM while tiles of group g stream by, and the
    # per-group checksums (col (X_g e)ᵀG_g, row X_gᵀ(G_g e)) verify and
    # branchlessly correct at the group-boundary flush. One SEU per
    # (group × output block) is corrected; empty groups return exact 0.

    # Tuning the tgmm variant explicitly:
    #   spec = templates.BatchedKernelSpec(ft_level="block", tgmm=True)
    #   autotune.best_params(T, f, d, 4, ft_level="block", spec=spec,
    #                        groups=G)      # cache key gains /v_tgmm/g_*
    # Multi-output fused forward (what ft_dot_fused's vjp uses):
    #   (y, actp), rep = ops.fused_matmul(x, w, bias=b, act="gelu",
    #                                     ft=ft, save_act_grad=True)
    # `benchmarks/backward_path.py` reports the fraction of train-step
    # GEMM FLOPs under in-kernel ABFT (and gates it ≥ 0.99 in CI).

Worked example — flash attention protected in BOTH directions (PR 5; what
`models.blocks.chunked_attention` runs on the pallas backend)::

    from repro.kernels import ops
    # forward: ONE launch; save_stats adds the per-row (m, l) softmax
    # statistics — the saved residual of the dedicated backward.
    out, m, l, rep = ops.flash_ft(q, k, v, ft=ft, causal=True,
                                  n_rep=n_rep, save_stats=True)
    # backward: TWO launches (dQ; dK/dV) — zero oracle recompute. The four
    # backward GEMMs (dP=g·Vᵀ, dV=Pᵀ·g, dQ=dS·K, dK=dSᵀ·Q) and the S
    # recompute all carry in-kernel checksums + branchless correction.
    dq, dk, dv, rep_dq, rep_dkv = ops.flash_ft_bwd(
        q, k, v, out, m, l, g, ft=ft, causal=True, n_rep=n_rep)

    # Tuning the flash variants explicitly — each direction owns a cache
    # key (existing keys unchanged):
    #   spec = templates.FlashKernelSpec(ft_level="block", direction="dq",
    #                                    dh=128)
    #   autotune.best_params(Sq, Skv, 128, 4, ft_level="block", spec=spec,
    #                        batch=B*H)    # key gains /v_flashbwd_dq/b_*
    # (bm, bn) come back as the (stationary, streamed) seq blocks; the
    # head dim never tiles (spec.dh, not bk).

    # Worked injection campaign — stochastic SEUs INSIDE the kernels (the
    # MPGemmFI lesson: the injector must live in the kernel it measures;
    # a campaign whose jaxpr falls back to a jnp oracle measures nothing):
    #   ftc = FTConfig(level="block", backend="pallas", inject_rate=1.0)
    #   out, rep = ops.flash_ft(q, k, v, ft=ftc, key=jax.random.PRNGKey(0))
    #   assert float(rep[..., 0].sum()) > 0          # detections happened
    #   # ... and per-GEMM deterministic SEUs for conformance tests:
    #   ops.flash_ft_bwd(..., inject=InjectionSpec(row=5, col=9,
    #                    magnitude=777.0, k_step=1), inj_target="dk",
    #                    inj_bh=1, inj_blk=1)
    # `tools.audit.pallas_call_names` asserts the campaign's jaxpr contains
    # the flash kernels (tests/test_flash_backward.py).

Worked example — per-site FT telemetry end to end (PR 8; the observability
layer over everything above)::

    from repro.core import telemetry
    from repro.models.blocks import Ctx
    from repro.tools import metrics

    # 1. Attribution: every Ctx-routed GEMM carries a structured site
    #    label ("wq", "moe_gate", "attn_flash", …); a trace-time registry
    #    maps labels to stable column ids of the report's fixed-width site
    #    matrices, and the layer scan places each layer's rows at
    #    1 + layer_idx (row 0 = unlayered). The SCALAR totals are reduced
    #    exactly as before PR 8 — sum(site_detected) == detected,
    #    bit-identical to the global triple.
    ctx = Ctx(ft=ftc, key=key, inject_sites=("moe_gate",))  # filtered SEUs
    loss, mets = mod.loss_fn(params, batch, cfg, ctx)
    telemetry.site_rows(mets["ft"])   # [{site, layer, detected, …}, …]

    # 2. Sink: one host-side step boundary; JSONL/stdout/in-memory
    #    emitters; the storm detector rides along.
    sink = metrics.MetricsSink([metrics.JsonlEmitter("metrics.jsonl")])
    sink.on_storm(lambda a: print("SDC storm:", a.site, a.rate))
    sink.record_ft(mets["ft"], step=step); sink.step_end(step, loss=loss)

    # Zero-cost claim: the site matrices ride the existing report pytree —
    # benchmarks/telemetry_overhead.py gates ZERO extra pallas launches vs
    # telemetry.site_attribution(False), and runs the single-site campaign
    # (detections attribute to exactly the injected site) in CI.
    # Spans: kernel dispatch fronts wear @traced("kernel/…") name scopes;
    # `python -m benchmarks.run --trace-dir d/` dumps a Perfetto trace.

Worked example — paged ragged flash decode (PR 9; what the serving
engine's `transformer.paged_decode_step` launches per layer)::

    from repro.kernels import ops
    from repro.train import kv_cache as kvc

    # KV lives in a page pool (n_pages, KVH, page, dh) — ONE page is ONE
    # kv block of the kernel, streamed through a scalar-prefetched page
    # table; lengths int32[B] are per-row ragged (a slot at 17 tokens and
    # a slot at 4096 share the launch, each masked at ITS length; dead
    # slots ride the reserved null page and write exact zeros).
    out, rep = ops.flash_ft_decode(q, k_pages, v_pages, lengths,
                                   page_table, ft=ft)
    # q (B, H, dh) with GQA folded to grid rows g = slot * KVH + kv_head
    # (n_rep query heads per row — KV never repeat-materialized); rep
    # (B*KVH, 1, 8) carries [det, corr, row, col, mag, max_res, tau, k].

    # Tuning the decode variant — its streamed block IS the page size, so
    # the autotuned bn feeds kv_cache.plan_pages and the cache layout and
    # the kernel tile stay ONE number:
    #   spec = templates.FlashKernelSpec(ft_level="block",
    #                                    direction="decode", dh=128)
    #   p = autotune.best_params(bq, max_len, 128, 4, ft_level="block",
    #                            spec=spec, batch=B*KVH)
    #   plan = kvc.plan_pages(cfg, ft, n_slots=B, max_len=max_len)
    #   assert plan.page_size == p.bn     # gather granularity ≡ kv block
    # (bq is the sublane-padded n_rep — decode's stationary axis is the
    # GQA group, not a seq block; the head dim never tiles.)
    # Deterministic SEUs address a grid row: ops.flash_ft_decode(...,
    # spec=InjectionSpec(row=1, col=7, k_step=1, magnitude=777.0),
    # inj_g=slot * KVH + kv_head); correction is bit-exact (the PV
    # accumulator is verified before the output rescale) —
    # tests/test_serve_engine.py gates this on every PR.

Worked example — per-site adaptive FT policy (PR 10; how a mixed-level
campaign picks WHICH kernels pay for protection)::

    from repro.core import policy
    from repro.core.policy import FTPolicy, ONLINE_BLOCK, OFFLINE_DETECT

    # 1. A policy is ordered (site-glob → FTConfig) rules + a default;
    #    every dispatch front above resolves its own `site=` label, so a
    #    single Ctx.ft drives different kernel variants per call site.
    pol = FTPolicy(rules=(("moe_*", ONLINE_BLOCK),
                          ("attn_*", OFFLINE_DETECT.replace(verify="final"))),
                   default=ONLINE_BLOCK)
    ctx = Ctx(ft=pol, key=key)        # a bare FTConfig still works: a
                                      # uniform policy is bit-identical,
                                      # tune-cache keys included.

    # 2. The static planner prices each site on the SAME roofline model
    #    the autotuner scores tiles with (`search.ft_plan_cost`):
    #    memory-bound sites absorb checksum FLOPs inside the bandwidth
    #    bound for free; compute-bound projections pay ~2K/(M·N) extra.
    with policy.record_site_costs() as costs:     # jax.eval_shape — no
        jax.eval_shape(loss_fn, params, batch)    # compute, full size OK
    plan = policy.plan_ft(costs.values(), budget_frac=0.01)
    print(plan.coverage, plan.overhead_frac)      # e.g. 1.00, 0.003
    ctx = Ctx(ft=plan.policy, key=key)

    # 3. The runtime loop closure: a StormDetector alert PROMOTES the
    #    storming site (detect→correct, final→step) for a cool-down
    #    window; current_policy() is a fresh frozen policy, so the jitted
    #    step retraces exactly when the resolved level changes.
    esc = policy.EscalationController(plan.policy, cooldown_steps=64)
    esc.attach(sink)                  # MetricsSink.on_storm / StormDetector
    loss = train_step(params, batch, esc.current_policy()); esc.step_end(s)

    # Since PR 10 the in-kernel stochastic SEU hook covers the ENTIRE
    # template family — 2-D, batched, grouped, and tgmm bodies, not just
    # flash — so whole-model campaigns on the pallas backend run with
    # zero jnp-injector call sites: pass key= to any front above with
    # ft.inject_rate > 0 (rate 0 with a key stays bit-identical).
    # `benchmarks/ft_plan.py` prints the coverage-vs-overhead Pareto
    # curve and gates planned < uniform-correct at ≥95% coverage in CI;
    # render a dumped plan with
    # `python -m repro.tools.report --policy benchmarks/ft_plan_moe.json`.

The epilogue extension hook is unchanged (register an `EpilogueOp` — give
it a ``grad`` rule and it can also ride the act_grad multi-output variant
— see `templates/epilogues.py`); batched/grouped specs accept aux-free
chains (activations); tgmm is epilogue-free.

Other modules:

  gemm.py     -- plain/masked non-FT entries + the naive ladder rung (§3)
  ftgemm.py   -- fused online-ABFT GEMM entry, 3 granularities (§4)
  flashft.py  -- flash attention with fused ABFT + ragged seq masking
                 (causal∧kv-edge mask on true lengths — ragged cross-length
                 causal runs on fitted blocks, no padded fallback) + GQA
                 via K/V index maps (n_rep — KV never repeat-materialized);
                 since PR 4 this is the training attention core on the
                 pallas backend (`models.blocks.chunked_attention`), and
                 since PR 5 its BACKWARD is first-class too: saved (m, l)
                 statistics, dedicated dQ/dK/dV kernels, degenerate-row
                 zeroing, and the in-kernel stochastic SEU hook
                 (`templates.emit.stochastic_seu`) for fault campaigns;
                 since PR 9 the paged DECODE direction: one query row per
                 GQA group, KV streamed page-by-page through a
                 scalar-prefetched page table with per-slot ragged lengths
                 (the serving engine's per-layer attention launch)
  grouped/    -- batched & grouped subsystem (layout + dispatch, PR 3;
                 tgmm backward-dw kernel, PR 4)
  ops.py      -- dispatching front doors (padding, autotune, interpret)
  ref.py      -- pure-jnp oracles (incl. the unfused epilogue composition)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated with interpret=True on CPU.
"""
from . import autotune, grouped, ops, ref, templates

__all__ = ["autotune", "grouped", "ops", "ref", "templates"]
