"""Pallas TPU kernels for the paper's compute hot-spot: GEMM.

Since PR 2 the kernel layer is a *generator*, not a collection of
hand-written bodies — the paper's template-based code generation (§3.2)
grown into a declarative pipeline:

    spec  →  template  →  autotune  →  launch

  1. **spec** (`templates/spec.py`) — a `KernelSpec` names one variant:
     FT level (off/inner/tile/block) × masked-vs-plain dispatch × an
     epilogue chain (bias-add, activation, residual-add from the
     `templates/epilogues.py` registry) × accumulate/output dtypes.
  2. **template** (`templates/emit.py`) — `render(spec, …)` composes the
     staged emitter (prologue / K-loop MAC + running checksums / fused
     epilogue + writeback) into ONE Pallas kernel body. The four formerly
     duplicated plain/masked × FT/non-FT bodies are all points in this
     space; fused epilogues apply to the VMEM-resident accumulator before
     the single HBM writeback, with linear ops folded into the ABFT
     checksum comparison so detection/correction still works post-epilogue.
  3. **autotune** (`autotune.py` + `search.py` + `tune_cache.py`) — the
     candidate search enumerates MXU-aligned tiles under the
     variant-aware VMEM model (fused epilogues add aux-operand buffers and
     shift roofline intensity), and the persistent cache keys include the
     variant (`KernelSpec.variant_key()`).
  4. **launch** (`templates/registry.py`, `ops.py`) — `ops.gemm_call(spec,
     a, b, …)` is the front door: variant-aware params, ragged masked
     dispatch, operand padding, interpret fallback off-TPU.
     `ops.matmul` / `ops.ft_matmul_report` / `ops.fused_matmul` are thin
     specializations; `gemm.py` / `ftgemm.py` keep their public signatures
     as registry lookups.

Worked example — registering a new epilogue op and running it::

    from repro.kernels.templates import epilogues, KernelSpec
    from repro.kernels import ops

    # 1. register: a leaky-relu epilogue (elementwise → aux=None;
    #    nonlinear → linear=False, so it ends the checksum-fold prefix)
    epilogues.register(epilogues.EpilogueOp(
        "leaky_relu", linear=False,
        apply=lambda y, aux: jnp.where(y > 0, y, 0.01 * y)))

    # 2. spec it — chains compose; tuning auto-keys the new variant
    spec = KernelSpec(ft_level="block", epilogue=("bias", "leaky_relu"))

    # 3. run: one kernel, bias+activation fused, online ABFT verifying
    #    post-bias (the linear prefix folds into the comparison)
    out, report = ops.gemm_call(spec, a, b, bias=bias)

    Linear ops with an aux operand additionally provide a `fold` rule
    (see `epilogues._bias_fold`) so ABFT verification can run after them.

Other modules:

  gemm.py     -- plain/masked non-FT entries + the naive ladder rung (§3)
  ftgemm.py   -- fused online-ABFT GEMM entry, 3 granularities (§4)
  flashft.py  -- flash attention with fused ABFT + ragged seq masking
  ops.py      -- dispatching front door (padding, autotune, interpret)
  ref.py      -- pure-jnp oracles (incl. the unfused epilogue composition)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated with interpret=True on CPU.
"""
from . import autotune, ops, ref, templates

__all__ = ["autotune", "ops", "ref", "templates"]
