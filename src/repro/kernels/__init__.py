"""Pallas TPU kernels for the paper's compute hot-spot: GEMM.

  gemm.py     -- baseline high-performance tiled GEMM (paper section 3)
  ftgemm.py   -- fused online-ABFT GEMM, thread/warp/threadblock analogues (section 4)
  ops.py      -- jit'd wrappers (padding, autotuned params, CPU interpret)
  ref.py      -- pure-jnp oracles
  autotune.py -- template/codegen parameter selection (section 3.2, Table 1 analogue)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are validated
with interpret=True on CPU.
"""
from . import autotune, ops, ref

__all__ = ["autotune", "ops", "ref"]
