"""Pallas TPU kernels for the paper's compute hot-spot: GEMM.

Since PR 2 the kernel layer is a *generator*, not a collection of
hand-written bodies — the paper's template-based code generation (§3.2)
grown into a declarative pipeline:

    spec  →  template  →  autotune  →  launch

  1. **spec** (`templates/spec.py`) — a `KernelSpec` names one variant:
     FT level (off/inner/tile/block) × masked-vs-plain dispatch × an
     epilogue chain (bias-add, activation, residual-add from the
     `templates/epilogues.py` registry) × accumulate/output dtypes.
     Since PR 3 a `BatchedKernelSpec` extends the space with a leading
     batch axis: uniform batched (B, M, K) × (B, K, N) (or a shared (K, N)
     right operand) and CSR-style *grouped* dispatch (row-sorted token
     buffer + per-group B selected by a scalar-prefetched tile→group map,
     per-group checksums, ragged group edges masked in-kernel via
     per-group row bounds — zero capacity padding).
  2. **template** (`templates/emit.py`) — `render(spec, …)` composes the
     staged emitter (prologue / K-loop MAC + running checksums / fused
     epilogue + writeback) into ONE Pallas kernel body. The four formerly
     duplicated plain/masked × FT/non-FT bodies, every fused-epilogue
     chain, and the batched/grouped bodies are all points in this space;
     fused epilogues apply to the VMEM-resident accumulator before the
     single HBM writeback, with linear ops folded into the ABFT checksum
     comparison so detection/correction still works post-epilogue.
  3. **autotune** (`autotune.py` + `search.py` + `tune_cache.py`) — the
     candidate search enumerates MXU-aligned tiles under the
     variant-aware VMEM model (fused epilogues add aux-operand buffers;
     grouped dispatch adds its scalar metadata and a per-group
     row-alignment penalty that steers bm), and the persistent cache keys
     include the variant (`KernelSpec.variant_key()`) plus a
     power-of-two-bucketed batch/group-count component (``/b_*`` /
     ``/g_*`` — `best_params(..., batch=…, groups=…)`); 2-D keys are
     unchanged so older caches stay valid.
  4. **launch** (`templates/registry.py`, `ops.py`) — `ops.gemm_call(spec,
     a, b, …)` is the 2-D front door and `ops.grouped_gemm_call` its
     batched/grouped sibling (rank-dispatching: 3-D a → uniform batched,
     2-D a + 3-D b + group_ids → grouped): variant-aware params, ragged
     masked dispatch, operand padding, interpret fallback off-TPU.
     `ops.matmul` / `ops.ft_matmul_report` / `ops.fused_matmul` are thin
     specializations; `gemm.py` / `ftgemm.py` keep their public signatures
     as registry lookups; `core.ft_batched_dot` / `core.ft_grouped_matmul`
     are the policy-level fronts the model zoo calls.

Worked example — a grouped MoE expert FFN (what `models/moe.py` runs)::

    import jax.numpy as jnp
    from repro.core import ft_grouped_matmul
    from repro.core.policy import FTConfig

    # tokens (T, d) each routed to one of G experts; weights (G, d, f).
    # No capacity, no dropped tokens: rows are scattered into a
    # group-sorted buffer whose groups start on row-tile boundaries
    # (kernels/grouped/layout.py), so the ≤ G·(bm-1) alignment rows are
    # the ONLY padding and every output block is wholly one expert's —
    # an SEU in expert e's rows is detected, located, and corrected
    # inside e's blocks and can never contaminate a neighbor.
    ft = FTConfig(level="block", backend="pallas")
    h = ft_grouped_matmul(tokens, w_gate, expert_ids, ft=ft)

    # Same variant space underneath — to tune it explicitly:
    #   spec = templates.BatchedKernelSpec(ft_level="block", grouped=True)
    #   autotune.best_params(T, f, d, 4, ft_level="block", spec=spec,
    #                        groups=G)        # cache key gains /g_<G·pow2>
    # and `benchmarks/tune_campaign.py` regenerates/diffs the persistent
    # cache per device kind (checked-in baseline: benchmarks/tuned/).

The epilogue extension hook is unchanged (register an `EpilogueOp`, spec
it, run — see `templates/epilogues.py`); batched/grouped specs accept
aux-free chains (activations).

Other modules:

  gemm.py     -- plain/masked non-FT entries + the naive ladder rung (§3)
  ftgemm.py   -- fused online-ABFT GEMM entry, 3 granularities (§4)
  flashft.py  -- flash attention with fused ABFT + ragged seq masking
                 (causal∧kv-edge mask on true lengths — ragged cross-length
                 causal runs on fitted blocks, no padded fallback)
  grouped/    -- batched & grouped subsystem (layout + dispatch, PR 3)
  ops.py      -- dispatching front doors (padding, autotune, interpret)
  ref.py      -- pure-jnp oracles (incl. the unfused epilogue composition)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated with interpret=True on CPU.
"""
from . import autotune, grouped, ops, ref, templates

__all__ = ["autotune", "grouped", "ops", "ref", "templates"]
