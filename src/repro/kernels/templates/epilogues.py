"""Registered epilogue operations for the kernel-template subsystem.

An *epilogue op* is one step of the fused post-GEMM chain a `KernelSpec`
requests (bias-add, activation, residual-add, …). Each op carries everything
the emitter (`templates.emit`) and the autotuner (`kernels.search`) need to
reason about it:

  * ``apply(y, aux)``       — the math, on the f32 accumulator tile. The
    same callable is used by the generated Pallas kernel body and by the
    pure-jnp oracle (`kernels.ref.fused_matmul_ref`), so fused and unfused
    compositions agree by construction.
  * ``linear``              — whether the op commutes with the Huang–Abraham
    checksum algebra. Linear ops in the leading prefix of a chain are folded
    *into* the final checksum comparison (`fold`), so ABFT verification runs
    post-epilogue; the first nonlinear op ends the foldable prefix and
    verification happens just before it (the latest point where the linear
    invariant still holds — same reasoning as flashft verifying scores
    before softmax).
  * ``fold(colck, rowck, aux, rows)`` — the checksum shift of a linear op:
    returns the (column, row) checksums of ``apply(y, aux)`` given those of
    ``y``. ``rows`` is the static tile row count (every tile row receives a
    broadcast bias, including masked padding rows — zero-padded aux operands
    keep the algebra exact on ragged tiles).
  * ``aux``                 — the streamed operand the op needs: ``None``
    (pure elementwise), ``"vector"`` (a (1, bn) slice of an N-vector, e.g.
    bias), or ``"tile"`` (a (bm, bn) slice of an (M, N) array, e.g.
    residual).
  * ``grad(y)``             — for nonlinear elementwise ops: the derivative
    d apply/d y evaluated at the *pre-activation* ``y``. This is what the
    multi-output "act_grad" kernel variant writes as a second VMEM output
    (PR 4): the fused forward kernel emits ``act'(preact)`` alongside the
    activated output so the backward pass consumes a saved residual instead
    of recomputing the pre-activation GEMM. Ops without a ``grad`` simply
    cannot ride the act_grad variant.

New ops are added with `register` — see the worked example in the
`repro.kernels` package docstring.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import jax.numpy as jnp

_SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)


@dataclasses.dataclass(frozen=True)
class EpilogueOp:
    name: str
    linear: bool
    apply: Callable            # (y, aux) -> y'   (aux is None for elementwise)
    aux: Optional[str] = None  # None | "vector" | "tile"
    fold: Optional[Callable] = None  # (colck, rowck, aux, rows) -> (colck, rowck)
    grad: Optional[Callable] = None  # (y) -> d apply/d y  (nonlinear elementwise)

    def __post_init__(self):
        if self.linear and self.fold is None:
            raise ValueError(
                f"linear epilogue '{self.name}' needs a checksum fold rule "
                f"(block-mode FT folds every linear-prefix op into the "
                f"final comparison); register ops without one as "
                f"linear=False to end the foldable prefix instead")


REGISTRY: Dict[str, EpilogueOp] = {}


def register(op: EpilogueOp, overwrite: bool = False) -> EpilogueOp:
    """Add an epilogue op to the registry (it becomes legal in any
    `KernelSpec.epilogue` chain and is picked up by the conformance sweep in
    tests/test_templates.py)."""
    if op.name in REGISTRY and not overwrite:
        raise ValueError(f"epilogue '{op.name}' already registered")
    REGISTRY[op.name] = op
    return op


def get(name: str) -> EpilogueOp:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown epilogue '{name}'; registered: "
                       f"{sorted(REGISTRY)}") from None


def names():
    return sorted(REGISTRY)


# ---------------------------------------------------------------------------
# activations (elementwise, nonlinear)
# ---------------------------------------------------------------------------
# Explicit formulas (not jax.nn.*) so the generated kernel body lowers
# through Mosaic with no surprises and the oracle uses bit-identical math.

def _relu(y, aux):
    return jnp.maximum(y, 0.0)


def _relu_grad(y):
    return (y > 0.0).astype(y.dtype)


def _silu(y, aux):
    return y * (1.0 / (1.0 + jnp.exp(-y)))


def _silu_grad(y):
    s = 1.0 / (1.0 + jnp.exp(-y))
    return s * (1.0 + y * (1.0 - s))


def _gelu(y, aux):
    # tanh approximation — matches jax.nn.gelu(approximate=True).
    return 0.5 * y * (1.0 + jnp.tanh(_SQRT_2_OVER_PI
                                     * (y + 0.044715 * y * y * y)))


def _gelu_grad(y):
    u = _SQRT_2_OVER_PI * (y + 0.044715 * y * y * y)
    t = jnp.tanh(u)
    du = _SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * y * y)
    return 0.5 * (1.0 + t) + 0.5 * y * (1.0 - t * t) * du


def activation(name: str) -> Callable:
    """The unary activation function of a registered elementwise op —
    shared by the jnp ABFT path (core.ft_gemm) and the oracles."""
    op = get(name)
    if op.aux is not None:
        raise ValueError(f"'{name}' is not an elementwise activation")
    return lambda y: op.apply(y, None)


def activation_grad(name: str) -> Callable:
    """The derivative of a registered elementwise activation — the math the
    "act_grad" multi-output variant stores and the jnp backward consumes."""
    op = get(name)
    if op.aux is not None or op.grad is None:
        raise ValueError(f"'{name}' has no registered derivative (needed "
                         f"for the act_grad multi-output variant)")
    return op.grad


# ---------------------------------------------------------------------------
# linear ops with aux operands + their checksum folds
# ---------------------------------------------------------------------------

def _bias_apply(y, aux):
    return y + aux                      # aux: (1, bn), broadcasts over rows


def _bias_fold(colck, rowck, aux, rows):
    # Every tile row gains aux → column sums shift by rows·aux, row sums by
    # Σ aux (zero over padded cols because ops.py zero-pads the vector).
    return colck + float(rows) * aux, rowck + jnp.sum(aux)


def _residual_apply(y, aux):
    return y + aux                      # aux: (bm, bn)


def _residual_fold(colck, rowck, aux, rows):
    return (colck + jnp.sum(aux, axis=0, keepdims=True),
            rowck + jnp.sum(aux, axis=1, keepdims=True))


register(EpilogueOp("bias", linear=True, apply=_bias_apply, aux="vector",
                    fold=_bias_fold))
register(EpilogueOp("residual", linear=True, apply=_residual_apply,
                    aux="tile", fold=_residual_fold))
register(EpilogueOp("relu", linear=False, apply=_relu, grad=_relu_grad))
register(EpilogueOp("silu", linear=False, apply=_silu, grad=_silu_grad))
register(EpilogueOp("gelu", linear=False, apply=_gelu, grad=_gelu_grad))


def reference_apply(chain, y, *, bias=None, residual=None):
    """Unfused oracle composition: apply the chain to a full (M, N) f32
    array, pulling aux operands by kind. Tests compare every generated
    kernel variant against this."""
    aux_of = {"vector": bias, "tile": residual}
    for name in chain:
        op = get(name)
        aux = aux_of[op.aux] if op.aux is not None else None
        if op.aux is not None and aux is None:
            raise ValueError(f"epilogue '{name}' needs a {op.aux} operand")
        y = op.apply(y, None if aux is None else aux.astype(jnp.float32))
    return y
