"""Kernel-body emitter: `render(spec, …)` → one parameterized Pallas kernel.

This is the template engine the paper's code generator maps onto (§3.2): a
single source body, specialized at trace time by a `KernelSpec`, replaces
the four hand-duplicated plain/masked × FT/non-FT kernels the repo used to
carry. The body is composed of stages:

  prologue  — scratch init on the first k-step (accumulator, running
              checksums, operand-magnitude trackers, report block);
  mac       — operand load (+ ragged masking from scalar-prefetched true
              dims), the MXU MAC, the emulated-SEU hook, and the running
              column/row checksum updates for the requested FT level (the
              paper's "fuse ABFT memory ops with the prefetching stage" —
              checksums ride the operand tiles already in VMEM);
  verify    — per-k-step detection/location/branchless correction
              (verify="step") on intermediate steps;
  epilogue  — on the last k-step: the *linear prefix* of the epilogue chain
              is applied to the accumulator and folded into the checksum
              comparison (so the final verification — and hence detection
              AND correction — runs post-epilogue), then the nonlinear
              suffix, the out-dtype cast, and the single HBM writeback.

Fusing the chain here is what keeps ABFT (and bias/activation/residual)
from costing a second HBM round-trip over C — FT-BLAS's fusion argument
applied to the whole epilogue.

Layout of the generated kernel's positional refs (see `Layout`):

    [inj_idx, inj_mag, dims]?  scalar prefetch   (FT: all 3; masked-only: dims)
    [gid, row_end]?            scalar prefetch   (grouped specs only)
    a, b [, bias][, residual]  VMEM inputs
    out [, extra…][, report]   VMEM outputs
    acc [, colck, rowck]       VMEM scratch
    [amax, bmax]               SMEM scratch      (FT threshold trackers)

Multi-output specs (``spec.extra_outputs``, PR 4) add derived outputs
between C and the report: "act_grad" writes the derivative of the chain's
nonlinear activation evaluated at the (verified, corrected) pre-activation
accumulator — the saved residual `core.ft_dot_fused`'s backward consumes
instead of recomputing the pre-activation GEMM.

Batched specs (`BatchedKernelSpec`) reuse this body: uniform batched adds a
leading batch grid axis (a/b/out/report blocks gain a unit leading dim and
the 5-wide [enable, batch, row, col, k_step] injection layout); grouped
keeps the 3-D grid but reads its owning group from the scalar-prefetched
tile→group map and masks rows past the group's `row_end` — per-group
checksums and correction fall out of per-block state, since row tiles
never span groups. The output-stationary tgmm variant (`render_tgmm`) is
the one structurally different body: its grid walks row tiles as the
reduction axis and flushes per group.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import epilogues
from .spec import KernelSpec

F32EPS = float(jnp.finfo(jnp.float32).eps)
REPORT_WIDTH = 8
MXU = 128


def _iota2(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


@dataclasses.dataclass(frozen=True)
class Layout:
    """Ref-list layout of a rendered kernel — shared contract between the
    emitter (which unpacks) and the registry (which builds the specs)."""
    n_prefetch: int
    n_inputs: int
    n_outputs: int
    n_vmem_scratch: int
    n_smem_scratch: int


def layout(spec: KernelSpec) -> Layout:
    if spec.tgmm:
        # [inj?, mag?, rng?, dims, gid, row_end] | x, g | dw [, rep] |
        # acc [, colck, rowck] | [amax, bmax, t0]
        if spec.ft:
            return Layout(6, 2, 2, 3, 3)
        return Layout(3, 2, 1, 1, 0)
    aux = int(spec.needs_bias) + int(spec.needs_residual)
    grp = 2 if spec.grouped else 0      # gid[num_tiles], row_end[G]
    nxo = len(spec.extra_outputs)
    if spec.ft:
        # FT scalar prefetch: [inj_idx, inj_mag, rng, dims] — rng is the
        # PR-10 stochastic-SEU seed triple, same slot order as the flash
        # family ([inj, mag, rng, …]).
        return Layout(4 + grp, 2 + aux, 2 + nxo, 3, 2)
    return Layout((1 if spec.masked else 0) + grp, 2 + aux, 1 + nxo, 1, 0)


# ---------------------------------------------------------------------------
# shared FT primitives (moved from kernels/ftgemm.py — single-sourced here)
# ---------------------------------------------------------------------------

def _locate_correct_full(acc, d_col, d_row, tau, corrects, bm, bn):
    """Locate a single error from checksum residuals and (optionally) apply
    the branchless correction. Returns (acc', detected, magnitude, row, col)."""
    dc = d_col[0, :]
    dr = d_row[:, 0]
    col = jnp.argmax(jnp.abs(dc)).astype(jnp.int32)
    row = jnp.argmax(jnp.abs(dr)).astype(jnp.int32)
    mag_c = jnp.max(jnp.abs(dc))
    mag_r = jnp.max(jnp.abs(dr))
    detected = jnp.maximum(mag_c, mag_r) > tau
    # Canonical magnitude from the column residual (signed).
    mag = jnp.where(detected, jnp.sum(jnp.where(
        jax.lax.iota(jnp.int32, bn) == col, dc, 0.0)), 0.0)
    if corrects:
        hit = ((_iota2((bm, bn), 0) == row) & (_iota2((bm, bn), 1) == col)
               & detected)
        acc = acc - jnp.where(hit, mag, 0.0)
    return acc, detected, mag, row, col


def _record(rep_ref, det, mag, row_g, col_g, d_col, d_row, tau, k_elapsed,
            corrects):
    # The report block is (1, 1, W) for 2-D/grouped launches and
    # (1, 1, 1, W) for batched ones — index the leading unit dims away.
    z = (0,) * (len(rep_ref.shape) - 1)
    detf = det.astype(jnp.float32)
    resid = jnp.maximum(jnp.max(jnp.abs(d_col)), jnp.max(jnp.abs(d_row)))
    rep_ref[z + (0,)] += detf
    rep_ref[z + (1,)] += detf if corrects else 0.0
    rep_ref[z + (2,)] = jnp.where(det, row_g.astype(jnp.float32),
                                  rep_ref[z + (2,)])
    rep_ref[z + (3,)] = jnp.where(det, col_g.astype(jnp.float32),
                                  rep_ref[z + (3,)])
    rep_ref[z + (4,)] = jnp.where(det, mag, rep_ref[z + (4,)])
    rep_ref[z + (5,)] = jnp.maximum(rep_ref[z + (5,)], resid)
    rep_ref[z + (6,)] = tau
    rep_ref[z + (7,)] = k_elapsed


# ---------------------------------------------------------------------------
# in-kernel stochastic SEU hook (PR 5)
# ---------------------------------------------------------------------------
#
# Stochastic (`ft.inject_rate`-driven) fault campaigns used to live only in
# the jnp paths (`core.fault_injection.Injector`), so forcing a campaign
# onto a Pallas kernel silently dropped the injection — the MPGemmFI
# failure mode where the injector and the kernel disagree and a "campaign"
# measures a clean run. These helpers are the in-kernel counterpart: a
# counter-based splitmix32-style hash (deterministic per grid cell, same
# bits under interpret and compiled modes — unlike the hardware
# `pltpu.prng_*` primitives, which have no interpret-mode lowering) seeded
# from the campaign key via two scalar-prefetched int32 words.


#: Per-template salts for the GEMM-template family (the flash family owns
#: 0x51–0x54 in `kernels.flashft`): each template body draws an independent
#: SEU stream from one campaign key.
SALT_GEMM2D = 0x55
SALT_BATCHED = 0x56
SALT_TGMM = 0x57


def _mix32(x):
    """splitmix32 finalizer on uint32 — full-avalanche integer hash."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    return x ^ (x >> jnp.uint32(16))


def stochastic_seu(rng_ref, salt: int, block_uid, n_steps,
                   bm: int, bn: int, rate: float):
    """Draw one potential SEU for the output block identified by
    ``block_uid`` (an int32 scalar unique per stationary output block).

    rng_ref — scalar-prefetch int32[3] = [enable, seed0, seed1] (the seeds
    derive from the campaign key; enable=0 ⇒ never hits). ``salt`` is a
    static per-kernel/per-GEMM discriminator so the forward and each
    backward kernel draw independent streams from one key.

    With probability ``rate`` the block suffers one SEU at a uniformly
    drawn (step, row, col); returns (hit, step, row, col) where ``hit`` is
    a traced bool and the coordinates are int32 scalars. The caller applies
    it with `apply_seu` on the step whose LIVE index matches ``step``.

    ``n_steps`` is the number of steps the block actually executes and may
    be a traced int32 (flash callers pass the causal/ragged live span, not
    the grid extent — drawing over skipped steps would silently deflate
    the realized injection rate below the nominal Bernoulli(rate), the
    exact mis-measurement the hook exists to prevent). n_steps ≤ 0 ⇒ the
    block never fires."""
    seed = (rng_ref[1].astype(jnp.uint32)
            ^ _mix32(rng_ref[2].astype(jnp.uint32)
                     + jnp.uint32((salt * 0x9E3779B9) & 0xFFFFFFFF)))
    h0 = _mix32(seed ^ (block_uid.astype(jnp.uint32)
                        * jnp.uint32(0x85EBCA6B)))
    u = (h0 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    n_steps = jnp.asarray(n_steps, jnp.int32)
    hit = (rng_ref[0] == 1) & (u < rate) & (n_steps > 0)
    h1, h2, h3 = _mix32(h0 + jnp.uint32(1)), _mix32(h0 + jnp.uint32(2)), \
        _mix32(h0 + jnp.uint32(3))

    def _bounded(h, n):
        return ((h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
                % jnp.maximum(jnp.asarray(n, jnp.int32), 1))

    return hit, _bounded(h1, n_steps), _bounded(h2, bm), _bounded(h3, bn)


def apply_seu(delta, row, col, hit_now, bit_shift: int):
    """Land the drawn SEU on one element of a (bm, bn) accumulator delta —
    the same magnitude model as `core.fault_injection.Injector`: the hit
    element scales by 2**bit_shift (a high-order mantissa/exponent flip),
    with an absolute 2**bit_shift offset when the element is ~0 so the flip
    stays observable."""
    bm, bn = delta.shape
    mask = ((_iota2((bm, bn), 0) == row) & (_iota2((bm, bn), 1) == col)
            & hit_now)
    mag = delta * (2.0 ** bit_shift - 1.0)
    mag = jnp.where(jnp.abs(mag) > 1e-6, mag,
                    jnp.full_like(mag, 2.0 ** bit_shift))
    return delta + jnp.where(mask, mag, 0.0)


# ---------------------------------------------------------------------------
# the template
# ---------------------------------------------------------------------------

def render(spec: KernelSpec, *, k_steps: int, bm: int, bn: int, bk: int,
           n_bands: int = 1, verify_step: bool = True, corrects: bool = True,
           rel_tau: float = 64.0, inject_rate: float = 0.0,
           bit_shift: int = 8, grid_m: int = 1, grid_n: int = 1,
           grid_b: int = 1):
    """Instantiate the kernel body for `spec` with the given static
    parameters. Returns a function matching `layout(spec)`'s ref list.

    Batched specs add a leading batch grid axis (uniform batched) or a
    scalar-prefetched tile→group map (grouped); see `BatchedKernelSpec`.

    ``inject_rate`` > 0 arms the in-kernel stochastic SEU hook (PR-5's
    flash-family hook, extended to the 2-D/batched/grouped bodies): each
    output block draws one Bernoulli(rate) SEU from the scalar-prefetched
    rng triple via `stochastic_seu` and lands it with `apply_seu`
    (magnitude model = `ft.inject_bit_shift`). The draw is gated on the
    STATIC rate, so rate-0 renders are bit-identical to pre-hook kernels.
    ``grid_m``/``grid_n``/``grid_b`` are the launch's grid extents — they
    make the per-block uid unique across the whole launch."""
    ft = spec.ft
    mode = spec.ft_level
    masked = spec.masked
    batched = spec.batched and not spec.grouped   # uniform batched (4-D grid)
    grouped = spec.grouped
    shared_b = spec.shared_b
    chain = [epilogues.get(n) for n in spec.epilogue]
    # Linear-prefix fold is a block-mode feature: tile/inner keep their
    # per-band / per-step verification on the raw accumulator and apply the
    # whole chain afterwards (correction has already happened by then).
    split = spec.fold_split() if (ft and mode == "block") else 0
    acc_dt = jnp.dtype(spec.acc_dtype)

    def kernel(*refs):
        refs = list(refs)
        if ft:
            inj_idx_ref, inj_mag_ref, rng_ref, dims_ref = refs[:4]
            del refs[:4]
        else:
            inj_idx_ref = inj_mag_ref = rng_ref = None
            dims_ref = refs.pop(0) if masked else None
        gid_ref = row_end_ref = None
        if grouped:
            gid_ref = refs.pop(0)
            row_end_ref = refs.pop(0)
        a_ref = refs.pop(0)
        b_ref = refs.pop(0)
        bias_ref = refs.pop(0) if spec.needs_bias else None
        res_ref = refs.pop(0) if spec.needs_residual else None
        out_ref = refs.pop(0)
        xo_refs = [refs.pop(0) for _ in spec.extra_outputs]
        rep_ref = refs.pop(0) if ft else None
        acc_ref = refs.pop(0)
        colck_ref = rowck_ref = amax_ref = bmax_ref = None
        if ft:
            colck_ref, rowck_ref, amax_ref, bmax_ref = refs

        if batched:
            g = pl.program_id(0)
            i = pl.program_id(1)
            j = pl.program_id(2)
            s = pl.program_id(3)
        else:
            i = pl.program_id(0)
            j = pl.program_id(1)
            s = pl.program_id(2)
            g = gid_ref[i] if grouped else None
        last = s == k_steps - 1

        def _aux(op):
            if op.aux == "vector":
                return bias_ref[...].astype(jnp.float32)
            if op.aux == "tile":
                return res_ref[...].astype(jnp.float32)
            return None

        def _store(y):
            # Batched output blocks are (1, bm, bn) — reshape the 2-D tile.
            out_ref[...] = y.astype(out_ref.dtype).reshape(out_ref.shape)

        def _apply_chain(y, ops_list):
            """Apply `ops_list` to the accumulator, writing any requested
            derived outputs at their defining point: act_grad is the first
            (only) nonlinear op's derivative at its input — i.e. at the
            *pre-activation*, after verification/correction has run."""
            for op in ops_list:
                if not op.linear and "act_grad" in spec.extra_outputs:
                    ref = xo_refs[spec.extra_outputs.index("act_grad")]
                    ref[...] = op.grad(y).astype(ref.dtype).reshape(ref.shape)
                y = op.apply(y, _aux(op))
            return y

        # ---- prologue: first-step scratch init ---------------------------
        @pl.when(s == 0)
        def _prologue():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            if ft:
                colck_ref[...] = jnp.zeros_like(colck_ref)
                rowck_ref[...] = jnp.zeros_like(rowck_ref)
                amax_ref[0, 0] = 0.0
                bmax_ref[0, 0] = 0.0
                rep_ref[...] = jnp.zeros_like(rep_ref)

        # ---- mac: load (+ragged mask), MAC, checksums --------------------
        a = a_ref[0] if batched else a_ref[...]
        b = b_ref[...] if (not spec.batched or shared_b) else b_ref[0]
        if masked:
            # Ragged dispatch: zero everything past the true (m, n, k)
            # carried in via scalar prefetch. The checksum math then sees
            # exactly zero-padding semantics (checksums of zero rows/cols
            # are zero), so ABFT survives the ragged edges and garbage in
            # the padded region (even NaN/Inf) cannot leak into the
            # accumulator or the running checksums. Grouped dispatch swaps
            # the row bound for the owning group's last live buffer row
            # (`row_end[gid]`) — the per-group ragged edge.
            tm, tn, tk = dims_ref[0], dims_ref[1], dims_ref[2]
            row_hi = row_end_ref[g] if grouped else tm
            a_ok = ((i * bm + _iota2((bm, bk), 0) < row_hi)
                    & (s * bk + _iota2((bm, bk), 1) < tk))
            b_ok = ((s * bk + _iota2((bk, bn), 0) < tk)
                    & (j * bn + _iota2((bk, bn), 1) < tn))
            a = jnp.where(a_ok, a, jnp.zeros_like(a))
            b = jnp.where(b_ok, b, jnp.zeros_like(b))

        if not ft:
            acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32
                                    ).astype(acc_dt)

            @pl.when(last)
            def _flush_plain():
                _store(_apply_chain(acc_ref[...].astype(jnp.float32), chain))
            return

        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)

        # Running operand-magnitude bounds for the rounding-aware threshold
        # — free: the tiles are already in VMEM (the fused-with-prefetch
        # point).
        amax_ref[0, 0] = jnp.maximum(amax_ref[0, 0], jnp.max(jnp.abs(af)))
        bmax_ref[0, 0] = jnp.maximum(bmax_ref[0, 0], jnp.max(jnp.abs(bf)))
        k_elapsed = (s + 1).astype(jnp.float32) * bk
        if masked:
            # Rounding-error accumulation stops at the true K.
            k_elapsed = jnp.minimum(k_elapsed,
                                    dims_ref[2].astype(jnp.float32))
        tau = jnp.maximum(rel_tau * F32EPS * k_elapsed
                          * amax_ref[0, 0] * bmax_ref[0, 0], 1e-30)

        delta = jnp.dot(a, b, preferred_element_type=jnp.float32)

        # ---- emulated SEU (scalar-prefetched spec) -----------------------
        # Uniform batched specs carry a 5-wide index [enable, batch, row,
        # col, k_step]; 2-D and grouped keep the 4-wide layout (grouped rows
        # are global buffer coordinates, so the tile offset locates them).
        if batched:
            enable, inj_b, g_row, g_col, inj_k = (
                inj_idx_ref[0], inj_idx_ref[1], inj_idx_ref[2],
                inj_idx_ref[3], inj_idx_ref[4])
        else:
            enable, g_row, g_col, inj_k = (inj_idx_ref[0], inj_idx_ref[1],
                                           inj_idx_ref[2], inj_idx_ref[3])
            inj_b = None
        r_loc = g_row - i * bm
        c_loc = g_col - j * bn
        hit_now = ((enable == 1) & (s == inj_k)
                   & (r_loc >= 0) & (r_loc < bm)
                   & (c_loc >= 0) & (c_loc < bn))
        if batched:
            # batch < 0 broadcasts the SEU into every slice — matching the
            # jnp path's inject_spec semantics (core._ft_bmm_backend).
            hit_now = hit_now & ((inj_b < 0) | (inj_b == g))
        hit_mask = ((_iota2((bm, bn), 0) == r_loc)
                    & (_iota2((bm, bn), 1) == c_loc)
                    & hit_now)
        delta = delta + jnp.where(hit_mask, inj_mag_ref[0], 0.0)

        # ---- stochastic SEU hook (PR 10: flash-family hook on the GEMM
        # bodies). Static-rate gate: rate-0 renders stay bit-identical.
        if inject_rate > 0.0:
            salt = SALT_BATCHED if batched else SALT_GEMM2D
            if batched:
                uid = (g * grid_m + i) * grid_n + j
            elif grouped:
                # Grouped blocks share (i, j) across the batch-of-one grid —
                # the row-tile index i is already launch-unique.
                uid = i * grid_n + j
            else:
                uid = i * grid_n + j
            # Live k-span: masked kernels zero loads past the true K, so the
            # block only accumulates over ceil(tk/bk) steps — drawing over
            # the padded span would deflate the realized rate.
            n_live = (((dims_ref[2] + bk - 1) // bk) if masked
                      else jnp.int32(k_steps))
            s_hit, s_step, s_row, s_col = stochastic_seu(
                rng_ref, salt, uid, n_live, bm, bn, inject_rate)
            delta = apply_seu(delta, s_row, s_col, s_hit & (s == s_step),
                              bit_shift)

        # ---- checksum maintenance + intermediate verification ------------
        if mode == "inner":
            # Verify this step's contribution in isolation (thread-level
            # analogue: smallest protected unit, no cross-step state).
            ck_col = jnp.dot(jnp.sum(af, axis=0, keepdims=True), bf)
            ck_row = jnp.dot(af, jnp.sum(bf, axis=1, keepdims=True))
            d_col = jnp.sum(delta, axis=0, keepdims=True) - ck_col
            d_row = jnp.sum(delta, axis=1, keepdims=True) - ck_row
            delta, det, mag, row_l, col_l = _locate_correct_full(
                delta, d_col, d_row, tau, corrects, bm, bn)
            acc_ref[...] += delta
            _record(rep_ref, det, mag, row_l + i * bm, col_l + j * bn,
                    d_col, d_row, tau, k_elapsed, corrects)
        else:
            acc_ref[...] += delta
            if mode == "block":
                colck_ref[...] += jnp.dot(jnp.sum(af, axis=0, keepdims=True),
                                          bf)
            else:  # mode == "tile": one running column checksum per MXU band
                for t in range(n_bands):
                    colck_ref[t:t + 1, :] += jnp.dot(
                        jnp.sum(af[t * MXU:(t + 1) * MXU], axis=0,
                                keepdims=True), bf)
            rowck_ref[...] += jnp.dot(af, jnp.sum(bf, axis=1, keepdims=True))

            def _verify_raw():
                acc = acc_ref[...]
                d_row = (jnp.sum(acc, axis=1, keepdims=True)
                         - rowck_ref[...])
                if mode == "block":
                    d_col = (jnp.sum(acc, axis=0, keepdims=True)
                             - colck_ref[0:1, :])
                    new_acc, det, mag, row_l, col_l = _locate_correct_full(
                        acc, d_col, d_row, tau, corrects, bm, bn)
                    acc_ref[...] = new_acc
                    _record(rep_ref, det, mag, row_l + i * bm,
                            col_l + j * bn, d_col, d_row, tau, k_elapsed,
                            corrects)
                else:
                    # Per-band verification & correction (one SEU per band).
                    for t in range(n_bands):
                        band = acc[t * MXU:(t + 1) * MXU]
                        d_col = (jnp.sum(band, axis=0, keepdims=True)
                                 - colck_ref[t:t + 1, :])
                        d_row_b = d_row[t * MXU:(t + 1) * MXU]
                        new_band, det, mag, row_l, col_l = \
                            _locate_correct_full(band, d_col, d_row_b, tau,
                                                 corrects, MXU, bn)
                        acc_ref[t * MXU:(t + 1) * MXU, :] = new_band
                        _record(rep_ref, det, mag,
                                row_l + i * bm + t * MXU, col_l + j * bn,
                                d_col, d_row_b, tau, k_elapsed, corrects)

            if verify_step:
                pl.when(jnp.logical_not(last))(_verify_raw)

        # ---- epilogue: fold, final verify, chain, cast, writeback --------
        @pl.when(last)
        def _flush():
            if mode == "block":
                acc = acc_ref[...]
                colck = colck_ref[0:1, :]
                rowck = rowck_ref[...]
                # Fold the linear prefix into the checksum comparison: the
                # final verification (and the branchless correction it
                # drives) runs on the post-epilogue values.
                for op in chain[:split]:
                    aux = _aux(op)
                    acc = op.apply(acc, aux)
                    colck, rowck = op.fold(colck, rowck, aux, bm)
                d_col = jnp.sum(acc, axis=0, keepdims=True) - colck
                d_row = jnp.sum(acc, axis=1, keepdims=True) - rowck
                acc, det, mag, row_l, col_l = _locate_correct_full(
                    acc, d_col, d_row, tau, corrects, bm, bn)
                _record(rep_ref, det, mag, row_l + i * bm, col_l + j * bn,
                        d_col, d_row, tau, k_elapsed, corrects)
                _store(_apply_chain(acc, chain[split:]))
            else:
                if mode == "tile":
                    _verify_raw()          # corrects acc_ref in place
                # "inner" verified every step already.
                _store(_apply_chain(acc_ref[...], chain))

    kernel.__name__ = (f"gemm_{spec.ft_level}"
                       + ("_grouped" if grouped else "")
                       + ("_batched" if batched else "")
                       + ("_masked" if masked else "")
                       + ("".join("_" + n for n in spec.epilogue))
                       + ("".join("_" + n for n in spec.extra_outputs)))
    return kernel


# ---------------------------------------------------------------------------
# the output-stationary grouped transpose GEMM (tgmm) template
# ---------------------------------------------------------------------------

def render_tgmm(spec: KernelSpec, *, t_tiles: int, bm: int, bn: int, bk: int,
                n_bands: int = 1, verify_step: bool = True,
                corrects: bool = True, rel_tau: float = 64.0,
                inject_rate: float = 0.0, bit_shift: int = 8,
                grid_k: int = 1, grid_n: int = 1):
    """The MoE backward-dw kernel: ``dw[g] = X_gᵀ G_g`` over a group-sorted
    buffer (see `BatchedKernelSpec` docs). Output-stationary over (G, K, N):

      grid = (K/bk, N/bn, t_tiles) — the innermost axis walks row tiles of
      the buffer (the *reduction* dimension); the output block index is the
      scalar-prefetched owning group ``gid[t]``, so each (g, ki, ni) block
      stays VMEM-resident over its group's contiguous tile range. The f32
      accumulator and per-group running checksums reset on the first tile of
      a group and flush (final verify → branchless correct → writeback) on
      its last — per-group ABFT falls out of the flush boundary exactly like
      per-block ABFT falls out of the k-loop in the forward template.

    Checksums (Huang–Abraham on the transpose product): the column checksum
    of dw_g is (X_g e_K)ᵀ G_g and the row checksum is X_gᵀ (G_g e_N) — both
    computed from operand tiles already in VMEM, never from dw.

    Ref list (see `layout`): FT — [inj_idx(4), inj_mag(1), rng(3), dims(3),
    gid, row_end | x, g | dw, rep | acc, colck, rowck | amax, bmax, t0];
    non-FT — [dims, gid, row_end | x, g | dw | acc]. ``dims`` is int32
    [t_buf, N, K]
    (true trailing dims — K/N ragged edges are masked in-kernel); injection
    rows/cols are global (K, N) coordinates and ``k_step`` is the row-tile
    index, which selects the owning group."""
    ft = spec.ft
    mode = spec.ft_level
    assert spec.tgmm and not spec.epilogue

    def kernel(*refs):
        refs = list(refs)
        if ft:
            inj_idx_ref, inj_mag_ref, rng_ref, dims_ref = refs[:4]
            del refs[:4]
        else:
            inj_idx_ref = inj_mag_ref = rng_ref = None
            dims_ref = refs.pop(0)
        gid_ref = refs.pop(0)
        row_end_ref = refs.pop(0)
        x_ref = refs.pop(0)
        g_ref = refs.pop(0)
        out_ref = refs.pop(0)
        rep_ref = refs.pop(0) if ft else None
        acc_ref = refs.pop(0)
        colck_ref = rowck_ref = amax_ref = bmax_ref = t0_ref = None
        if ft:
            colck_ref, rowck_ref, amax_ref, bmax_ref, t0_ref = refs

        ki = pl.program_id(0)
        ni = pl.program_id(1)
        t = pl.program_id(2)
        gidx = gid_ref[t]
        # Group boundaries in the (contiguous, group-sorted) tile walk.
        first = (t == 0) | (gid_ref[jnp.maximum(t - 1, 0)] != gidx)
        last = (t == t_tiles - 1) | \
               (gid_ref[jnp.minimum(t + 1, t_tiles - 1)] != gidx)

        @pl.when(first)
        def _prologue():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            if ft:
                colck_ref[...] = jnp.zeros_like(colck_ref)
                rowck_ref[...] = jnp.zeros_like(rowck_ref)
                amax_ref[0, 0] = 0.0
                bmax_ref[0, 0] = 0.0
                t0_ref[0, 0] = t.astype(jnp.float32)
                rep_ref[...] = jnp.zeros_like(rep_ref)

        # ---- load + ragged masking (group edge, true K/N edges) ----------
        tn, tk = dims_ref[1], dims_ref[2]
        row_hi = row_end_ref[gidx]
        rows = t * bm + _iota2((bm, 1), 0)
        x = x_ref[...].astype(jnp.float32)
        g = g_ref[...].astype(jnp.float32)
        x_ok = (rows < row_hi) & (ki * bk + _iota2((bm, bk), 1) < tk)
        g_ok = (rows < row_hi) & (ni * bn + _iota2((bm, bn), 1) < tn)
        x = jnp.where(x_ok, x, 0.0)
        g = jnp.where(g_ok, g, 0.0)

        contract_rows = (((0,), (0,)), ((), ()))     # Xᵀ·G without transpose
        delta = jax.lax.dot_general(x, g, contract_rows,
                                    preferred_element_type=jnp.float32)

        if not ft:
            acc_ref[...] += delta

            @pl.when(last)
            def _flush_plain():
                out_ref[...] = (acc_ref[...].astype(out_ref.dtype)
                                .reshape(out_ref.shape))
            return

        amax_ref[0, 0] = jnp.maximum(amax_ref[0, 0], jnp.max(jnp.abs(x)))
        bmax_ref[0, 0] = jnp.maximum(bmax_ref[0, 0], jnp.max(jnp.abs(g)))
        # Rounding-error accumulation follows the live rows reduced so far
        # for THIS group (from its first tile t0 through the group edge).
        rows_elapsed = (jnp.minimum((t + 1) * bm, row_hi).astype(jnp.float32)
                        - t0_ref[0, 0] * bm)
        rows_elapsed = jnp.maximum(rows_elapsed, 1.0)
        tau = jnp.maximum(rel_tau * F32EPS * rows_elapsed
                          * amax_ref[0, 0] * bmax_ref[0, 0], 1e-30)

        # ---- emulated SEU (global (K, N) coordinates, tile-step timed) ---
        enable, g_row, g_col, inj_k = (inj_idx_ref[0], inj_idx_ref[1],
                                       inj_idx_ref[2], inj_idx_ref[3])
        r_loc = g_row - ki * bk
        c_loc = g_col - ni * bn
        hit = ((enable == 1) & (t == inj_k)
               & (r_loc >= 0) & (r_loc < bk) & (c_loc >= 0) & (c_loc < bn))
        hit_mask = ((_iota2((bk, bn), 0) == r_loc)
                    & (_iota2((bk, bn), 1) == c_loc) & hit)
        delta = delta + jnp.where(hit_mask, inj_mag_ref[0], 0.0)

        # ---- stochastic SEU hook: one draw per stationary (group, ki, ni)
        # output block, timed on the group-LOCAL tile step so the realized
        # rate tracks the group's live span (not the whole buffer walk).
        if inject_rate > 0.0:
            t0 = t0_ref[0, 0].astype(jnp.int32)
            n_live = (row_hi - t0 * bm + bm - 1) // bm       # group's tiles
            uid = (gidx * grid_k + ki) * grid_n + ni
            s_hit, s_step, s_row, s_col = stochastic_seu(
                rng_ref, SALT_TGMM, uid, n_live, bk, bn, inject_rate)
            delta = apply_seu(delta, s_row, s_col,
                              s_hit & ((t - t0) == s_step), bit_shift)

        # ---- per-group running checksums ---------------------------------
        xsum = jnp.sum(x, axis=1, keepdims=True)             # (bm, 1): X e_K
        gsum = jnp.sum(g, axis=1, keepdims=True)             # (bm, 1): G e_N
        if mode == "inner":
            ck_col = jax.lax.dot_general(xsum, g, contract_rows)   # (1, bn)
            ck_row = jax.lax.dot_general(x, gsum, contract_rows)   # (bk, 1)
            d_col = jnp.sum(delta, axis=0, keepdims=True) - ck_col
            d_row = jnp.sum(delta, axis=1, keepdims=True) - ck_row
            delta, det, mag, row_l, col_l = _locate_correct_full(
                delta, d_col, d_row, tau, corrects, bk, bn)
            acc_ref[...] += delta
            _record(rep_ref, det, mag, row_l + ki * bk, col_l + ni * bn,
                    d_col, d_row, tau, rows_elapsed, corrects)
        else:
            acc_ref[...] += delta
            if mode == "block":
                colck_ref[...] += jax.lax.dot_general(xsum, g, contract_rows)
            else:  # "tile": one running column checksum per MXU band of dw
                for b in range(n_bands):
                    xb = jnp.sum(x[:, b * MXU:(b + 1) * MXU], axis=1,
                                 keepdims=True)
                    colck_ref[b:b + 1, :] += jax.lax.dot_general(
                        xb, g, contract_rows)
            rowck_ref[...] += jax.lax.dot_general(x, gsum, contract_rows)

            def _verify_raw():
                acc = acc_ref[...]
                d_row = jnp.sum(acc, axis=1, keepdims=True) - rowck_ref[...]
                if mode == "block":
                    d_col = (jnp.sum(acc, axis=0, keepdims=True)
                             - colck_ref[0:1, :])
                    new_acc, det, mag, row_l, col_l = _locate_correct_full(
                        acc, d_col, d_row, tau, corrects, bk, bn)
                    acc_ref[...] = new_acc
                    _record(rep_ref, det, mag, row_l + ki * bk,
                            col_l + ni * bn, d_col, d_row, tau,
                            rows_elapsed, corrects)
                else:
                    for b in range(n_bands):
                        band = acc[b * MXU:(b + 1) * MXU]
                        d_col = (jnp.sum(band, axis=0, keepdims=True)
                                 - colck_ref[b:b + 1, :])
                        d_row_b = d_row[b * MXU:(b + 1) * MXU]
                        new_band, det, mag, row_l, col_l = \
                            _locate_correct_full(band, d_col, d_row_b, tau,
                                                 corrects, MXU, bn)
                        acc_ref[b * MXU:(b + 1) * MXU, :] = new_band
                        _record(rep_ref, det, mag,
                                row_l + ki * bk + b * MXU, col_l + ni * bn,
                                d_col, d_row_b, tau, rows_elapsed, corrects)

            if verify_step:
                pl.when(jnp.logical_not(last))(_verify_raw)

        @pl.when(last)
        def _flush():
            if mode != "inner":
                _verify_raw()            # final per-group verify + correct
            out_ref[...] = (acc_ref[...].astype(out_ref.dtype)
                            .reshape(out_ref.shape))

    kernel.__name__ = f"tgmm_{spec.ft_level}"
    return kernel
