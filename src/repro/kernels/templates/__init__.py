"""Declarative kernel-generation subsystem (the paper's template code
generator, §3.2, grown into a variant registry with fused epilogues).

    spec.py       -- KernelSpec: ft_level × masked × epilogue chain × dtypes;
                     BatchedKernelSpec adds the leading batch/group axis
    epilogues.py  -- registered epilogue ops (bias/activation/residual) with
                     checksum-fold rules for ABFT-through-epilogue
    emit.py       -- spec → parameterized Pallas kernel body (staged emitter)
    registry.py   -- spec + tile params → memoized pallas_call launches
                     (`kernel_call` 2-D, `batched_kernel_call` batched/grouped)

Entry points: `kernels.ops.gemm_call` / `kernels.ops.grouped_gemm_call`
(dispatching front doors), `registry.kernel_call` (raw launch),
`epilogues.register` (extend the variant space).
"""
from . import emit, epilogues, registry, spec
from .spec import BatchedKernelSpec, FlashKernelSpec, KernelSpec, fused

__all__ = ["emit", "epilogues", "registry", "spec", "BatchedKernelSpec",
           "FlashKernelSpec", "KernelSpec", "fused"]
