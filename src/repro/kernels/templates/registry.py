"""Variant registry: KernelSpec + KernelParams → a ready pallas_call.

`kernel_call` is the single launch point every GEMM kernel in the repo now
routes through — `kernels.gemm.gemm/gemm_masked`, `kernels.ftgemm.ft_gemm`,
and `kernels.ops.gemm_call` are all thin wrappers over it. Rendering and
compilation are memoized by jit's static-argument cache (the spec and
params are frozen dataclasses), so each (spec, params, grid) variant is
rendered once per process.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policy import FTConfig
from ..pallas_compat import CompilerParams as _CompilerParams
from ..autotune import MXU, KernelParams
from . import emit
from .spec import BatchedKernelSpec, KernelSpec

REPORT_WIDTH = emit.REPORT_WIDTH


def validate(spec: KernelSpec, params: KernelParams, m: int, n: int, k: int,
             in_bytes: int = 4) -> None:
    """Static legality of a launch: the operands must divide the tile grid,
    and bm must respect the variant's alignment floor — MXU-aligned for
    unmasked tiles and for "tile" mode (whose per-band checksums slice the
    accumulator in MXU-row bands), sublane-aligned for masked ragged
    tiles."""
    bm, bn, bk = params.bm, params.bn, params.bk
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        ((m, n, k), params, spec)
    from .. import search
    need = MXU if (spec.ft_level == "tile" or not spec.masked) \
        else search.sublane(in_bytes)
    assert bm % need == 0, (params, spec)


@functools.partial(jax.jit,
                   static_argnames=("spec", "params", "ft", "interpret",
                                    "out_dtype"))
def kernel_call(a: jax.Array, b: jax.Array,
                bias: Optional[jax.Array] = None,
                residual: Optional[jax.Array] = None,
                inj_idx: Optional[jax.Array] = None,
                inj_mag: Optional[jax.Array] = None,
                rng: Optional[jax.Array] = None,
                dims: Optional[jax.Array] = None, *,
                spec: KernelSpec, params: KernelParams,
                ft: Optional[FTConfig] = None,
                interpret: bool = False, out_dtype=None):
    """Launch the rendered variant. Returns (C, report) — report is None
    for non-FT specs. Multi-output specs (``spec.extra_outputs``) return
    ((C, extra…), report) — the derived outputs ride between C and the
    report in the pallas_call's output list.

    Operand contract (enforced by `kernels.ops.gemm_call`, the padding
    front door): a (M, K), b (K, N) padded to the tile grid; bias (1, N)
    and residual (M, N) zero-padded likewise; for FT specs inj_idx int32[4]
    / inj_mag f32[1] (see `ftgemm.encode_injection`) and rng int32[3]
    (`flashft.encode_rng` — [enable, seed0, seed1], zeros disable the
    stochastic SEU draw); dims int32[3] true (m, n, k) for masked specs
    (ignored but required for unmasked FT)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    validate(spec, params, m, n, k, a.dtype.itemsize)
    bm, bn, bk = params.bm, params.bn, params.bk
    grid = (m // bm, n // bn, k // bk)
    out_dtype = out_dtype or (jnp.dtype(spec.out_dtype) if spec.out_dtype
                              else a.dtype)
    n_bands = bm // MXU if spec.ft_level == "tile" else 1
    ft = ft or FTConfig(level=spec.ft_level if spec.ft else "block",
                        action="correct" if spec.ft else "off")

    kernel = emit.render(
        spec, k_steps=grid[2], bm=bm, bn=bn, bk=bk, n_bands=n_bands,
        verify_step=(ft.verify == "step"), corrects=ft.corrects,
        rel_tau=ft.rel_tau, inject_rate=ft.inject_rate,
        bit_shift=ft.inject_bit_shift, grid_m=grid[0], grid_n=grid[1])
    lay = emit.layout(spec)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s, *_: (i, s)),
        pl.BlockSpec((bk, bn), lambda i, j, s, *_: (s, j)),
    ]
    operands = [a, b]
    if spec.needs_bias:
        assert bias is not None and bias.shape == (1, n), \
            (None if bias is None else bias.shape, n)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s, *_: (0, j)))
        operands.append(bias)
    if spec.needs_residual:
        assert residual is not None and residual.shape == (m, n), \
            (None if residual is None else residual.shape, (m, n))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j)))
        operands.append(residual)

    out_specs = [pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((m, n), out_dtype)]
    for _ in spec.extra_outputs:
        out_specs.append(pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j)))
        out_shape.append(jax.ShapeDtypeStruct((m, n), out_dtype))
    scratch = [pltpu.VMEM((bm, bn), jnp.dtype(spec.acc_dtype))]
    prefetch = []
    if spec.ft:
        assert inj_idx is not None and inj_mag is not None
        if rng is None:
            rng = jnp.zeros((3,), jnp.int32)
        if dims is None:
            dims = jnp.array([m, n, k], jnp.int32)
        prefetch = [inj_idx, inj_mag, rng, dims]
        out_specs.append(pl.BlockSpec((1, 1, REPORT_WIDTH),
                                      lambda i, j, s, *_: (i, j, 0)))
        out_shape.append(jax.ShapeDtypeStruct(
            (grid[0], grid[1], REPORT_WIDTH), jnp.float32))
        scratch += [pltpu.VMEM((n_bands, bn), jnp.float32),
                    pltpu.VMEM((bm, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32)]
    elif spec.masked:
        assert dims is not None
        prefetch = [dims]
    assert len(prefetch) == lay.n_prefetch and len(operands) == lay.n_inputs

    compiler_params = _CompilerParams(
        dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                             pltpu.ARBITRARY))

    multi = len(out_shape) > 1           # FT report and/or extra outputs
    if prefetch:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs if multi else out_specs[0],
            scratch_shapes=scratch,
        )
        call = pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=out_shape if multi else out_shape[0],
            compiler_params=compiler_params, interpret=interpret)
        result = call(*prefetch, *operands)
    else:
        call = pl.pallas_call(
            kernel, grid=grid,
            in_specs=in_specs,
            out_specs=out_specs if multi else out_specs[0],
            out_shape=out_shape if multi else out_shape[0],
            scratch_shapes=scratch,
            compiler_params=compiler_params, interpret=interpret)
        result = call(*operands)

    if not multi:
        return result, None
    result = list(result)
    rep = result.pop() if spec.ft else None
    out = tuple(result) if spec.extra_outputs else result[0]
    return out, rep


# ---------------------------------------------------------------------------
# flash-attention variants (PR 5) — the registry's launch builders for the
# `kernels.flashft` kernel family. The kernel bodies live in flashft (online
# softmax is its own body, not an emit.render product); tile selection rides
# `autotune.best_params` under `spec.FlashKernelSpec` variant keys; these
# functions own the grid/BlockSpec plumbing, exactly like `kernel_call` does
# for the 2-D template. Called from the jit'd wrappers in flashft — not
# jit'd themselves.
# ---------------------------------------------------------------------------

def flash_fwd_call(q, k, v, inj_idx, inj_mag, rng, dims, *, bq: int,
                   bkv: int, causal: bool, ft: FTConfig, interpret: bool,
                   protect_qk: bool, scale: float, n_rep: int,
                   save_stats: bool):
    """Forward flash-FT launch. Returns (out, report) or, with
    ``save_stats``, (out, m, l, report) — m/l are (BH, Sq, 1) f32 per-row
    softmax statistics (degenerate rows marked m=−∞, l=0)."""
    from .. import flashft

    bh, sq, dh = q.shape
    skv = k.shape[1]
    grid = (bh, sq // bq, skv // bkv)
    kernel = functools.partial(
        flashft._flash_ft_kernel, kv_steps=grid[2], q_blocks=grid[1],
        bq=bq, bkv=bkv, dh=dh, causal=causal, scale=scale,
        corrects=ft.corrects, rel_tau=ft.rel_tau, protect_qk=protect_qk,
        save_stats=save_stats, inject_rate=ft.inject_rate,
        bit_shift=ft.inject_bit_shift)

    out_specs = [pl.BlockSpec((1, bq, dh), lambda b, i, s, *_: (b, i, 0))]
    out_shape = [jax.ShapeDtypeStruct((bh, sq, dh), q.dtype)]
    if save_stats:
        for _ in ("m", "l"):
            out_specs.append(pl.BlockSpec((1, bq, 1),
                                          lambda b, i, s, *_: (b, i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32))
    out_specs.append(pl.BlockSpec((1, 1, REPORT_WIDTH),
                                  lambda b, i, s, *_: (b, i, 0)))
    out_shape.append(jax.ShapeDtypeStruct((bh, sq // bq, REPORT_WIDTH),
                                          jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, s, *_: (b, i, 0)),
            pl.BlockSpec((1, bkv, dh),
                         lambda b, i, s, *_: (b // n_rep, s, 0)),
            pl.BlockSpec((1, bkv, dh),
                         lambda b, i, s, *_: (b // n_rep, s, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    result = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(inj_idx, inj_mag, rng, dims, q, k, v)
    return tuple(result)


def flash_decode_call(q, k_pages, v_pages, inj_idx, inj_mag, rng, lengths,
                      page_table, *, kvh: int, ft: FTConfig,
                      interpret: bool, protect_qk: bool, scale: float):
    """Paged ragged decode launch (PR 9). Grid (B·kvh, max_pages): one row
    per (slot, kv head), reduction walk over the slot's KV pages. The
    scalar-prefetched page table drives the K/V *index maps* — kv step s of
    grid row g DMAs physical page ``page_table[g // kvh, s]`` of kv head
    ``g % kvh`` straight out of the shared (n_pages, kvh, page, dh) pool,
    so the kernel streams exactly the slot's pages (NULL entries stream the
    trash page; the in-body length mask keeps them unattended). The length
    vector replaces the forward's (Sq, Skv) dims pair — per-row ragged
    dispatch. Returns (out (B·kvh, bq, dh), report (B·kvh, 1, W))."""
    from .. import flashft

    g_rows, bq, dh = q.shape
    n_pages, _, page, _ = k_pages.shape
    max_pages = page_table.shape[1]
    grid = (g_rows, max_pages)
    kernel = functools.partial(
        flashft._flash_decode_kernel, kv_steps=grid[1], kvh=kvh, bq=bq,
        page=page, dh=dh, scale=scale, corrects=ft.corrects,
        rel_tau=ft.rel_tau, protect_qk=protect_qk,
        inject_rate=ft.inject_rate, bit_shift=ft.inject_bit_shift)

    # prefetch order: inj_idx, inj_mag, rng, lengths, page_table — the
    # table is pf[4] inside the index maps.
    kv_spec = pl.BlockSpec(
        (1, 1, page, dh),
        lambda g, s, *pf: (pf[4][g // kvh, s], g % kvh, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda g, s, *_: (g, 0, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda g, s, *_: (g, 0, 0)),
            pl.BlockSpec((1, 1, REPORT_WIDTH), lambda g, s, *_: (g, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    out, rep = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((g_rows, bq, dh), q.dtype),
            jax.ShapeDtypeStruct((g_rows, 1, REPORT_WIDTH), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(inj_idx, inj_mag, rng, lengths, page_table, q, k_pages, v_pages)
    return out, rep


def flash_dq_call(q, k, v, g, m, l, di, inj_idx, inj_mag, rng, dims, *,
                  bq: int, bkv: int, causal: bool, ft: FTConfig,
                  interpret: bool, protect_qk: bool, scale: float,
                  n_rep: int):
    """dQ backward launch (q-block stationary, kv-step reduction walk).
    Returns (dq (BH, Sq, dh), report (BH, Sq/bq, W))."""
    from .. import flashft

    bh, sq, dh = q.shape
    skv = k.shape[1]
    grid = (bh, sq // bq, skv // bkv)
    kernel = functools.partial(
        flashft._flash_dq_kernel, kv_steps=grid[2], q_blocks=grid[1],
        bq=bq, bkv=bkv, dh=dh, causal=causal, scale=scale,
        corrects=ft.corrects, rel_tau=ft.rel_tau, protect_qk=protect_qk,
        inject_rate=ft.inject_rate, bit_shift=ft.inject_bit_shift)

    q_spec = pl.BlockSpec((1, bq, dh), lambda b, i, s, *_: (b, i, 0))
    kv_spec = pl.BlockSpec((1, bkv, dh),
                           lambda b, i, s, *_: (b // n_rep, s, 0))
    stat_spec = pl.BlockSpec((1, bq, 1), lambda b, i, s, *_: (b, i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, stat_spec, stat_spec,
                  stat_spec],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, s, *_: (b, i, 0)),
            pl.BlockSpec((1, 1, REPORT_WIDTH),
                         lambda b, i, s, *_: (b, i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
    )
    dq, rep = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, sq // bq, REPORT_WIDTH), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(inj_idx, inj_mag, rng, dims, q, k, v, g, m, l, di)
    return dq, rep


def flash_dkv_call(q, k, v, g, m, l, di, inj_idx, inj_mag, rng, dims, *,
                   bq: int, bkv: int, causal: bool, ft: FTConfig,
                   interpret: bool, protect_qk: bool, scale: float,
                   n_rep: int):
    """dK/dV backward launch (kv-block stationary; the reduction walk covers
    the n_rep GQA query heads × q blocks of each KV head). Returns
    (dk, dv (BKVH, Skv, dh), report (BKVH, Skv/bkv, W))."""
    from .. import flashft

    bh, sq, dh = q.shape
    bkvh, skv, _ = k.shape
    grid = (bkvh, skv // bkv, n_rep, sq // bq)
    kernel = functools.partial(
        flashft._flash_dkv_kernel, q_steps=grid[3], n_rep=n_rep,
        kv_blocks=grid[1], bq=bq, bkv=bkv, dh=dh, causal=causal,
        scale=scale, corrects=ft.corrects, rel_tau=ft.rel_tau,
        protect_qk=protect_qk, inject_rate=ft.inject_rate,
        bit_shift=ft.inject_bit_shift)

    q_spec = pl.BlockSpec((1, bq, dh),
                          lambda b, kvi, r, qi, *_: (b * n_rep + r, qi, 0))
    stat_spec = pl.BlockSpec((1, bq, 1),
                             lambda b, kvi, r, qi, *_: (b * n_rep + r, qi, 0))
    kv_spec = pl.BlockSpec((1, bkv, dh),
                           lambda b, kvi, r, qi, *_: (b, kvi, 0))
    out_spec = pl.BlockSpec((1, bkv, dh),
                            lambda b, kvi, r, qi, *_: (b, kvi, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[q_spec, q_spec, stat_spec, stat_spec, stat_spec,
                  kv_spec, kv_spec],
        out_specs=[
            out_spec, out_spec,
            pl.BlockSpec((1, 1, REPORT_WIDTH),
                         lambda b, kvi, r, qi, *_: (b, kvi, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bkv, dh), jnp.float32),
                        pltpu.VMEM((bkv, dh), jnp.float32)],
    )
    dk, dv, rep = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bkvh, skv, dh), k.dtype),
            jax.ShapeDtypeStruct((bkvh, skv, dh), v.dtype),
            jax.ShapeDtypeStruct((bkvh, skv // bkv, REPORT_WIDTH),
                                 jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY, pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(inj_idx, inj_mag, rng, dims, q, g, m, l, di, k, v)
    return dk, dv, rep


@functools.partial(jax.jit,
                   static_argnames=("n_groups", "spec", "params", "ft",
                                    "interpret", "out_dtype"))
def tgmm_kernel_call(x: jax.Array, g: jax.Array,
                     inj_idx: Optional[jax.Array] = None,
                     inj_mag: Optional[jax.Array] = None,
                     rng: Optional[jax.Array] = None,
                     dims: Optional[jax.Array] = None,
                     gid: Optional[jax.Array] = None,
                     row_end: Optional[jax.Array] = None, *,
                     n_groups: int,
                     spec: BatchedKernelSpec, params: KernelParams,
                     ft: Optional[FTConfig] = None,
                     interpret: bool = False, out_dtype=None):
    """Launch the output-stationary grouped transpose GEMM (``spec.tgmm``):
    ``dw[g] = X_gᵀ G_g`` with x (t_buf, K), g (t_buf, N) group-sorted
    buffers sharing one layout (``gid`` int32[t_buf/bm], ``row_end``
    int32[G]). Returns (dw (G, K, N) f32-by-default, report|None); the
    report is (G, gk, gn, W) — per *group* blocks, since the accumulator
    flushes at group boundaries.

    Output blocks of EMPTY groups are never visited by the grid and hold
    unspecified memory — `kernels.grouped.dispatch.tgmm_buffer_call` (the
    padding/masking front door) zeroes them; call through it."""
    assert spec.tgmm, spec
    bm, bn, bk = params.bm, params.bn, params.bk
    t_buf, k = x.shape
    t2, n = g.shape
    assert t_buf == t2, (x.shape, g.shape)
    assert t_buf % bm == 0 and n % bn == 0 and k % bk == 0, \
        ((t_buf, n, k), params)
    assert gid is not None and row_end is not None
    assert gid.shape == (t_buf // bm,) and row_end.shape == (n_groups,), \
        (gid.shape, row_end.shape, t_buf // bm, n_groups)
    from .. import search
    need = MXU if spec.ft_level == "tile" else 1
    assert bk % need == 0, (params, spec)   # "tile" bands slice dw's K rows
    assert bm % search.sublane(x.dtype.itemsize) == 0, (params, spec)

    grid = (k // bk, n // bn, t_buf // bm)
    out_dtype = out_dtype or jnp.float32    # dw is a gradient — default f32
    n_bands = bk // MXU if spec.ft_level == "tile" else 1
    ft = ft or FTConfig(level=spec.ft_level if spec.ft else "block",
                        action="correct" if spec.ft else "off")
    kernel = emit.render_tgmm(
        spec, t_tiles=grid[2], bm=bm, bn=bn, bk=bk, n_bands=n_bands,
        verify_step=(ft.verify == "step"), corrects=ft.corrects,
        rel_tau=ft.rel_tau, inject_rate=ft.inject_rate,
        bit_shift=ft.inject_bit_shift, grid_k=grid[0], grid_n=grid[1])
    lay = emit.layout(spec)

    if spec.ft:
        assert inj_idx is not None and inj_mag is not None
        if rng is None:
            rng = jnp.zeros((3,), jnp.int32)
        if dims is None:
            dims = jnp.array([t_buf, n, k], jnp.int32)
        prefetch = [inj_idx, inj_mag, rng, dims]
    else:
        assert dims is not None
        prefetch = [dims]
    prefetch += [gid, row_end]
    gpos = len(prefetch) - 2                # index of `gid` among scalar refs
    assert len(prefetch) == lay.n_prefetch, (len(prefetch), lay)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda ki, ni, t, *_: (t, ki)),
        pl.BlockSpec((bm, bn), lambda ki, ni, t, *_: (t, ni)),
    ]
    # Output-stationary: the scalar-prefetched owning group IS the leading
    # output block index — the accumulator stays resident across the
    # group's contiguous row-tile range and flushes at the boundary.
    out_specs = [pl.BlockSpec((1, bk, bn),
                              lambda ki, ni, t, *pf: (pf[gpos][t], ki, ni))]
    out_shape = [jax.ShapeDtypeStruct((n_groups, k, n), out_dtype)]
    scratch = [pltpu.VMEM((bk, bn), jnp.dtype(spec.acc_dtype))]
    if spec.ft:
        out_specs.append(pl.BlockSpec(
            (1, 1, 1, REPORT_WIDTH),
            lambda ki, ni, t, *pf: (pf[gpos][t], ki, ni, 0)))
        out_shape.append(jax.ShapeDtypeStruct(
            (n_groups, grid[0], grid[1], REPORT_WIDTH), jnp.float32))
        scratch += [pltpu.VMEM((n_bands, bn), jnp.float32),
                    pltpu.VMEM((bk, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if spec.ft else out_specs[0],
        scratch_shapes=scratch,
    )
    call = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=out_shape if spec.ft else out_shape[0],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret)
    result = call(*prefetch, x, g)
    if spec.ft:
        out, rep = result
        return out, rep
    return result, None


@functools.partial(jax.jit,
                   static_argnames=("spec", "params", "ft", "interpret",
                                    "out_dtype"))
def batched_kernel_call(a: jax.Array, b: jax.Array,
                        inj_idx: Optional[jax.Array] = None,
                        inj_mag: Optional[jax.Array] = None,
                        rng: Optional[jax.Array] = None,
                        dims: Optional[jax.Array] = None,
                        gid: Optional[jax.Array] = None,
                        row_end: Optional[jax.Array] = None, *,
                        spec: BatchedKernelSpec, params: KernelParams,
                        ft: Optional[FTConfig] = None,
                        interpret: bool = False, out_dtype=None):
    """Launch a `BatchedKernelSpec` variant. Returns (C, report|None).

    Uniform batched (``spec.grouped=False``): a (B, M, K); b (B, K, N), or
    (K, N) with ``shared_b``; the grid gains a leading batch axis and the
    report becomes (B, gm, gn, W). ``inj_idx`` is the 5-wide batched layout
    int32[5] = [enable, batch, row, col, k_step].

    Grouped (``spec.grouped=True``): a (T_buf, K) row-sorted token buffer
    whose groups start on bm boundaries; b (G, K, N); ``gid`` int32[T_buf/bm]
    maps each row tile to its owning group (drives B's index map);
    ``row_end`` int32[G] is each group's first dead buffer row (in-kernel
    ragged group-edge mask). ``inj_idx`` keeps the 2-D 4-wide layout with
    rows in global buffer coordinates. The grid/report stay 3-D: the grouped
    launch is a 2-D GEMM over the buffer with per-tile B selection."""
    grouped = spec.grouped
    bm, bn, bk = params.bm, params.bn, params.bk
    if grouped:
        t_buf, k = a.shape
        ng, k2, n = b.shape
        assert k == k2, (a.shape, b.shape)
        assert t_buf % bm == 0 and n % bn == 0 and k % bk == 0, \
            ((t_buf, n, k), params)
        assert gid is not None and row_end is not None
        assert gid.shape == (t_buf // bm,) and row_end.shape == (ng,), \
            (gid.shape, row_end.shape, t_buf // bm, ng)
        grid = (t_buf // bm, n // bn, k // bk)
        batch = None
    else:
        batch, m, k = a.shape
        if spec.shared_b:
            k2, n = b.shape
        else:
            b2, k2, n = b.shape
            assert b2 == batch, (a.shape, b.shape)
        assert k == k2, (a.shape, b.shape)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
            ((m, n, k), params)
        grid = (batch, m // bm, n // bn, k // bk)
    from .. import search
    need = MXU if (spec.ft_level == "tile" or not spec.masked) \
        else search.sublane(a.dtype.itemsize)
    assert bm % need == 0, (params, spec)

    out_dtype = out_dtype or (jnp.dtype(spec.out_dtype) if spec.out_dtype
                              else a.dtype)
    n_bands = bm // MXU if spec.ft_level == "tile" else 1
    ft = ft or FTConfig(level=spec.ft_level if spec.ft else "block",
                        action="correct" if spec.ft else "off")
    kernel = emit.render(
        spec, k_steps=grid[-1], bm=bm, bn=bn, bk=bk, n_bands=n_bands,
        verify_step=(ft.verify == "step"), corrects=ft.corrects,
        rel_tau=ft.rel_tau, inject_rate=ft.inject_rate,
        bit_shift=ft.inject_bit_shift,
        grid_m=grid[0] if grouped else grid[1],
        grid_n=grid[1] if grouped else grid[2],
        grid_b=1 if grouped else grid[0])
    lay = emit.layout(spec)

    prefetch = []
    if spec.ft:
        assert inj_idx is not None and inj_mag is not None
        if rng is None:
            rng = jnp.zeros((3,), jnp.int32)
        if dims is None:
            dims = (jnp.array([a.shape[0], n, k], jnp.int32) if grouped
                    else jnp.array([m, n, k], jnp.int32))
        prefetch = [inj_idx, inj_mag, rng, dims]
    elif spec.masked:
        assert dims is not None
        prefetch = [dims]
    if grouped:
        prefetch += [gid, row_end]
    gpos = len(prefetch) - 2            # index of `gid` among scalar refs

    if grouped:
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, s, *_: (i, s)),
            # The group id *is* the block index of B — the scalar-prefetched
            # tile→group map drives which expert's weights stream in.
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, s, *pf: (pf[gpos][i], s, j)),
        ]
        out_specs = [pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j))]
        out_shape = [jax.ShapeDtypeStruct((t_buf, n), out_dtype)]
        rep_spec = pl.BlockSpec((1, 1, REPORT_WIDTH),
                                lambda i, j, s, *_: (i, j, 0))
        rep_shape = jax.ShapeDtypeStruct(
            (grid[0], grid[1], REPORT_WIDTH), jnp.float32)
        semantics = (pltpu.PARALLEL, pltpu.PARALLEL, pltpu.ARBITRARY)
    else:
        in_specs = [
            pl.BlockSpec((1, bm, bk), lambda g, i, j, s, *_: (g, i, s)),
            (pl.BlockSpec((bk, bn), lambda g, i, j, s, *_: (s, j))
             if spec.shared_b else
             pl.BlockSpec((1, bk, bn), lambda g, i, j, s, *_: (g, s, j))),
        ]
        out_specs = [pl.BlockSpec((1, bm, bn),
                                  lambda g, i, j, s, *_: (g, i, j))]
        out_shape = [jax.ShapeDtypeStruct((batch, m, n), out_dtype)]
        rep_spec = pl.BlockSpec((1, 1, 1, REPORT_WIDTH),
                                lambda g, i, j, s, *_: (g, i, j, 0))
        rep_shape = jax.ShapeDtypeStruct(
            (batch, grid[1], grid[2], REPORT_WIDTH), jnp.float32)
        semantics = (pltpu.PARALLEL, pltpu.PARALLEL, pltpu.PARALLEL,
                     pltpu.ARBITRARY)

    scratch = [pltpu.VMEM((bm, bn), jnp.dtype(spec.acc_dtype))]
    if spec.ft:
        out_specs.append(rep_spec)
        out_shape.append(rep_shape)
        scratch += [pltpu.VMEM((n_bands, bn), jnp.float32),
                    pltpu.VMEM((bm, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32)]
    assert len(prefetch) == lay.n_prefetch, (len(prefetch), lay)

    compiler_params = _CompilerParams(dimension_semantics=semantics)
    if prefetch:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs if spec.ft else out_specs[0],
            scratch_shapes=scratch,
        )
        call = pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=out_shape if spec.ft else out_shape[0],
            compiler_params=compiler_params, interpret=interpret)
        result = call(*prefetch, a, b)
    else:
        call = pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs, out_specs=out_specs[0],
            out_shape=out_shape[0], scratch_shapes=scratch,
            compiler_params=compiler_params, interpret=interpret)
        result = call(a, b)

    if spec.ft:
        out, rep = result
        return out, rep
    return result, None
