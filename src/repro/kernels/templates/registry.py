"""Variant registry: KernelSpec + KernelParams → a ready pallas_call.

`kernel_call` is the single launch point every GEMM kernel in the repo now
routes through — `kernels.gemm.gemm/gemm_masked`, `kernels.ftgemm.ft_gemm`,
and `kernels.ops.gemm_call` are all thin wrappers over it. Rendering and
compilation are memoized by jit's static-argument cache (the spec and
params are frozen dataclasses), so each (spec, params, grid) variant is
rendered once per process.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policy import FTConfig
from ..pallas_compat import CompilerParams as _CompilerParams
from ..autotune import MXU, KernelParams
from . import emit
from .spec import KernelSpec

REPORT_WIDTH = emit.REPORT_WIDTH


def validate(spec: KernelSpec, params: KernelParams, m: int, n: int, k: int,
             in_bytes: int = 4) -> None:
    """Static legality of a launch: the operands must divide the tile grid,
    and bm must respect the variant's alignment floor — MXU-aligned for
    unmasked tiles and for "tile" mode (whose per-band checksums slice the
    accumulator in MXU-row bands), sublane-aligned for masked ragged
    tiles."""
    bm, bn, bk = params.bm, params.bn, params.bk
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        ((m, n, k), params, spec)
    from .. import search
    need = MXU if (spec.ft_level == "tile" or not spec.masked) \
        else search.sublane(in_bytes)
    assert bm % need == 0, (params, spec)


@functools.partial(jax.jit,
                   static_argnames=("spec", "params", "ft", "interpret",
                                    "out_dtype"))
def kernel_call(a: jax.Array, b: jax.Array,
                bias: Optional[jax.Array] = None,
                residual: Optional[jax.Array] = None,
                inj_idx: Optional[jax.Array] = None,
                inj_mag: Optional[jax.Array] = None,
                dims: Optional[jax.Array] = None, *,
                spec: KernelSpec, params: KernelParams,
                ft: Optional[FTConfig] = None,
                interpret: bool = False, out_dtype=None):
    """Launch the rendered variant. Returns (C, report) — report is None
    for non-FT specs.

    Operand contract (enforced by `kernels.ops.gemm_call`, the padding
    front door): a (M, K), b (K, N) padded to the tile grid; bias (1, N)
    and residual (M, N) zero-padded likewise; for FT specs inj_idx int32[4]
    / inj_mag f32[1] (see `ftgemm.encode_injection`); dims int32[3] true
    (m, n, k) for masked specs (ignored but required for unmasked FT)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    validate(spec, params, m, n, k, a.dtype.itemsize)
    bm, bn, bk = params.bm, params.bn, params.bk
    grid = (m // bm, n // bn, k // bk)
    out_dtype = out_dtype or (jnp.dtype(spec.out_dtype) if spec.out_dtype
                              else a.dtype)
    n_bands = bm // MXU if spec.ft_level == "tile" else 1
    ft = ft or FTConfig(level=spec.ft_level if spec.ft else "block",
                        action="correct" if spec.ft else "off")

    kernel = emit.render(
        spec, k_steps=grid[2], bm=bm, bn=bn, bk=bk, n_bands=n_bands,
        verify_step=(ft.verify == "step"), corrects=ft.corrects,
        rel_tau=ft.rel_tau)
    lay = emit.layout(spec)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s, *_: (i, s)),
        pl.BlockSpec((bk, bn), lambda i, j, s, *_: (s, j)),
    ]
    operands = [a, b]
    if spec.needs_bias:
        assert bias is not None and bias.shape == (1, n), \
            (None if bias is None else bias.shape, n)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s, *_: (0, j)))
        operands.append(bias)
    if spec.needs_residual:
        assert residual is not None and residual.shape == (m, n), \
            (None if residual is None else residual.shape, (m, n))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j)))
        operands.append(residual)

    out_specs = [pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((m, n), out_dtype)]
    scratch = [pltpu.VMEM((bm, bn), jnp.dtype(spec.acc_dtype))]
    prefetch = []
    if spec.ft:
        assert inj_idx is not None and inj_mag is not None
        if dims is None:
            dims = jnp.array([m, n, k], jnp.int32)
        prefetch = [inj_idx, inj_mag, dims]
        out_specs.append(pl.BlockSpec((1, 1, REPORT_WIDTH),
                                      lambda i, j, s, *_: (i, j, 0)))
        out_shape.append(jax.ShapeDtypeStruct(
            (grid[0], grid[1], REPORT_WIDTH), jnp.float32))
        scratch += [pltpu.VMEM((n_bands, bn), jnp.float32),
                    pltpu.VMEM((bm, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32),
                    pltpu.SMEM((1, 1), jnp.float32)]
    elif spec.masked:
        assert dims is not None
        prefetch = [dims]
    assert len(prefetch) == lay.n_prefetch and len(operands) == lay.n_inputs

    compiler_params = _CompilerParams(
        dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                             pltpu.ARBITRARY))

    if prefetch:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs if spec.ft else out_specs[0],
            scratch_shapes=scratch,
        )
        call = pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=out_shape if spec.ft else out_shape[0],
            compiler_params=compiler_params, interpret=interpret)
        result = call(*prefetch, *operands)
    else:
        call = pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs, out_specs=out_specs[0],
            out_shape=out_shape[0], scratch_shapes=scratch,
            compiler_params=compiler_params, interpret=interpret)
        result = call(*operands)

    if spec.ft:
        out, rep = result
        return out, rep
    return result, None
