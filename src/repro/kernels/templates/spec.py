"""`KernelSpec` — the declarative description of one GEMM kernel variant.

A spec names a point in the template subsystem's variant space:

    ft_level (off/inner/tile/block)  ×  masked/plain dispatch
        ×  epilogue chain (bias, activation, residual, …)
        ×  accumulate dtype  ×  output dtype cast

`templates.emit.render` turns a spec into a single parameterized Pallas
kernel body; `templates.registry.kernel_call` wraps it in the pallas_call;
`kernels.ops.gemm_call` is the dispatching front door. The spec is frozen
and hashable so it can serve as a jit static argument and as part of the
autotuning cache key (`variant_key`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from . import epilogues

FT_LEVELS = ("off", "inner", "tile", "block")

#: Registered *derived* kernel outputs ("multi-output" support, PR 4).
#: "act_grad" — the derivative of the chain's (single) nonlinear activation
#: evaluated at the pre-activation, written as a second VMEM output from the
#: forward kernel so a custom_vjp can consume a saved residual instead of
#: recomputing the pre-activation GEMM in the backward pass. It is computed
#: from the *corrected* accumulator (after the folded checksum comparison),
#: so an SEU corrected in the forward kernel never reaches the saved grad.
EXTRA_OUTPUTS = ("act_grad",)

#: dtype string → (short tag, element bytes) for variant keys / VMEM math.
_DTYPES = {"float32": ("f32", 4), "bfloat16": ("bf16", 2),
           "float16": ("f16", 2)}


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    ft_level: str = "off"
    masked: bool = False
    epilogue: Tuple[str, ...] = ()
    acc_dtype: str = "float32"
    out_dtype: Optional[str] = None   # None → follow the input dtype
    #: Derived second-class outputs the kernel writes alongside C (see
    #: `EXTRA_OUTPUTS`). Each adds one (bm, bn) VMEM output block and one
    #: (M, N) HBM stream — the autotuner charges both.
    extra_outputs: Tuple[str, ...] = ()

    #: Structure flags the emitter branches on. The base spec is the 2-D
    #: GEMM; `BatchedKernelSpec` overrides these (kept as plain class
    #: attributes so they are not dataclass fields / not part of equality
    #: for the 2-D case).
    batched = False
    grouped = False
    shared_b = False
    tgmm = False
    flash = False

    def __post_init__(self):
        if self.ft_level not in FT_LEVELS:
            raise ValueError(f"ft_level must be one of {FT_LEVELS}, "
                             f"got {self.ft_level!r}")
        object.__setattr__(self, "epilogue", tuple(self.epilogue))
        seen_aux = set()
        for name in self.epilogue:
            op = epilogues.get(name)            # raises on unknown ops
            if op.aux is not None:
                if op.aux in seen_aux:
                    raise ValueError(f"chain {self.epilogue} streams two "
                                     f"'{op.aux}' aux operands")
                seen_aux.add(op.aux)
        if self.acc_dtype not in _DTYPES:
            raise ValueError(f"unsupported acc_dtype {self.acc_dtype!r}")
        if self.ft and self.acc_dtype != "float32":
            raise ValueError("FT variants accumulate in float32 (the "
                             "checksum algebra's dtype)")
        if self.out_dtype is not None and self.out_dtype not in _DTYPES:
            raise ValueError(f"unsupported out_dtype {self.out_dtype!r}")
        object.__setattr__(self, "extra_outputs", tuple(self.extra_outputs))
        for name in self.extra_outputs:
            if name not in EXTRA_OUTPUTS:
                raise ValueError(f"unknown extra output {name!r}; "
                                 f"registered: {EXTRA_OUTPUTS}")
        if "act_grad" in self.extra_outputs:
            nonlin = [n for n in self.epilogue
                      if not epilogues.get(n).linear]
            if len(nonlin) != 1:
                raise ValueError(
                    "act_grad needs exactly one nonlinear op in the chain "
                    f"(the saved act'(preact) residual), got {self.epilogue}")
            if epilogues.get(nonlin[0]).grad is None:
                raise ValueError(f"epilogue '{nonlin[0]}' has no registered "
                                 f"derivative — cannot emit act_grad")

    # -- structure ---------------------------------------------------------

    @property
    def ft(self) -> bool:
        return self.ft_level != "off"

    @property
    def needs_bias(self) -> bool:
        return any(epilogues.get(n).aux == "vector" for n in self.epilogue)

    @property
    def needs_residual(self) -> bool:
        return any(epilogues.get(n).aux == "tile" for n in self.epilogue)

    def fold_split(self) -> int:
        """Index splitting the chain into the linear prefix (folded into the
        final checksum comparison, so verification runs post-epilogue) and
        the suffix applied after verification (everything from the first
        nonlinear op on)."""
        for i, name in enumerate(self.epilogue):
            if not epilogues.get(name).linear:
                return i
        return len(self.epilogue)

    # -- autotuning hooks --------------------------------------------------

    def variant_key(self) -> str:
        """Canonical variant component of the tuning-cache key. Empty for
        the plain default variant so PR-1 cache entries stay valid."""
        parts = []
        if self.epilogue:
            parts.append("+".join(self.epilogue))
        if self.extra_outputs:
            parts.append("xo_" + "+".join(self.extra_outputs))
        if self.acc_dtype != "float32":
            parts.append(f"acc{_DTYPES[self.acc_dtype][0]}")
        if self.out_dtype is not None:
            parts.append(f"out{_DTYPES[self.out_dtype][0]}")
        return ".".join(parts)

    def extra_vmem_bytes(self, bm: int, bn: int, in_bytes: int) -> int:
        """Added VMEM working set of the fused epilogue: double-buffered aux
        operand tiles (the accumulator itself is already counted by
        `KernelParams.vmem_bytes`), plus one (bm, bn) output block per extra
        output. Fused chains shift the budget, so the candidate search must
        see this."""
        extra = 0
        if self.needs_bias:
            extra += 2 * bn * in_bytes
        if self.needs_residual:
            extra += 2 * bm * bn * in_bytes
        extra += len(self.extra_outputs) * bm * bn * in_bytes
        return extra

    def vmem_bytes(self, params, in_bytes: int, ft_level: str) -> int:
        """The variant's full VMEM working set for one tile config — the
        single model shared by the candidate search and budget clamping.
        The base variant delegates to `KernelParams.vmem_bytes` and adds the
        fused-epilogue/extra-output buffers; structurally different bodies
        (the tgmm variant) override this wholesale."""
        return (params.vmem_bytes(in_bytes, ft_level)
                + self.extra_vmem_bytes(params.bm, params.bn, in_bytes))

    def epilogue_flops(self, me: int, ne: int) -> float:
        """Elementwise epilogue FLOPs over the executed output (a small
        roofline term — ~5 flops per nonlinear op element; an act_grad
        output pays roughly one more activation evaluation)."""
        per_elem = sum(1.0 if epilogues.get(n).linear else 5.0
                       for n in self.epilogue)
        per_elem += 5.0 * len(self.extra_outputs)
        return per_elem * me * ne

    def extra_hbm_bytes(self, me: int, ne: int, in_bytes: int) -> float:
        """Added HBM traffic of the fused variant: aux operands are read
        once, extra outputs are written once. (The unfused composition
        instead re-reads AND re-writes the whole C between passes — that
        delta is the fusion win the fused_epilogue benchmark reports.)"""
        extra = 0.0
        if self.needs_bias:
            extra += ne * in_bytes
        if self.needs_residual:
            extra += me * ne * in_bytes
        extra += len(self.extra_outputs) * me * ne * in_bytes
        return extra


@dataclasses.dataclass(frozen=True)
class BatchedKernelSpec(KernelSpec):
    """A `KernelSpec` with a leading batch grid axis (PR 3).

    Two operand regimes share the one emitted body:

      * uniform batched (``grouped=False``) — A (B, M, K) × B (B, K, N) (or a
        shared (K, N) right operand with ``shared_b=True``): the grid gains a
        leading batch dimension, every output block keeps its own running
        checksums/report row, and `masked` carries the (m, n, k) ragged edge
        shared by all batch slices. This is the `core.ft_batched_dot` kernel
        (attention QK/PV cores, per-expert matmuls on padded layouts).
      * grouped (``grouped=True``) — a CSR-style ragged grouped GEMM: A is a
        row-sorted (T_buf, K) token buffer whose groups start at row-tile
        (bm) boundaries, B is per-group (G, K, N), and two extra
        scalar-prefetch operands drive the kernel: ``gid[num_tiles]`` (the
        group owning each row tile — it feeds the *index map* of B, so the
        right tile streams in per group) and ``row_end[G]`` (the first dead
        buffer row of each group — the in-kernel ragged group-edge mask).
        Because every row tile is wholly owned by one group, checksums,
        verification, and correction are naturally per group: an SEU in one
        expert's rows can never contaminate a neighboring group.
      * tgmm (``tgmm=True``, PR 4) — the grouped *transpose* GEMM of the MoE
        backward dw: ``dw[g] = X_gᵀ G_g`` over the same group-sorted buffer
        layout, but **output-stationary over (G, K, N)**: the grid's
        innermost axis walks row tiles (the reduction dim), the output block
        index is the scalar-prefetched owning group, and the accumulator +
        running per-group checksums flush whenever the group id changes
        between consecutive row tiles (groups are contiguous in the buffer,
        so each (g, k, n) output block is visited over one contiguous tile
        range). Exactly the useful T·K·N FLOPs — the only padding is the
        same ≤ G·(bm-1) alignment rows the forward grouped kernel pays.

    Aux-operand epilogues (bias/residual) would need per-batch streams; the
    batched variants support aux-free chains only (activations etc.), and
    the tgmm variant is epilogue-free (it produces a gradient).
    """
    shared_b: bool = False
    grouped: bool = False
    tgmm: bool = False

    batched = True

    def __post_init__(self):
        super().__post_init__()
        if self.grouped or self.tgmm:
            if self.shared_b:
                raise ValueError("grouped GEMM has per-group B operands")
            if self.grouped and self.tgmm:
                raise ValueError("tgmm is its own body — not grouped=True")
            # Grouped/tgmm dispatch always masks the ragged group edges.
            object.__setattr__(self, "masked", True)
        if self.needs_bias or self.needs_residual:
            raise ValueError("batched/grouped variants support aux-free "
                             f"epilogue chains only, got {self.epilogue}")
        if self.tgmm and self.epilogue:
            raise ValueError("the tgmm variant is epilogue-free, got "
                             f"{self.epilogue}")
        if self.extra_outputs:
            raise ValueError("extra outputs are a 2-D variant feature")

    def variant_key(self) -> str:
        """Batched variants render a different body (batch axis / group
        metadata), so they never share a cache entry with the 2-D kernel
        even for an empty epilogue chain. The batch/group *count* component
        (`/b_*` / `/g_*`) is added separately by `tune_cache.cache_key`."""
        base = super().variant_key()
        tag = ("tgmm" if self.tgmm else
               "grouped" if self.grouped else
               "batched_sharedB" if self.shared_b else "batched")
        return f"{base}.{tag}" if base else tag

    def vmem_bytes(self, params, in_bytes: int, ft_level: str) -> int:
        """The tgmm body holds a different working set than the forward
        template: operand tiles are (bm, bk) + (bm, bn) slices of the two
        buffers, the accumulator is the (bk, bn) *output* block, and the
        checksum scratch follows the output block's row count (bk)."""
        if not self.tgmm:
            return super().vmem_bytes(params, in_bytes, ft_level)
        bm, bn, bk = params.bm, params.bn, params.bk
        operands = 2 * (bm * bk + bm * bn) * in_bytes
        acc = bk * bn * 4
        if ft_level == "off":
            return operands + acc
        from ..autotune import MXU
        n_bands = bk // MXU if ft_level == "tile" else 1
        return operands + acc + max(n_bands, 1) * bn * 4 + bk * 4


@dataclasses.dataclass(frozen=True)
class FlashKernelSpec(KernelSpec):
    """Variant descriptor for the flash-attention kernel family (PR 5).

    The flash kernels (`kernels.flashft`) are not emitted by `emit.render` —
    online softmax is its own body — but they ARE registry variants: each
    direction has its own working set, roofline, and therefore its own
    autotuning cache key. This spec is the handle the autotuner pipeline
    (`autotune.best_params` → `search` → `tune_cache`) uses for them.

    The (m, n, k) problem dims map to the attention geometry as
    (stationary seq dim, streamed seq dim, lane-padded head dim): the tile
    params come back as bm → the stationary block (bq for "fwd"/"dq", bkv
    for "dkv"), bn → the streamed block, bk → advisory only (the head dim is
    always streamed whole — `vmem_bytes` models it via `self.dh`, never
    `params.bk`).

    Directions:
      * "fwd" — the forward kernel (2 in-kernel GEMMs: S = QKᵀ, Δ = PV);
        ``save_stats`` adds the per-row (m, l) softmax-statistic outputs the
        dedicated backward consumes.
      * "decode" — the paged single-position serving kernel (PR 9): same
        2-GEMM online-softmax body as "fwd" but the stationary block is one
        kv head's GQA query rows, the streamed block is ONE KV-cache page
        routed through a scalar-prefetched page table, and per-row ragged
        true lengths (an ``int32[B]`` vector, not one (Sq, Skv) pair)
        bound both the masking and the checksum-verify τ.
      * "dq"  — q-block-stationary backward: recomputes S from the saved
        stats and runs dP = g·Vᵀ and dQ = dS·K (3 GEMMs).
      * "dkv" — kv-block-stationary backward: S recompute + dP = g·Vᵀ,
        dV = Pᵀ·g, dK = dSᵀ·Q (4 GEMMs).

    Cache-key tags are ``flashfwd[_stats]`` / ``flashdecode`` /
    ``flashbwd_dq`` / ``flashbwd_dkv`` — new ``/v_*`` components, so
    existing cache entries (plain GEMM, fused, batched, tgmm) are
    untouched.
    """
    direction: str = "fwd"
    dh: int = 128            # lane-padded head dim (streamed whole)
    save_stats: bool = False

    flash = True

    _GEMMS = {"fwd": 2, "decode": 2, "dq": 3, "dkv": 4}

    def __post_init__(self):
        super().__post_init__()
        if self.direction not in self._GEMMS:
            raise ValueError(f"flash direction must be one of "
                             f"{tuple(self._GEMMS)}, got {self.direction!r}")
        if self.dh % 128 != 0 or self.dh <= 0:
            raise ValueError(f"flash dh must be lane-padded (128-multiple), "
                             f"got {self.dh}")
        if self.epilogue or self.extra_outputs:
            raise ValueError("flash variants take no epilogue chain / extra "
                             "outputs (softmax statistics are built in)")
        if self.save_stats and self.direction != "fwd":
            raise ValueError("save_stats is a forward-direction feature")

    def variant_key(self) -> str:
        tag = {"fwd": "flashfwd", "decode": "flashdecode",
               "dq": "flashbwd_dq", "dkv": "flashbwd_dkv"}[self.direction]
        if self.save_stats:
            tag += "_stats"
        return tag

    def vmem_bytes(self, params, in_bytes: int, ft_level: str) -> int:
        """Flash working set: double-buffered operand tiles over the full
        head dim, the f32 accumulator(s), the (stationary × streamed) score
        transients, and the per-row statistic columns. ``params.bk`` is
        ignored — the head dim never tiles."""
        bs, bt = params.bm, params.bn          # stationary / streamed blocks
        dh = self.dh
        trans = 3 * bs * bt * 4                # scores, p, ds (≤3 live)
        if self.direction in ("fwd", "decode"):
            tiles = 2 * (bs * dh + 2 * bt * dh) * in_bytes
            acc = bs * dh * 4 + 2 * bs * 4     # acc + m/l scratch
            stats = 2 * bs * 4 if self.save_stats else 0
            return tiles + acc + trans + stats
        if self.direction == "dq":
            # stationary: q, g + (m, l, di); streamed: k, v
            tiles = 2 * ((2 * bs + 2 * bt) * dh + 3 * bs) * in_bytes
            acc = bs * dh * 4
            return tiles + acc + trans
        # "dkv" — stationary: k, v; streamed: q, g + (m, l, di)
        tiles = 2 * ((2 * bs + 2 * bt) * dh + 3 * bt) * in_bytes
        acc = 2 * bs * dh * 4                  # dk and dv accumulators
        return tiles + acc + trans

    def epilogue_flops(self, me: int, ne: int) -> float:
        """Extra per-(stationary × streamed) element work beyond the one
        S-GEMM the base roofline charges: the remaining in-kernel GEMMs
        (each 2·dh MACs per score element) plus the softmax/rescale
        elementwise chain."""
        extra_gemms = self._GEMMS[self.direction] - 1
        return (extra_gemms * 2.0 * self.dh + 12.0) * me * ne

    def extra_hbm_bytes(self, me: int, ne: int, in_bytes: int) -> float:
        """Streams beyond the base model's A/B/C accounting: the second
        stationary operand (g for the backwards), the f32 statistic columns,
        and the extra gradient output of the dkv direction."""
        extra = 0.0
        if self.direction in ("dq", "dkv"):
            extra += me * self.dh * in_bytes       # g rides with q
            extra += 3 * me * 4                    # m, l, di columns
        elif self.save_stats:
            extra += 2 * me * 4                    # m, l written once
        if self.direction == "dkv":
            extra += me * self.dh * 4              # second (dk) output, f32
        return extra


def fused(bias: bool = False, act: Optional[str] = None,
          residual: bool = False, *, ft_level: str = "off",
          out_dtype: Optional[str] = None) -> KernelSpec:
    """Canonical-order spec builder: y = act(A·B + bias) + residual, cast to
    out_dtype — the matmul→bias→activation(→residual) sequence the model
    blocks used to run as separate passes."""
    chain = []
    if bias:
        chain.append("bias")
    if act is not None:
        epilogues.get(act)
        chain.append(act)
    if residual:
        chain.append("residual")
    return KernelSpec(ft_level=ft_level, epilogue=tuple(chain),
                      out_dtype=out_dtype)
