"""Fused fault-tolerant GEMM entry point — the paper's core contribution
(§4) adapted to TPU (DESIGN.md §2).

Checksum encodings (Huang–Abraham) are maintained **inside the kernel** from
operand tiles already resident in VMEM — the TPU analogue of the paper's
"fuse all ABFT memory operations with the prefetching stage": zero extra HBM
traffic, checksum updates ride the same VMEM residency as the GEMM itself.

Three granularities mirroring the paper's thread/warp/threadblock ablation:

  mode="inner"  (thread-level analogue)  — every k-step's contribution
      Δ = A_ik·B_kj is verified *independently* (no running checksum state):
      Δ is materialized, reduced, checked, then accumulated. Highest
      overhead: extra accumulator traffic + per-step full reductions.
  mode="tile"   (warp-level analogue)    — running checksums kept per
      128-row MXU band (extra VMEM scratch reads/writes each step, finer
      error localization: one correctable SEU per band per interval).
  mode="block"  (threadblock-level analogue, the paper's winner) — one
      running (col, row) checksum pair per output block, updated with two
      GEMVs per k-step; verification per k-step (verify="step", the online
      scheme) or once per tile (verify="final").

Error injection (paper §5.3): a scalar-prefetch spec
[enable, row, col, k_step] + magnitude adds an offset to the accumulator at
the given global coordinates after k-step `k_step` — emulating a compute-unit
SEU in the accumulation registers. Detection → location → **branchless
correction** happen in-kernel, on-line.

Since PR 2 the kernel body is *generated*: `ft_gemm` is a registry lookup
(`templates.registry.kernel_call`) on the FT `KernelSpec` for the requested
level/masking — the same single-source template that also emits the non-FT
and fused-epilogue variants (epilogue chains ride `ops.gemm_call`; this
entry keeps the bare-FT signature).

Outputs: (C, report) where report[i, j] = [detected, corrected, row, col,
magnitude, max_residual, tau, k_elapsed] per output block (f32).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import FTConfig, InjectionSpec
from .autotune import KernelParams
from .templates import registry
from .templates.emit import F32EPS, REPORT_WIDTH          # noqa: F401 (re-export)
from .templates.spec import KernelSpec


def ft_gemm(a: jax.Array, b: jax.Array,
            inj_idx: jax.Array, inj_mag: jax.Array, *,
            params: Optional[KernelParams] = None, ft: FTConfig,
            interpret: bool = False, out_dtype=None,
            dims: Optional[jax.Array] = None):
    """Fused FT-GEMM on tile-divisible shapes. inj_idx: int32[4]
    [enable,row,col,k_step]; inj_mag: f32[1]. Returns (C, report).

    params=None routes through the autotuner (`autotune.best_params`, which
    consults the persistent tuning cache) — the given shapes must then
    divide the selected tiles, so `ops.ft_matmul*` (which pads/masks first)
    is the entry for arbitrary shapes.

    dims — optional int32[3] true (m, n, k) for the masked ragged path: the
    operand arrays are padded only to the fitted tile grid and the kernel
    masks the partial edge tiles (checksum math included) in-kernel."""
    m, k = a.shape
    _, n = b.shape
    if params is None:
        from . import autotune
        params = autotune.best_params(m, n, k, a.dtype.itemsize,
                                      ft_level=ft.level)
    spec = KernelSpec(ft_level=ft.level, masked=dims is not None)
    return registry.kernel_call(a, b, inj_idx=inj_idx, inj_mag=inj_mag,
                                dims=dims, spec=spec, params=params, ft=ft,
                                interpret=interpret, out_dtype=out_dtype)


def encode_injection(spec: Optional[InjectionSpec]):
    """InjectionSpec → (int32[4], f32[1]) kernel operands."""
    if spec is None:
        return (jnp.zeros((4,), jnp.int32), jnp.zeros((1,), jnp.float32))
    idx = jnp.array([1, spec.row, spec.col, spec.k_step], jnp.int32)
    mag = jnp.array([spec.magnitude], jnp.float32)
    return idx, mag
