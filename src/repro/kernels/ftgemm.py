"""Fused fault-tolerant GEMM Pallas kernel — the paper's core contribution
(§4) adapted to TPU (DESIGN.md §2).

Checksum encodings (Huang–Abraham) are maintained **inside the kernel** from
operand tiles already resident in VMEM — the TPU analogue of the paper's
"fuse all ABFT memory operations with the prefetching stage": zero extra HBM
traffic, checksum updates ride the same VMEM residency as the GEMM itself.

Three granularities mirroring the paper's thread/warp/threadblock ablation:

  mode="inner"  (thread-level analogue)  — every k-step's contribution
      Δ = A_ik·B_kj is verified *independently* (no running checksum state):
      Δ is materialized, reduced, checked, then accumulated. Highest
      overhead: extra accumulator traffic + per-step full reductions.
  mode="tile"   (warp-level analogue)    — running checksums kept per
      128-row MXU band (extra VMEM scratch reads/writes each step, finer
      error localization: one correctable SEU per band per interval).
  mode="block"  (threadblock-level analogue, the paper's winner) — one
      running (col, row) checksum pair per output block, updated with two
      GEMVs per k-step; verification per k-step (verify="step", the online
      scheme) or once per tile (verify="final").

Error injection (paper §5.3): a scalar-prefetch spec
[enable, row, col, k_step] + magnitude adds an offset to the accumulator at
the given global coordinates after k-step `k_step` — emulating a compute-unit
SEU in the accumulation registers. Detection → location → **branchless
correction** happen in-kernel, on-line.

Outputs: (C, report) where report[i, j] = [detected, corrected, row, col,
magnitude, max_residual, tau, k_elapsed] per output block (f32).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams as _CompilerParams

from repro.core.policy import FTConfig, InjectionSpec
from .autotune import KernelParams, MXU

F32EPS = float(jnp.finfo(jnp.float32).eps)
REPORT_WIDTH = 8


def _iota2(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _ftgemm_kernel(inj_idx_ref, inj_mag_ref, dims_ref,  # scalar prefetch
                   a_ref, b_ref,                      # VMEM inputs
                   out_ref, rep_ref,                  # VMEM outputs
                   acc_ref, colck_ref, rowck_ref,     # VMEM scratch
                   amax_ref, bmax_ref,                # SMEM scratch
                   *, k_steps: int, bm: int, bn: int, bk: int,
                   mode: str, verify_step: bool, corrects: bool,
                   rel_tau: float, n_bands: int, masked: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    s = pl.program_id(2)
    last = s == k_steps - 1

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        colck_ref[...] = jnp.zeros_like(colck_ref)
        rowck_ref[...] = jnp.zeros_like(rowck_ref)
        amax_ref[0, 0] = 0.0
        bmax_ref[0, 0] = 0.0
        rep_ref[...] = jnp.zeros_like(rep_ref)

    a = a_ref[...]
    b = b_ref[...]
    if masked:
        # Ragged dispatch: zero everything past the true (m, n, k) carried
        # in via scalar prefetch. The checksum math below then sees exactly
        # zero-padding semantics (checksums of zero rows/cols are zero), so
        # ABFT detection/correction survives the ragged edges, and garbage
        # in the padded region (even NaN/Inf) cannot leak into either the
        # accumulator or the running checksums.
        tm, tn, tk = dims_ref[0], dims_ref[1], dims_ref[2]
        a_ok = ((i * bm + _iota2((bm, bk), 0) < tm)
                & (s * bk + _iota2((bm, bk), 1) < tk))
        b_ok = ((s * bk + _iota2((bk, bn), 0) < tk)
                & (j * bn + _iota2((bk, bn), 1) < tn))
        a = jnp.where(a_ok, a, jnp.zeros_like(a))
        b = jnp.where(b_ok, b, jnp.zeros_like(b))
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    # Running operand-magnitude bounds for the rounding-aware threshold —
    # free: the tiles are already in VMEM (the "fused with prefetch" point).
    amax_ref[0, 0] = jnp.maximum(amax_ref[0, 0], jnp.max(jnp.abs(af)))
    bmax_ref[0, 0] = jnp.maximum(bmax_ref[0, 0], jnp.max(jnp.abs(bf)))
    k_elapsed = (s + 1).astype(jnp.float32) * bk
    if masked:
        # Rounding-error accumulation stops at the true K.
        k_elapsed = jnp.minimum(k_elapsed, dims_ref[2].astype(jnp.float32))
    tau = jnp.maximum(rel_tau * F32EPS * k_elapsed
                      * amax_ref[0, 0] * bmax_ref[0, 0], 1e-30)

    delta = jnp.dot(a, b, preferred_element_type=jnp.float32)

    # ---- emulated SEU (scalar-prefetched spec) --------------------------
    enable, g_row, g_col, inj_k = (inj_idx_ref[0], inj_idx_ref[1],
                                   inj_idx_ref[2], inj_idx_ref[3])
    r_loc = g_row - i * bm
    c_loc = g_col - j * bn
    hit_now = ((enable == 1) & (s == inj_k)
               & (r_loc >= 0) & (r_loc < bm) & (c_loc >= 0) & (c_loc < bn))
    hit_mask = ((_iota2((bm, bn), 0) == r_loc)
                & (_iota2((bm, bn), 1) == c_loc)
                & hit_now)
    delta = delta + jnp.where(hit_mask, inj_mag_ref[0], 0.0)

    # ---- checksum maintenance + verification ----------------------------
    if mode == "inner":
        # Verify this step's contribution in isolation (thread-level
        # analogue: smallest protected unit, no cross-step state).
        ck_col = jnp.dot(jnp.sum(af, axis=0, keepdims=True), bf)      # (1,bn)
        ck_row = jnp.dot(af, jnp.sum(bf, axis=1, keepdims=True))      # (bm,1)
        d_col = jnp.sum(delta, axis=0, keepdims=True) - ck_col
        d_row = jnp.sum(delta, axis=1, keepdims=True) - ck_row
        delta, det, mag, row_l, col_l = _locate_correct_full(
            delta, d_col, d_row, tau, corrects, bm, bn)
        acc_ref[...] += delta
        _record(rep_ref, det, mag, row_l + i * bm, col_l + j * bn,
                d_col, d_row, tau, k_elapsed, corrects)
    else:
        acc_ref[...] += delta
        if mode == "block":
            colck_ref[...] += jnp.dot(jnp.sum(af, axis=0, keepdims=True), bf)
        else:  # mode == "tile": one running column checksum per MXU band
            for t in range(n_bands):
                colck_ref[t:t + 1, :] += jnp.dot(
                    jnp.sum(af[t * MXU:(t + 1) * MXU], axis=0, keepdims=True),
                    bf)
        rowck_ref[...] += jnp.dot(af, jnp.sum(bf, axis=1, keepdims=True))

        do_verify = verify_step or (k_steps == 1)

        def _verify():
            acc = acc_ref[...]
            d_row = jnp.sum(acc, axis=1, keepdims=True) - rowck_ref[...]
            if mode == "block":
                d_col = (jnp.sum(acc, axis=0, keepdims=True)
                         - colck_ref[0:1, :])
                new_acc, det, mag, row_l, col_l = _locate_correct_full(
                    acc, d_col, d_row, tau, corrects, bm, bn)
                acc_ref[...] = new_acc
                _record(rep_ref, det, mag, row_l + i * bm, col_l + j * bn,
                        d_col, d_row, tau, k_elapsed, corrects)
            else:
                # Per-band verification & correction (one SEU per band).
                for t in range(n_bands):
                    band = acc[t * MXU:(t + 1) * MXU]
                    d_col = (jnp.sum(band, axis=0, keepdims=True)
                             - colck_ref[t:t + 1, :])
                    d_row_b = d_row[t * MXU:(t + 1) * MXU]
                    new_band, det, mag, row_l, col_l = _locate_correct_full(
                        band, d_col, d_row_b, tau, corrects, MXU, bn)
                    acc_ref[t * MXU:(t + 1) * MXU, :] = new_band
                    _record(rep_ref, det, mag,
                            row_l + i * bm + t * MXU, col_l + j * bn,
                            d_col, d_row_b, tau, k_elapsed, corrects)

        if do_verify:
            _verify()
        else:
            pl.when(last)(_verify)

    @pl.when(last)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _locate_correct_full(acc, d_col, d_row, tau, corrects, bm, bn):
    """Locate a single error from checksum residuals and (optionally) apply
    the branchless correction. Returns (acc', detected, magnitude, row, col)."""
    dc = d_col[0, :]
    dr = d_row[:, 0]
    col = jnp.argmax(jnp.abs(dc)).astype(jnp.int32)
    row = jnp.argmax(jnp.abs(dr)).astype(jnp.int32)
    mag_c = jnp.max(jnp.abs(dc))
    mag_r = jnp.max(jnp.abs(dr))
    detected = jnp.maximum(mag_c, mag_r) > tau
    # Canonical magnitude from the column residual (signed).
    mag = jnp.where(detected, jnp.sum(jnp.where(
        jax.lax.iota(jnp.int32, bn) == col, dc, 0.0)), 0.0)
    if corrects:
        hit = ((_iota2((bm, bn), 0) == row) & (_iota2((bm, bn), 1) == col)
               & detected)
        acc = acc - jnp.where(hit, mag, 0.0)
    return acc, detected, mag, row, col


def _record(rep_ref, det, mag, row_g, col_g, d_col, d_row, tau, k_elapsed,
            corrects):
    detf = det.astype(jnp.float32)
    resid = jnp.maximum(jnp.max(jnp.abs(d_col)), jnp.max(jnp.abs(d_row)))
    rep_ref[0, 0, 0] += detf
    rep_ref[0, 0, 1] += detf if corrects else 0.0
    rep_ref[0, 0, 2] = jnp.where(det, row_g.astype(jnp.float32),
                                 rep_ref[0, 0, 2])
    rep_ref[0, 0, 3] = jnp.where(det, col_g.astype(jnp.float32),
                                 rep_ref[0, 0, 3])
    rep_ref[0, 0, 4] = jnp.where(det, mag, rep_ref[0, 0, 4])
    rep_ref[0, 0, 5] = jnp.maximum(rep_ref[0, 0, 5], resid)
    rep_ref[0, 0, 6] = tau
    rep_ref[0, 0, 7] = k_elapsed


@functools.partial(jax.jit, static_argnames=("params", "ft", "interpret",
                                             "out_dtype"))
def ft_gemm(a: jax.Array, b: jax.Array,
            inj_idx: jax.Array, inj_mag: jax.Array, *,
            params: Optional[KernelParams] = None, ft: FTConfig,
            interpret: bool = False, out_dtype=None,
            dims: Optional[jax.Array] = None):
    """Fused FT-GEMM on tile-divisible shapes. inj_idx: int32[4]
    [enable,row,col,k_step]; inj_mag: f32[1]. Returns (C, report).

    params=None routes through the autotuner (`autotune.best_params`, which
    consults the persistent tuning cache) — the given shapes must then
    divide the selected tiles, so `ops.ft_matmul*` (which pads/masks first)
    is the entry for arbitrary shapes.

    dims — optional int32[3] true (m, n, k) for the masked ragged path: the
    operand arrays are padded only to the fitted tile grid and the kernel
    masks the partial edge tiles (checksum math included) in-kernel."""
    m, k = a.shape
    _, n = b.shape
    if params is None:
        from . import autotune
        params = autotune.best_params(m, n, k, a.dtype.itemsize,
                                      ft_level=ft.level)
    bm, bn, bk = params.bm, params.bn, params.bk
    masked = dims is not None
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, params)
    # Unmasked tiles stay MXU-aligned; masked tiles only need hardware
    # (sublane) alignment on bm — except "tile" mode, whose per-band
    # checksums slice the accumulator in MXU-row bands.
    assert bm % (MXU if (ft.level == "tile" or not masked) else 8) == 0, params
    out_dtype = out_dtype or a.dtype
    grid = (m // bm, n // bn, k // bk)
    n_bands = bm // MXU if ft.level == "tile" else 1
    if dims is None:
        dims = jnp.array([m, n, k], jnp.int32)

    kernel = functools.partial(
        _ftgemm_kernel, k_steps=grid[2], bm=bm, bn=bn, bk=bk,
        mode=ft.level, verify_step=(ft.verify == "step"),
        corrects=ft.corrects, rel_tau=ft.rel_tau, n_bands=n_bands,
        masked=masked)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s, *_: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s, *_: (s, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j)),
            pl.BlockSpec((1, 1, REPORT_WIDTH), lambda i, j, s, *_: (i, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((n_bands, bn), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1], REPORT_WIDTH),
                                 jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(inj_idx, inj_mag, dims, a, b)


def encode_injection(spec: Optional[InjectionSpec]):
    """InjectionSpec → (int32[4], f32[1]) kernel operands."""
    if spec is None:
        return (jnp.zeros((4,), jnp.int32), jnp.zeros((1,), jnp.float32))
    idx = jnp.array([1, spec.row, spec.col, spec.k_step], jnp.int32)
    mag = jnp.array([spec.magnitude], jnp.float32)
    return idx, mag
