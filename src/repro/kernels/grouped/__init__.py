"""Batched & grouped FT-GEMM subsystem (PR 3).

The paper's threadblock-level ABFT wins biggest on irregular shapes; the two
most irregular hot paths in the model zoo are *batched* (attention QK/PV
cores, per-expert matmuls on uniform layouts) and *grouped* (MoE expert FFNs
over ragged, routing-dependent token counts). This package puts both on the
PR-2 template registry with ONE emitted body (`templates.emit` renders a
`BatchedKernelSpec`):

    layout.py   -- CSR-style group-sorted buffer: aligned offsets, tile→group
                   map, row bounds, scatter/gather (zero capacity padding —
                   worst case G·(bm-1) alignment rows)
    dispatch.py -- batched_gemm_call (leading batch grid axis, masked ragged
                   (m,n,k)), grouped_buffer_call / grouped_matmul_rows
                   (per-group B via scalar-prefetched index maps, per-group
                   checksums + detection/correction), plan_grouped;
                   tgmm_buffer_call / tgmm_matmul_rows / plan_tgmm (PR 4 —
                   the output-stationary grouped transpose GEMM of the MoE
                   backward dw, per-group checksums flushed at group
                   boundaries)

Front doors: `kernels.ops.grouped_gemm_call` (rank-dispatching),
`core.ft_batched_dot` / `core.ft_grouped_matmul` (policy-level, all three
backends — the grouped backward's dw runs the tgmm kernel on pallas).
"""
from . import dispatch, layout
from .dispatch import (batched_gemm_call, encode_batched_injection,
                       grouped_buffer_call, grouped_matmul_rows,
                       plan_grouped, plan_tgmm, tgmm_buffer_call,
                       tgmm_matmul_rows)
from .layout import (GroupLayout, buffer_rows, gather_rows, make_layout,
                     scatter_rows)

__all__ = [
    "dispatch", "layout", "batched_gemm_call", "encode_batched_injection",
    "grouped_buffer_call", "grouped_matmul_rows", "plan_grouped",
    "plan_tgmm", "tgmm_buffer_call", "tgmm_matmul_rows",
    "GroupLayout", "buffer_rows", "gather_rows", "make_layout",
    "scatter_rows",
]
