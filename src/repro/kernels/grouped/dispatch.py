"""Dispatch layer of the batched & grouped FT-GEMM subsystem.

Parallels `kernels.ops.gemm_call` for the batched variant space:

  * `batched_gemm_call`  — uniform batched (B, M, K) × (B, K, N) (or shared
    (K, N)): one Pallas launch with a leading batch grid axis; ragged
    (m, n, k) shared by all slices takes the masked path on a fitted tile
    grid (exactly the 2-D dispatch policy, batched).
  * `grouped_buffer_call` — ragged grouped GEMM over a group-sorted token
    buffer (see `grouped.layout`): per-group B, per-group checksums, no
    capacity padding — executed rows exceed the true rows by at most
    G·(bm-1) alignment rows.
  * `grouped_matmul_rows` — row-space convenience (layout + scatter + call
    + gather in one step) for callers that run a single grouped GEMM.
  * `tgmm_buffer_call` / `tgmm_matmul_rows` — the grouped *transpose* GEMM
    (PR 4): dw[g] = X_gᵀ G_g over the same group-sorted buffer layout, run
    as one output-stationary kernel over (G, K, N) with per-group running
    checksums (`templates.emit.render_tgmm`). This is the MoE backward dw
    — the last train-path GEMM that used to run as a segment-summed jnp
    einsum.

`kernels.ops.grouped_gemm_call` is the public front door that routes to
these based on the operand ranks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import FTConfig, InjectionSpec, FT_OFF
from repro.tools.trace import traced
from .. import autotune, search
from ..autotune import MXU, KernelParams
# ops does not import this package at module level, so these are cycle-free;
# one interpret-fallback policy and one padding helper repo-wide.
from ..ops import _pad2 as _pad_last2
from ..ops import _should_interpret
from ..templates import registry
from ..templates.spec import BatchedKernelSpec
from . import layout as layout_mod


def _resolve_ft(spec: BatchedKernelSpec, ft: Optional[FTConfig]) -> FTConfig:
    if ft is None:
        ft = FTConfig(level=spec.ft_level) if spec.ft else FT_OFF
    if spec.ft != ft.enabled or (spec.ft and ft.level != spec.ft_level):
        raise ValueError(f"FTConfig(level={ft.level!r}, action={ft.action!r})"
                         f" disagrees with spec.ft_level={spec.ft_level!r}")
    return ft


def encode_batched_injection(spec: Optional[InjectionSpec], batch: int = 0):
    """InjectionSpec → (int32[5], f32[1]) — the batched kernels' 5-wide
    [enable, batch, row, col, k_step] layout. ``batch < 0`` broadcasts the
    SEU into every batch slice (the jnp injector's semantics)."""
    if spec is None:
        return (jnp.zeros((5,), jnp.int32), jnp.zeros((1,), jnp.float32))
    idx = jnp.array([1, batch, spec.row, spec.col, spec.k_step], jnp.int32)
    return idx, jnp.array([spec.magnitude], jnp.float32)


# ---------------------------------------------------------------------------
# uniform batched
# ---------------------------------------------------------------------------

@traced("kernel/batched_gemm")
def batched_gemm_call(spec: BatchedKernelSpec, a: jax.Array, b: jax.Array, *,
                      ft: Optional[FTConfig] = None,
                      inject: Optional[InjectionSpec] = None,
                      inj_batch: int = 0,
                      params: Optional[KernelParams] = None,
                      interpret: Optional[bool] = None,
                      out_dtype=None,
                      key: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Uniform batched GEMM: a (B, M, K) × b (B, K, N) or (K, N) → (B, M, N)
    in ONE Pallas launch (leading batch grid axis — no per-slice loop).
    Returns (C, report|None); the FT report is (B, gm, gn, W). ``key``
    drives the in-kernel stochastic SEU hook when ``ft.inject_rate > 0``."""
    batch, m, k = a.shape
    shared = b.ndim == 2
    n = b.shape[-1]
    assert b.shape[-2] == k and (shared or b.shape[0] == batch), \
        (a.shape, b.shape)
    in_bytes = a.dtype.itemsize
    ft_level = spec.ft_level
    ft = _resolve_ft(spec, ft)

    p = params or autotune.best_params(
        m, n, k, in_bytes, ft_level=ft_level,
        spec=dataclasses.replace(spec, shared_b=shared, masked=False),
        batch=batch)
    divisible = (m % p.bm == 0 and n % p.bn == 0 and k % p.bk == 0)
    if divisible:
        rp, me, ne, ke = p, m, n, k
    else:
        sub = search.sublane(in_bytes)
        align_m = MXU if ft_level == "tile" else sub
        rp = KernelParams(bm=search.fit_tile(m, p.bm, align_m),
                          bn=search.fit_tile(n, p.bn, MXU),
                          bk=search.fit_tile(k, p.bk, MXU),
                          shape_class=p.shape_class)
        me, ne, ke = search.executed_dims(m, n, k, rp)
    rspec = dataclasses.replace(spec, shared_b=shared,
                                masked=not divisible)

    a = _pad_last2(a, me, ke)
    b = _pad_last2(b, ke, ne)
    dims = jnp.array([m, n, k], jnp.int32) if (rspec.masked or rspec.ft) \
        else None
    inj_idx = inj_mag = rng = None
    if rspec.ft:
        from .. import flashft
        inj_idx, inj_mag = encode_batched_injection(inject, inj_batch)
        rng = flashft.encode_rng(key, ft)
    out, rep = registry.batched_kernel_call(
        a, b, inj_idx=inj_idx, inj_mag=inj_mag, rng=rng, dims=dims,
        spec=rspec, params=rp, ft=ft,
        interpret=_should_interpret(interpret), out_dtype=out_dtype)
    if not divisible:
        out = out[:, :m, :n]
    return out, rep


# ---------------------------------------------------------------------------
# ragged grouped
# ---------------------------------------------------------------------------

def plan_grouped(t_rows: int, n: int, k: int, dtype, *, n_groups: int,
                 ft_level: str = "off",
                 spec: Optional[BatchedKernelSpec] = None,
                 params: Optional[KernelParams] = None) -> KernelParams:
    """Tile plan for a grouped launch. bm (the group-alignment granularity)
    is fitted to the *average* group size so tiny experts don't drag whole
    class tiles of padding along, AND capped so the worst-case per-group
    alignment padding G·(bm-1) stays within 25% of the true rows — the
    moe_dispatch benchmark's ≤1.25× ragged-floor criterion holds by
    construction for any routing skew (down to the hardware sublane floor;
    "tile"-level FT needs MXU-aligned bm and trades this bound away)."""
    in_bytes = jnp.dtype(dtype).itemsize
    p = params or autotune.best_params(t_rows, n, k, in_bytes,
                                       ft_level=ft_level, spec=spec,
                                       groups=n_groups)
    align_m = MXU if ft_level == "tile" else search.sublane(in_bytes)
    g = max(n_groups, 1)
    avg = max(1, t_rows // g)
    cap = ((t_rows // (4 * g) + 1) // align_m) * align_m
    bm_max = max(align_m, min(p.bm, cap))
    return KernelParams(bm=search.fit_tile(min(avg, bm_max), bm_max,
                                           align_m),
                        bn=search.fit_tile(n, p.bn, MXU),
                        bk=search.fit_tile(k, p.bk, MXU),
                        shape_class=p.shape_class)


@traced("kernel/grouped_buffer")
def grouped_buffer_call(spec: BatchedKernelSpec, buf: jax.Array,
                        w: jax.Array,
                        lay: Optional[layout_mod.GroupLayout] = None, *,
                        gid: Optional[jax.Array] = None,
                        row_end: Optional[jax.Array] = None,
                        params: KernelParams,
                        ft: Optional[FTConfig] = None,
                        inject: Optional[InjectionSpec] = None,
                        interpret: Optional[bool] = None,
                        out_dtype=None,
                        key: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Grouped GEMM over a prepared buffer: buf (t_buf, K) group-sorted
    (see `layout.scatter_rows`), w (G, K, N). Group metadata comes from a
    `GroupLayout` or the raw (``gid``, ``row_end``) arrays. Returns
    (y_buf (t_buf, N), report|None); the report is (t_buf/bm, gn, W) — one
    row per row tile, i.e. per-group blocks since tiles never span
    groups."""
    if lay is not None:
        gid, row_end = lay.gid, lay.row_end
        assert params.bm == lay.bm and buf.shape[0] == lay.t_buf, \
            (params, lay.bm, buf.shape, lay.t_buf)
    assert gid is not None and row_end is not None
    t_buf, k = buf.shape
    ng, k2, n = w.shape
    assert k == k2 and ng == row_end.shape[0], (buf.shape, w.shape,
                                                row_end.shape)
    assert t_buf == gid.shape[0] * params.bm, (t_buf, gid.shape, params.bm)
    ft = _resolve_ft(spec, ft)
    rspec = dataclasses.replace(spec, grouped=True, shared_b=False)

    # Fit n/k to the ragged problem (zero padding is checksum-neutral).
    bk = search.fit_tile(k, params.bk, MXU)
    bn = search.fit_tile(n, params.bn, MXU)
    rp = KernelParams(bm=params.bm, bn=bn, bk=bk,
                      shape_class=params.shape_class)
    ke = ((k + bk - 1) // bk) * bk
    ne = ((n + bn - 1) // bn) * bn
    buf_p = _pad_last2(buf, t_buf, ke)
    w_p = _pad_last2(w, ke, ne)
    dims = jnp.array([t_buf, n, k], jnp.int32)
    inj_idx = inj_mag = rng = None
    if rspec.ft:
        from .. import flashft, ftgemm
        inj_idx, inj_mag = ftgemm.encode_injection(inject)
        rng = flashft.encode_rng(key, ft)
    out, rep = registry.batched_kernel_call(
        buf_p, w_p, inj_idx=inj_idx, inj_mag=inj_mag, rng=rng, dims=dims,
        gid=gid, row_end=row_end, spec=rspec, params=rp, ft=ft,
        interpret=_should_interpret(interpret), out_dtype=out_dtype)
    if ne != n:
        out = out[:, :n]
    return out, rep


def grouped_matmul_rows(spec: BatchedKernelSpec, x: jax.Array, w: jax.Array,
                        group_ids: jax.Array, *,
                        ft: Optional[FTConfig] = None,
                        inject: Optional[InjectionSpec] = None,
                        params: Optional[KernelParams] = None,
                        interpret: Optional[bool] = None,
                        out_dtype=None,
                        key: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Row-space grouped GEMM: y[r] = x[r] @ w[group_ids[r]], any group
    sizes (including empty and ragged-last), zero capacity padding."""
    t, k = x.shape
    ng, _, n = w.shape
    ft_level = spec.ft_level
    p = params or plan_grouped(t, n, k, x.dtype, n_groups=ng,
                               ft_level=ft_level, spec=spec)
    lay = layout_mod.make_layout(group_ids, ng, p.bm)
    buf = layout_mod.scatter_rows(x, lay)
    y_buf, rep = grouped_buffer_call(spec, buf, w, lay, params=p, ft=ft,
                                     inject=inject, interpret=interpret,
                                     out_dtype=out_dtype, key=key)
    return layout_mod.gather_rows(y_buf, lay), rep


# ---------------------------------------------------------------------------
# grouped transpose GEMM ("tgmm" — the MoE backward dw)
# ---------------------------------------------------------------------------

def group_counts_from_metadata(row_end: jax.Array, bm: int) -> jax.Array:
    """Recover per-group live-row counts from (row_end, bm) alone, using the
    layout invariant that group g's region starts at the bm-aligned end of
    group g-1's: counts[g] = row_end[g] - roundup(row_end[g-1], bm)."""
    prev = jnp.concatenate([jnp.zeros((1,), row_end.dtype), row_end[:-1]])
    base = ((prev + bm - 1) // bm) * bm
    return row_end - base


@traced("kernel/tgmm_buffer")
def tgmm_buffer_call(spec: BatchedKernelSpec, buf: jax.Array,
                     gbuf: jax.Array,
                     lay: Optional[layout_mod.GroupLayout] = None, *,
                     gid: Optional[jax.Array] = None,
                     row_end: Optional[jax.Array] = None,
                     n_groups: Optional[int] = None,
                     params: KernelParams,
                     ft: Optional[FTConfig] = None,
                     inject: Optional[InjectionSpec] = None,
                     interpret: Optional[bool] = None,
                     out_dtype=None,
                     key: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Grouped transpose GEMM over prepared buffers:
    ``dw[g] = buf_gᵀ gbuf_g`` with buf (t_buf, K) and gbuf (t_buf, N) both
    group-sorted under ONE layout. Returns (dw (G, K, N), report|None); the
    report is (G, gk, gn, W) — per-group blocks, since the accumulator
    flushes at group boundaries.

    The kernel grid only visits blocks of non-empty groups, so dw (and
    report) rows of empty groups come back as unspecified memory — this
    front door zeroes them (their true gradient contribution is zero: no
    rows were routed there). Dead buffer rows between `row_end[g]` and the
    next bm boundary are masked in-kernel, so garbage in either buffer's
    alignment padding cannot reach dw or the checksums."""
    if lay is not None:
        gid, row_end = lay.gid, lay.row_end
        n_groups = lay.n_groups
        assert params.bm == lay.bm and buf.shape[0] == lay.t_buf, \
            (params, lay.bm, buf.shape, lay.t_buf)
    assert gid is not None and row_end is not None
    ng = n_groups if n_groups is not None else row_end.shape[0]
    t_buf, k = buf.shape
    t2, n = gbuf.shape
    assert t_buf == t2 and ng == row_end.shape[0], \
        (buf.shape, gbuf.shape, row_end.shape)
    assert t_buf == gid.shape[0] * params.bm, (t_buf, gid.shape, params.bm)
    ft = _resolve_ft(spec, ft)
    rspec = dataclasses.replace(spec, tgmm=True, grouped=False,
                                shared_b=False)

    # Fit the output dims to the ragged problem (zero padding of the K/N
    # trailing edges is checksum-neutral — masked in-kernel besides).
    bk = search.fit_tile(k, params.bk, MXU)
    bn = search.fit_tile(n, params.bn, MXU)
    rp = KernelParams(bm=params.bm, bn=bn, bk=bk,
                      shape_class=params.shape_class)
    ke = ((k + bk - 1) // bk) * bk
    ne = ((n + bn - 1) // bn) * bn
    buf_p = _pad_last2(buf, t_buf, ke)
    gbuf_p = _pad_last2(gbuf, t_buf, ne)
    dims = jnp.array([t_buf, n, k], jnp.int32)
    inj_idx = inj_mag = rng = None
    if rspec.ft:
        from .. import flashft, ftgemm
        inj_idx, inj_mag = ftgemm.encode_injection(inject)
        rng = flashft.encode_rng(key, ft)
    dw, rep = registry.tgmm_kernel_call(
        buf_p, gbuf_p, inj_idx=inj_idx, inj_mag=inj_mag, rng=rng, dims=dims,
        gid=gid, row_end=row_end, n_groups=ng, spec=rspec, params=rp,
        ft=ft, interpret=_should_interpret(interpret), out_dtype=out_dtype)
    dw = dw[:, :k, :n]
    # Zero the never-visited blocks of empty groups (see docstring).
    live = group_counts_from_metadata(row_end, params.bm) > 0
    dw = jnp.where(live[:, None, None], dw, 0)
    if rep is not None:
        rep = jnp.where(live[:, None, None, None], rep, 0)
    return dw, rep


def plan_tgmm(t_rows: int, n: int, k: int, dtype, *, n_groups: int,
              ft_level: str = "off",
              spec: Optional[BatchedKernelSpec] = None,
              params: Optional[KernelParams] = None,
              bm: Optional[int] = None) -> KernelParams:
    """Tile plan for a tgmm launch — same bm policy as `plan_grouped` (the
    row tile is the group-alignment granularity on the *reduction* dim, so
    the identical G·(bm-1) padding bound applies), but scored/budgeted under
    the tgmm variant's own VMEM and roofline terms (``/v_…tgmm`` cache
    key).

    ``bm`` pins the row tile instead (the backward case: the forward
    layout's bm is a fact of the existing buffer, not a free parameter) —
    bn/bk are then re-clamped under the tgmm working-set model WITH that
    bm, so a pinned row tile deeper than the searched one can never launch
    an over-budget kernel."""
    spec = spec or BatchedKernelSpec(ft_level=ft_level, tgmm=True)
    in_bytes = jnp.dtype(dtype).itemsize
    p = params or autotune.best_params(t_rows, n, k, in_bytes,
                                       ft_level=ft_level, spec=spec,
                                       groups=n_groups)
    align_m = search.sublane(in_bytes)
    if bm is None:
        g = max(n_groups, 1)
        avg = max(1, t_rows // g)
        cap = ((t_rows // (4 * g) + 1) // align_m) * align_m
        bm_max = max(align_m, min(p.bm, cap))
        # "tile"-level FT bands slice dw's K rows (bk), not bm — no MXU
        # floor on bm here, but bk stays MXU-aligned (fit_tile guarantees).
        bm = search.fit_tile(min(avg, bm_max), bm_max, align_m)
    q = KernelParams(bm=bm, bn=search.fit_tile(n, p.bn, MXU),
                     bk=search.fit_tile(k, p.bk, MXU),
                     shape_class=p.shape_class)

    def _ws(qq: KernelParams) -> int:
        return search.vmem_bytes(qq, in_bytes, ft_level, spec, m=t_rows,
                                 groups=n_groups)

    def _halve(edge: int) -> int:        # stay MXU-aligned while shrinking
        return max(MXU, (edge // 2) // MXU * MXU)

    while _ws(q) > autotune.VMEM_BUDGET and q.bk > MXU:
        q = dataclasses.replace(q, bk=_halve(q.bk))
    while _ws(q) > autotune.VMEM_BUDGET and q.bn > MXU:
        q = dataclasses.replace(q, bn=_halve(q.bn))
    return q


def tgmm_matmul_rows(spec: BatchedKernelSpec, x: jax.Array, g: jax.Array,
                     group_ids: jax.Array, *, n_groups: int,
                     ft: Optional[FTConfig] = None,
                     inject: Optional[InjectionSpec] = None,
                     params: Optional[KernelParams] = None,
                     interpret: Optional[bool] = None,
                     out_dtype=None,
                     key: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Row-space grouped transpose GEMM:
    ``dw[e] = Σ_{r: group_ids[r]=e} x[r] ⊗ g[r]`` — any group sizes
    (including empty and ragged-last). Lays out ONE group-sorted buffer
    pair and runs the output-stationary kernel."""
    t, k = x.shape
    t2, n = g.shape
    assert t == t2 and group_ids.shape == (t,), (x.shape, g.shape,
                                                 group_ids.shape)
    p = params or plan_tgmm(t, n, k, x.dtype, n_groups=n_groups,
                            ft_level=spec.ft_level, spec=dataclasses.replace(
                                spec, tgmm=True, grouped=False))
    lay = layout_mod.make_layout(group_ids, n_groups, p.bm)
    return tgmm_buffer_call(spec, layout_mod.scatter_rows(x, lay),
                            layout_mod.scatter_rows(g, lay), lay, params=p,
                            ft=ft, inject=inject, interpret=interpret,
                            out_dtype=out_dtype, key=key)
