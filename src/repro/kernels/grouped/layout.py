"""CSR-style group layout for the ragged grouped GEMM.

The grouped kernel consumes a *row-sorted token buffer*: all rows of group 0
first, then group 1, … — with each group's region starting on a row-tile
(``bm``) boundary so that every tile of the launch grid is wholly owned by
one group. That alignment is what keeps the per-block ABFT checksums
per-group (an SEU in one expert's rows can never contaminate a neighbor) and
what lets the kernel's B index map be a plain scalar-prefetch lookup.

Everything here is static-shaped jnp: group *sizes* are dynamic values
(routing decides them at runtime) but the buffer capacity is the worst case
``T + G·(bm-1)`` rounded to ``bm`` — the only "padding" the grouped path
ever pays, bounded by ``G·(bm-1)`` rows regardless of how skewed the
routing is (contrast: capacity-based dispatch pads every expert to the same
worst-case capacity AND drops overflow tokens).

`make_layout` builds the metadata, `scatter_rows`/`gather_rows` move data
between row space (caller order) and buffer space (group-sorted).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """Metadata of one group-sorted buffer.

    Static (Python ints, part of the treedef):
      n_groups   — G
      bm         — row-tile edge every group region is aligned to
      t_buf      — buffer rows (bm multiple, worst-case capacity)
      n_rows     — T, the true row count the layout was built for

    Traced arrays:
      counts     — int32 (G,)  rows routed to each group
      base       — int32 (G,)  aligned first buffer row of each group
      row_end    — int32 (G,)  first *dead* buffer row of each group
                   (= base + counts; the kernel's ragged group-edge bound)
      gid        — int32 (t_buf/bm,) owning group of each row tile
                   (tiles past the last live row are clamped to G-1 and
                   fully masked by row_end)
      positions  — int32 (T,)  buffer row holding caller row r
    """
    n_groups: int
    bm: int
    t_buf: int
    n_rows: int
    counts: jax.Array
    base: jax.Array
    row_end: jax.Array
    gid: jax.Array
    positions: jax.Array

    @property
    def num_tiles(self) -> int:
        return self.t_buf // self.bm

    def tree_flatten(self):
        arrays = (self.counts, self.base, self.row_end, self.gid,
                  self.positions)
        static = (self.n_groups, self.bm, self.t_buf, self.n_rows)
        return arrays, static

    @classmethod
    def tree_unflatten(cls, static, arrays):
        return cls(*static, *arrays)


def buffer_rows(n_rows: int, n_groups: int, bm: int) -> int:
    """Static worst-case buffer capacity: every group wastes at most bm-1
    alignment rows, and the per-group aligned sizes are bm multiples, so
    their sum never exceeds this bound."""
    return bm * max(1, (n_rows + n_groups * (bm - 1)) // bm)


def make_layout(group_ids: jax.Array, n_groups: int, bm: int) -> GroupLayout:
    """group_ids: int32 (T,) — owning group of each caller row."""
    t = group_ids.shape[0]
    group_ids = group_ids.astype(jnp.int32)
    t_buf = buffer_rows(t, n_groups, bm)
    counts = jnp.zeros((n_groups,), jnp.int32).at[group_ids].add(1)
    aligned = ((counts + bm - 1) // bm) * bm
    ends = jnp.cumsum(aligned)                       # aligned region ends
    base = ends - aligned                            # aligned region starts
    row_end = base + counts

    # Buffer position of each caller row: its group's base plus its rank in
    # the (stable) group-sorted order.
    order = jnp.argsort(group_ids, stable=True)      # caller rows, sorted
    sorted_gids = group_ids[order]
    group_start_sorted = jnp.cumsum(counts) - counts
    pos_sorted = (base[sorted_gids] + jnp.arange(t, dtype=jnp.int32)
                  - group_start_sorted[sorted_gids])
    positions = jnp.zeros((t,), jnp.int32).at[order].set(pos_sorted)

    # Owning group per row tile: which aligned region the tile start falls
    # in. Tiles past the last live region clamp to the final group — their
    # rows are ≥ row_end[G-1], so the kernel masks them out entirely.
    tile_start = jnp.arange(t_buf // bm, dtype=jnp.int32) * bm
    gid = jnp.clip(jnp.searchsorted(ends, tile_start, side="right"),
                   0, n_groups - 1).astype(jnp.int32)
    return GroupLayout(n_groups=n_groups, bm=bm, t_buf=t_buf, n_rows=t,
                       counts=counts, base=base, row_end=row_end, gid=gid,
                       positions=positions)


def scatter_rows(x: jax.Array, layout: GroupLayout) -> jax.Array:
    """(T, K) caller rows → (t_buf, K) group-sorted buffer (dead rows 0)."""
    assert x.shape[0] == layout.n_rows, (x.shape, layout.n_rows)
    buf = jnp.zeros((layout.t_buf,) + x.shape[1:], x.dtype)
    return buf.at[layout.positions].set(x)


def gather_rows(buf: jax.Array, layout: GroupLayout) -> jax.Array:
    """(t_buf, N) buffer → (T, N) caller rows (drops dead rows)."""
    return buf[layout.positions]
