"""Shape-class kernel-parameter selection — the TPU analogue of the paper's
template-based code generation (§3.2, Table 1).

The paper's code generator takes 7 tile parameters (threadblock / warp /
thread tile sizes) and emits a CUDA kernel per input-shape class
(small/medium/large/tall-and-skinny/huge). On TPU the corresponding degrees
of freedom are the Pallas BlockSpec tile sizes (bm, bn, bk): they determine
the VMEM working set (the shared-memory analogue), the MXU utilization
(dims must be multiples of 128 to fill the 128×128 systolic array), and the
HBM→VMEM pipeline depth. "Code generation" is JAX tracing of a parameterized
kernel — `build_params(M, N, K)` is the generator's parameter-selection
stage, and `kernels.gemm/ftgemm` are the template.

VMEM budget model (v5e: 16 MiB/core usable — see KernelParams.vmem_bytes):
    2 × (bm·bk + bk·bn) · bytes(in)   — double-buffered operand tiles
  +     bm·bn · 4                      — f32 accumulator
  + n_bands·bn·4 + bm·4               — running checksums (FT modes;
                                        n_bands = bm/128 for "tile", else 1)
The table below keeps every class ≤ 8 MiB so Mosaic has slack for
spills/semaphores, mirroring the paper's "semi-empirical" selection.

Two selection stages live here:
  * `build_params`  — the static Table-1 lookup (search-free baseline).
  * `best_params`   — the autotuned path: persistent-cache lookup backed by
    the candidate search in `kernels.search` (see that module and
    `kernels.tune_cache`). This is what `ops.matmul` / `ops.ft_matmul*`
    and hence `core.ft_gemm`'s Pallas backend route through.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

MXU = 128          # systolic array edge — all tiles aligned to this
VMEM_BUDGET = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class KernelParams:
    bm: int
    bn: int
    bk: int
    shape_class: str = "custom"

    def vmem_bytes(self, in_bytes: int = 4, ft_level: str = "block") -> int:
        """Working-set model — the single source of truth used by the
        static table, the candidate search, and budget clamping. FT scratch
        depends on the level: one running (col, row) checksum pair for
        "block"/"inner", one column-checksum row per 128-row MXU band for
        "tile". Defaults to "block" (the flagship config) so budget checks
        without an explicit level stay conservative for non-tile modes."""
        operands = 2 * (self.bm * self.bk + self.bk * self.bn) * in_bytes
        acc = self.bm * self.bn * 4
        if ft_level == "off":
            return operands + acc
        n_bands = self.bm // MXU if ft_level == "tile" else 1
        colck = max(n_bands, 1) * self.bn * 4
        rowck = self.bm * 4
        return operands + acc + colck + rowck


#: Table-1 analogue. Keys are shape classes; values are (bm, bn, bk).
#: All multiples of the 128-wide MXU edge; chosen so small problems launch
#: enough grid blocks to fill all cores while huge problems maximize reuse.
TABLE = {
    "small":       (128, 128, 256),   # M, N ≤ 256 — many small blocks
    "medium":      (256, 256, 256),   # ≤ 512
    "large":       (256, 512, 256),   # ≤ 2048
    "tall_skinny": (512, 128, 512),   # M ≫ N — deep k-pipeline, narrow n
    "wide_flat":   (128, 512, 512),   # N ≫ M
    "huge":        (512, 512, 256),   # ≥ 2048 square — max VMEM reuse
}


def classify(m: int, n: int, k: int) -> str:
    if m >= 8 * n:
        return "tall_skinny"
    if n >= 8 * m:
        return "wide_flat"
    s = max(m, n)
    if s <= 256:
        return "small"
    if s <= 512:
        return "medium"
    if s <= 2048:
        return "large"
    return "huge"


def clamp_params(p: KernelParams, m: int, n: int, k: int,
                 in_bytes: int = 4, ft_level: str = "block",
                 spec=None) -> KernelParams:
    """Clamp tile params to the (MXU-padded) problem and the VMEM budget —
    shared by the static table and the search/cache paths, so a cached
    class winner is always legal for the concrete shape at hand. Uses the
    same working-set model (`KernelSpec.vmem_bytes`, wrapping
    `KernelParams.vmem_bytes` plus the variant's aux/extra-output buffers —
    or the tgmm override's transposed geometry) the search enumerates
    under."""

    def _ws(q: KernelParams) -> int:
        if spec is not None:
            return spec.vmem_bytes(q, in_bytes, ft_level)
        return q.vmem_bytes(in_bytes, ft_level)

    p = dataclasses.replace(p,
                            bm=min(p.bm, _round_up(m, MXU)),
                            bn=min(p.bn, _round_up(n, MXU)),
                            bk=min(p.bk, _round_up(k, MXU)))
    # Shrink bk first (pipeline depth) if over budget — cheapest dimension.
    while _ws(p) > VMEM_BUDGET and p.bk > MXU:
        p = dataclasses.replace(p, bk=p.bk // 2)
    while (_ws(p) > VMEM_BUDGET
           and max(p.bm, p.bn) > MXU):
        if p.bm >= p.bn:
            p = dataclasses.replace(p, bm=p.bm // 2)
        else:
            p = dataclasses.replace(p, bn=p.bn // 2)
    return p


def build_params(m: int, n: int, k: int, in_bytes: int = 4) -> KernelParams:
    """The static-table selection stage: shape → TABLE params, clamped to
    the problem size and the VMEM budget. Kept as the search-free baseline
    (and the comparison point the codegen benchmark reports against);
    runtime dispatch goes through `best_params` below."""
    cls = classify(m, n, k)
    bm, bn, bk = TABLE[cls]
    return clamp_params(KernelParams(bm=bm, bn=bn, bk=bk, shape_class=cls),
                        m, n, k, in_bytes)


def device_kind() -> str:
    """Normalized accelerator kind for tuning-cache keys ("cpu",
    "tpu_v5_lite", …)."""
    try:
        import jax
        return jax.devices()[0].device_kind.strip().lower().replace(" ", "_")
    except Exception:
        return "unknown"


def _pow2_bucket(x: int) -> int:
    """Round a batch/group count up to a power of two — the tuning-cache
    bucket. Counts inside one bucket share a roofline regime; exact counts
    would fragment the cache across every routing outcome."""
    b = 1
    while b < x:
        b *= 2
    return b


def best_params(m: int, n: int, k: int, in_bytes: int = 4, *,
                ft_level: str = "off", spec=None,
                measure=None, cache=None,
                use_cache: bool = True,
                batch: int = 1, groups: int = 0) -> KernelParams:
    """Autotuned parameter selection: consult the persistent tuning cache
    (keyed by device kind + shape class + element width + FT level + kernel
    variant); on a miss run the candidate search
    (`kernels.search.select_best` — measured on TPU hardware,
    roofline-modeled elsewhere), persist the winner, and return it clamped
    to this concrete problem.

    `spec` — optional `templates.KernelSpec`. Fused epilogues shift the
    VMEM budget (aux-operand buffers) and the roofline intensity (aux HBM
    reads + elementwise FLOPs), so the variant is part of the cache key
    (`spec.variant_key()`) and of the candidate space: two variants of one
    shape class can legitimately tune to different tiles. Flash-attention
    variants (`templates.FlashKernelSpec`, PR 5) reinterpret the problem as
    (m, n, k) = (stationary seq dim, streamed seq dim, lane-padded head
    dim): the winner's (bm, bn) become the (bq, bkv)-style sequence blocks
    and bk is advisory (the head dim never tiles — the spec's own VMEM and
    roofline models ignore it).

    ``batch``/``groups`` make the selection batched-aware: a uniform batch
    count multiplies every roofline term, a ragged group count adds
    per-group row-alignment padding and metadata VMEM, and either adds a
    power-of-two-bucketed ``/b_*`` / ``/g_*`` component to the cache key
    (2-D launches keep the bare key, so existing caches stay valid).

    Deterministic given a warm cache: the same key always yields the same
    stored tile, and clamping is pure. The key includes the per-dim search
    cap, so tuning order across shapes of one class cannot pin a winner
    searched under a smaller candidate space onto a larger problem.
    `use_cache=False` forces a fresh search (cache regeneration, tests)."""
    from . import search, tune_cache

    if spec is not None and spec.ft_level != ft_level:
        raise ValueError(f"spec.ft_level={spec.ft_level!r} disagrees with "
                         f"ft_level={ft_level!r}")
    batch_key = ""
    if groups > 0:
        batch_key = f"g_{_pow2_bucket(groups)}"
    elif batch > 1:
        batch_key = f"b_{_pow2_bucket(batch)}"
    if use_cache:
        # NOT `cache or default`: an empty TuneCache is falsy (__len__ == 0)
        # and must still be honored — cache-regeneration campaigns pass one.
        cache = tune_cache.default_cache() if cache is None else cache
        caps = (min(search.MAX_TILE, _round_up(m, MXU)),
                min(search.MAX_TILE, _round_up(n, MXU)),
                min(search.MAX_TILE, _round_up(k, MXU)))
        key = tune_cache.cache_key(device_kind(), classify(m, n, k),
                                   in_bytes, ft_level, caps,
                                   variant=spec.variant_key() if spec else "",
                                   batch=batch_key)
        hit = cache.get(key)
        if hit is not None:
            return clamp_params(hit, m, n, k, in_bytes, ft_level, spec)
    best = search.select_best(m, n, k, in_bytes=in_bytes, ft_level=ft_level,
                              spec=spec, measure=measure,
                              batch=batch, groups=groups)
    if use_cache:
        cache.put(key, best)
    return clamp_params(best, m, n, k, in_bytes, ft_level, spec)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def padded_shape(m: int, n: int, k: int, p: KernelParams) -> Tuple[int, int, int]:
    """Problem size padded to tile multiples (zero padding is ABFT-neutral:
    checksums of zero rows/cols are zero)."""
    return _round_up(m, p.bm), _round_up(n, p.bn), _round_up(k, p.bk)
