"""Shape-class kernel-parameter selection — the TPU analogue of the paper's
template-based code generation (§3.2, Table 1).

The paper's code generator takes 7 tile parameters (threadblock / warp /
thread tile sizes) and emits a CUDA kernel per input-shape class
(small/medium/large/tall-and-skinny/huge). On TPU the corresponding degrees
of freedom are the Pallas BlockSpec tile sizes (bm, bn, bk): they determine
the VMEM working set (the shared-memory analogue), the MXU utilization
(dims must be multiples of 128 to fill the 128×128 systolic array), and the
HBM→VMEM pipeline depth. "Code generation" is JAX tracing of a parameterized
kernel — `build_params(M, N, K)` is the generator's parameter-selection
stage, and `kernels.gemm/ftgemm` are the template.

VMEM budget model (v5e: 16 MiB/core usable):
    2 × (bm·bk + bk·bn) · bytes(in)   — double-buffered operand tiles
  +     bm·bn · 4                      — f32 accumulator
  +     (bm + bn) · 4 · 2              — running checksums (FT mode)
The table below keeps every class ≤ 8 MiB so Mosaic has slack for
spills/semaphores, mirroring the paper's "semi-empirical" selection.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

MXU = 128          # systolic array edge — all tiles aligned to this
VMEM_BUDGET = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class KernelParams:
    bm: int
    bn: int
    bk: int
    shape_class: str = "custom"

    def vmem_bytes(self, in_bytes: int = 4) -> int:
        operands = 2 * (self.bm * self.bk + self.bk * self.bn) * in_bytes
        acc = self.bm * self.bn * 4
        checksums = (self.bm + self.bn) * 4 * 2
        return operands + acc + checksums


#: Table-1 analogue. Keys are shape classes; values are (bm, bn, bk).
#: All multiples of the 128-wide MXU edge; chosen so small problems launch
#: enough grid blocks to fill all cores while huge problems maximize reuse.
TABLE = {
    "small":       (128, 128, 256),   # M, N ≤ 256 — many small blocks
    "medium":      (256, 256, 256),   # ≤ 512
    "large":       (256, 512, 256),   # ≤ 2048
    "tall_skinny": (512, 128, 512),   # M ≫ N — deep k-pipeline, narrow n
    "wide_flat":   (128, 512, 512),   # N ≫ M
    "huge":        (512, 512, 256),   # ≥ 2048 square — max VMEM reuse
}


def classify(m: int, n: int, k: int) -> str:
    if m >= 8 * n:
        return "tall_skinny"
    if n >= 8 * m:
        return "wide_flat"
    s = max(m, n)
    if s <= 256:
        return "small"
    if s <= 512:
        return "medium"
    if s <= 2048:
        return "large"
    return "huge"


def build_params(m: int, n: int, k: int, in_bytes: int = 4) -> KernelParams:
    """The generator's parameter-selection stage: shape → kernel params,
    clamped to the problem size and the VMEM budget."""
    cls = classify(m, n, k)
    bm, bn, bk = TABLE[cls]
    # Never exceed the (padded) problem.
    bm = min(bm, _round_up(m, MXU))
    bn = min(bn, _round_up(n, MXU))
    bk = min(bk, _round_up(k, MXU))
    p = KernelParams(bm=bm, bn=bn, bk=bk, shape_class=cls)
    # Shrink bk first (pipeline depth) if over budget — cheapest dimension.
    while p.vmem_bytes(in_bytes) > VMEM_BUDGET and p.bk > MXU:
        p = dataclasses.replace(p, bk=p.bk // 2)
    while p.vmem_bytes(in_bytes) > VMEM_BUDGET and max(p.bm, p.bn) > MXU:
        if p.bm >= p.bn:
            p = dataclasses.replace(p, bm=p.bm // 2)
        else:
            p = dataclasses.replace(p, bn=p.bn // 2)
    return p


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def padded_shape(m: int, n: int, k: int, p: KernelParams) -> Tuple[int, int, int]:
    """Problem size padded to tile multiples (zero padding is ABFT-neutral:
    checksums of zero rows/cols are zero)."""
    return _round_up(m, p.bm), _round_up(n, p.bn), _round_up(k, p.bk)
