"""Persistent autotuning cache — the memoized output of the parameter search.

The paper's code generator amortizes its search by emitting one kernel per
shape class and reusing it for every GEMM in that class; our analogue is a
small JSON file mapping

    {device_kind}/{shape_class}/b{in_bytes}/ft_{ft_level}  →  (bm, bn, bk)

so the (enumerate → score/measure) pass in `kernels.search` runs once per
class per device and every later `autotune.best_params()` call is a dict
lookup. The file lives at ``$REPRO_TUNE_CACHE`` when set, else
``~/.cache/repro_tune.json`` (``$XDG_CACHE_HOME`` respected); a repo-local
path can be passed explicitly (benchmarks, tests).

Robustness: a missing, corrupt, or foreign-schema file degrades to an empty
cache (never an exception on the hot path); writes are atomic
(tmp + ``os.replace``) so a crashed process cannot truncate the cache.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, Optional, Tuple

from .autotune import KernelParams

_SCHEMA = 1
_ENV_VAR = "REPRO_TUNE_CACHE"


def default_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro_tune.json")


def cache_key(device_kind: str, shape_class: str, in_bytes: int,
              ft_level: str, caps: Optional[Tuple[int, int, int]] = None,
              variant: str = "", batch: str = "") -> str:
    """`caps` is the search-space ceiling (per-dim max candidate tile) the
    triggering shape imposed. It must be part of the key: without it, a
    small shape that misses first would pin its capped winner onto every
    later same-class shape whose search space is wider (order-dependent
    tuning).

    `variant` is the kernel-template variant (`KernelSpec.variant_key()` —
    fused epilogue chain + non-default dtypes + batched/grouped body, and
    since PR 5 the flash-attention family: ``flashfwd[_stats]`` /
    ``flashbwd_dq`` / ``flashbwd_dkv``, whose (bm, bn) are the stationary/
    streamed sequence blocks). Fused epilogues change the VMEM budget and
    the roofline intensity, so two variants of one class may tune to
    different tiles; the plain variant keeps the empty string so PR-1
    cache files stay valid.

    `batch` is the batch/group-count component of a batched launch —
    ``"b_<n>"`` (uniform batch count) or ``"g_<n>"`` (ragged group count),
    power-of-two bucketed by `autotune.best_params`. The count shifts the
    roofline (batch multiplies every traffic/FLOP term; groups add
    per-group row padding that grows with bm), so it is part of the key;
    2-D launches keep the empty string and existing keys stay valid."""
    dev = device_kind.strip().lower().replace(" ", "_")
    cap = "" if caps is None else f"/c{caps[0]}x{caps[1]}x{caps[2]}"
    var = f"/v_{variant}" if variant else ""
    bat = f"/{batch}" if batch else ""
    return f"{dev}/{shape_class}{cap}/b{in_bytes}/ft_{ft_level}{var}{bat}"


class TuneCache:
    """Dict-like view over the JSON tuning file. Entries are
    ``key → [bm, bn, bk, shape_class]``."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_path()
        self._entries: Dict[str, Tuple[int, int, int, str]] = {}
        self._loaded = False

    # -- persistence -------------------------------------------------------

    def load(self) -> "TuneCache":
        self._entries = {}
        self._loaded = True
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                raw = json.load(f)
            if raw.get("schema") != _SCHEMA:
                return self
            for key, val in raw.get("entries", {}).items():
                bm, bn, bk, cls = val
                self._entries[str(key)] = (int(bm), int(bn), int(bk), str(cls))
        except (OSError, ValueError, TypeError, KeyError):
            self._entries = {}
        return self

    def save(self) -> None:
        payload = {"schema": _SCHEMA,
                   "entries": {k: list(v) for k, v in self._entries.items()}}
        tmp = None
        try:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            # An unwritable cache must never break the GEMM hot path — the
            # search result is still returned, just not persisted.
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    # -- access ------------------------------------------------------------

    def _ensure(self) -> None:
        if not self._loaded:
            self.load()

    def get(self, key: str) -> Optional[KernelParams]:
        self._ensure()
        hit = self._entries.get(key)
        if hit is None:
            return None
        bm, bn, bk, cls = hit
        return KernelParams(bm=bm, bn=bn, bk=bk, shape_class=cls)

    def put(self, key: str, params: KernelParams, persist: bool = True) -> None:
        self._ensure()
        self._entries[key] = (params.bm, params.bn, params.bk,
                              params.shape_class)
        if persist:
            self.save()

    def __len__(self) -> int:
        self._ensure()
        return len(self._entries)

    def keys(self):
        self._ensure()
        return list(self._entries)

    def as_dict(self) -> Dict[str, Tuple[int, int, int, str]]:
        self._ensure()
        return dict(self._entries)


_DEFAULT: Optional[TuneCache] = None


def default_cache() -> TuneCache:
    """Process-wide cache singleton (re-pointed by `reset`, e.g. after the
    ``REPRO_TUNE_CACHE`` env var changes in tests)."""
    global _DEFAULT
    if _DEFAULT is None or _DEFAULT.path != default_path():
        _DEFAULT = TuneCache()
    return _DEFAULT


def reset() -> None:
    global _DEFAULT
    _DEFAULT = None
