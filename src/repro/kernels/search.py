"""Search-based kernel-parameter autotuning (paper §3.2 / Fig. 10-11).

The paper's code generator enumerates template parameters per input-shape
class, benchmarks the instantiated kernels, and keeps the winner — beating
one fixed kernel by up to 230% on irregular shapes. This module is that
search for the Pallas GEMM template:

  * `enumerate_candidates` — every MXU-aligned `(bm, bn, bk)` whose working
    set (operand double-buffers + f32 accumulator + FT checksum scratch)
    fits the VMEM budget and that does not exceed the padded problem.
  * `predicted_time_s`    — the analytical fallback score: a per-kernel
    roofline (`tools.roofline.kernel_time_s`) over executed (padded) FLOPs
    and modeled HBM traffic with tile-reuse accounting, plus the FT
    checksum-update FLOPs for the requested level.
  * `measure_candidates`  — the empirical score: wall-clock timing of each
    instantiated kernel via `benchmarks.common.time_fn` — only meaningful
    on real hardware, so `select_best` uses it only when the backend is a
    TPU (or when forced), and otherwise falls back to the model.
  * `fit_tile`            — ragged-dispatch helper: the block edge (aligned
    to hardware granularity) that minimizes executed work on a dimension
    that does not divide the class tile, used by the masked kernels.

Everything here is deterministic given the same inputs: candidate order is
sorted, the model is closed-form, and ties break toward larger tiles
(more VMEM reuse), so a warm cache and a cold cache agree on hardware-free
hosts.
"""
from __future__ import annotations

import math
from typing import Callable, List, Optional, Sequence, Tuple

from repro.tools import roofline
from .autotune import MXU, VMEM_BUDGET, KernelParams, classify, _round_up

#: Largest tile edge the search considers (matches the static TABLE's max).
MAX_TILE = 512

#: Sublane granularity of the (8, 128) VREG by element width — the minimum
#: legal second-to-last block-dim multiple on TPU.
_SUBLANE = {8: 8, 4: 8, 2: 16, 1: 32}


def sublane(in_bytes: int) -> int:
    return _SUBLANE.get(in_bytes, 8)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def vmem_bytes(p: KernelParams, in_bytes: int = 4,
               ft_level: str = "off", spec=None, *,
               m: int = 0, groups: int = 0) -> int:
    """FT-level-and-variant-aware working set — delegates to the single
    model on `KernelSpec.vmem_bytes` (which itself wraps
    `KernelParams.vmem_bytes` plus the fused-epilogue aux / extra-output
    buffers, and which the tgmm variant overrides wholesale — its operand
    tiles and accumulator have a different geometry) so search legality and
    budget clamping can never disagree. A grouped launch (``groups > 0``)
    additionally holds its scalar-prefetched tile→group map and per-group
    row bounds on chip: 4·(num_tiles + groups) bytes, where the tile count
    includes the worst-case per-group alignment padding — the group count
    is part of the working set, not just the key."""
    base = (spec.vmem_bytes(p, in_bytes, ft_level) if spec
            else p.vmem_bytes(in_bytes, ft_level))
    extra = 0
    if groups > 0:
        num_tiles = (m + groups * (p.bm - 1)) // p.bm + 1
        extra += 4 * (num_tiles + groups)
    return base + extra


def _tile_range(dim: int, max_tile: int = MAX_TILE) -> List[int]:
    upper = min(max_tile, _round_up(dim, MXU))
    return list(range(MXU, upper + 1, MXU))


def enumerate_candidates(m: int, n: int, k: int, *, in_bytes: int = 4,
                         ft_level: str = "off", spec=None,
                         max_tile: int = MAX_TILE,
                         groups: int = 0) -> List[KernelParams]:
    """All legal tile configs for the problem: MXU-aligned in every dim,
    no larger than the MXU-padded problem, within the VMEM budget (fused
    epilogue aux buffers — and grouped-dispatch metadata when ``groups`` is
    given — included)."""
    cls = classify(m, n, k)
    out = []
    for bm in _tile_range(m, max_tile):
        for bn in _tile_range(n, max_tile):
            for bk in _tile_range(k, max_tile):
                p = KernelParams(bm=bm, bn=bn, bk=bk, shape_class=cls)
                if vmem_bytes(p, in_bytes, ft_level, spec, m=m,
                              groups=groups) <= VMEM_BUDGET:
                    out.append(p)
    return out


# ---------------------------------------------------------------------------
# Analytical scoring (roofline fallback)
# ---------------------------------------------------------------------------

def executed_dims(m: int, n: int, k: int,
                  p: KernelParams) -> Tuple[int, int, int]:
    """Problem size the kernel actually executes under tiling (grid of
    whole tiles covering the problem)."""
    return (_round_up(m, p.bm), _round_up(n, p.bn), _round_up(k, p.bk))


def ft_overhead_flops(p: KernelParams, ft_level: str, k_steps: int,
                      blocks: int) -> float:
    """Checksum-maintenance FLOPs across the whole launch. Per k-step per
    block: column checksum = reduce A tile (bm·bk) + GEMV (bk·bn MACs → 2×),
    row checksum = reduce B tile (bk·bn) + GEMV (bm·bk MACs → 2×); "inner"
    additionally reduces the materialized Δ both ways every step."""
    if ft_level == "off":
        return 0.0
    per_step = (p.bm * p.bk + 2 * p.bk * p.bn) + (p.bk * p.bn + 2 * p.bm * p.bk)
    if ft_level == "tile":
        per_step += p.bm * p.bn            # per-band verify reductions
    if ft_level == "inner":
        per_step += 2 * p.bm * p.bn        # Δ reduced along both axes
    return float(per_step) * k_steps * blocks


def predicted_time_s(m: int, n: int, k: int, p: KernelParams, *,
                     in_bytes: int = 4, ft_level: str = "off",
                     spec=None, batch: int = 1, groups: int = 0) -> float:
    """Roofline score of one candidate on the (padded) problem.

    HBM traffic model: each A tile is streamed once per output-column of
    tiles and each B tile once per output-row of tiles (no cross-block L2
    reuse on TPU — VMEM is the only cache we control), plus one output
    write. Compute: 2·M·N·K MACs on executed dims + checksum updates. A
    fused-epilogue `spec` adds its aux-operand reads and elementwise FLOPs
    (`KernelSpec.extra_hbm_bytes` / `epilogue_flops`) — the variant shifts
    the roofline intensity, which is why it is part of the tuning key.

    ``batch`` multiplies every term (a uniform batched launch runs the
    whole grid once per batch slice). ``groups`` models the grouped ragged
    dispatch instead: every group starts on a bm row-tile boundary, so up
    to bm-1 padding rows ride along per group — the executed M grows by
    the worst case ``groups·(bm-1)``, which is what steers the search away
    from deep row tiles when the expert count is high.

    The tgmm variant (``spec.tgmm``) is modeled on its own geometry: M is
    the *reduction* dimension (buffer rows, walked in bm tiles, carrying
    the same per-group alignment padding), the output is (G, K, N) written
    once per group in f32, the X buffer streams once per N-block column and
    the G buffer once per K-block row."""
    if groups > 0:
        m = m + groups * (p.bm - 1)     # per-group row-alignment padding
    me, ne, ke = executed_dims(m, n, k, p)
    gm, gn, gk = me // p.bm, ne // p.bn, ke // p.bk
    if spec is not None and spec.flash:
        # Flash-attention geometry: m = stationary seq dim (q for fwd/dq,
        # kv for dkv), n = streamed seq dim, k = head dim (never tiled —
        # spec.dh, not p.bk). Stationary operands are read once; the
        # streamed pair re-streams once per stationary block row; outputs
        # are written once per stationary row. The in-kernel GEMMs beyond
        # the S-GEMM and the softmax chain ride spec.epilogue_flops, the
        # side streams (g, stats, the extra dkv output) ride
        # spec.extra_hbm_bytes — same hooks as the fused-epilogue variants.
        dh = spec.dh
        flops = 2.0 * me * ne * dh + spec.epilogue_flops(me, ne)
        if ft_level != "off":
            # Checksum GEMVs: ~2·(bs + bt)·dh MACs per (stationary,
            # streamed) block pair per protected GEMM.
            n_gemms = spec._GEMMS[spec.direction]
            flops += n_gemms * 4.0 * (p.bm + p.bn) * dh * gm * gn
        stat_bytes = me * dh * in_bytes            # q (or k∥v via extra)
        stream_bytes = gm * 2.0 * ne * dh * in_bytes   # k+v (or q+g) re-read
        out_bytes = me * dh * in_bytes
        extra = spec.extra_hbm_bytes(me, ne, in_bytes)
        return batch * roofline.kernel_time_s(
            flops, stat_bytes + stream_bytes + out_bytes + extra)
    if spec is not None and spec.tgmm:
        tiles = gm
        flops = 2.0 * me * ne * ke
        if ft_level != "off":
            # Per row-tile per (ki, ni) block: two operand reductions
            # (bm·bk + bm·bn) and two checksum GEMVs (2·bm·bn + 2·bm·bk).
            per_step = 3.0 * (p.bm * p.bk + p.bm * p.bn)
            if ft_level == "tile":
                per_step += p.bk * p.bn
            if ft_level == "inner":
                per_step += 2.0 * p.bk * p.bn
            flops += per_step * tiles * gk * gn
        a_bytes = gn * me * ke * in_bytes       # X once per N-block column
        b_bytes = gk * me * ne * in_bytes       # G once per K-block row
        c_bytes = max(groups, 1) * ke * ne * 4  # dw written once, f32
        return roofline.kernel_time_s(flops, a_bytes + b_bytes + c_bytes)
    flops = 2.0 * me * ne * ke + ft_overhead_flops(p, ft_level, gk, gm * gn)
    a_bytes = gn * me * ke * in_bytes
    b_bytes = gm * ke * ne * in_bytes
    c_bytes = me * ne * in_bytes
    extra_bytes = 0.0
    if spec is not None:
        flops += spec.epilogue_flops(me, ne)
        extra_bytes = spec.extra_hbm_bytes(me, ne, in_bytes)
    return batch * roofline.kernel_time_s(
        flops, a_bytes + b_bytes + c_bytes + extra_bytes)


# ---------------------------------------------------------------------------
# Empirical scoring (hardware measurement)
# ---------------------------------------------------------------------------

def _time_fn_fallback(fn: Callable, *args, warmup: int = 2,
                      iters: int = 5) -> float:
    import time
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _timer() -> Callable:
    try:                                   # shared benchmark harness when
        from benchmarks.common import time_fn  # run from the repo root
        return time_fn
    except ImportError:
        return _time_fn_fallback


def can_measure() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def measure_candidates(m: int, n: int, k: int,
                       candidates: Sequence[KernelParams], *,
                       in_bytes: int = 4, ft_level: str = "off",
                       interpret: bool = False) -> List[float]:
    """Wall-clock each candidate (µs) on the padded problem. Compiles one
    kernel per candidate — intended for offline cache regeneration, not the
    request path."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.policy import FTConfig
    from . import ftgemm, gemm

    dtype = {4: jnp.float32, 2: jnp.bfloat16}.get(in_bytes, jnp.float32)
    time_fn = _timer()
    rng = np.random.default_rng(0)
    times = []
    for p in candidates:
        me, ne, ke = executed_dims(m, n, k, p)
        a = jnp.asarray(rng.normal(size=(me, ke)), dtype)
        b = jnp.asarray(rng.normal(size=(ke, ne)), dtype)
        if ft_level == "off":
            times.append(time_fn(
                lambda a, b, p=p: gemm.gemm(a, b, params=p,
                                            interpret=interpret), a, b))
        else:
            ft = FTConfig(level=ft_level)
            idx, mag = ftgemm.encode_injection(None)
            times.append(time_fn(
                lambda a, b, p=p, ft=ft: ftgemm.ft_gemm(
                    a, b, idx, mag, params=p, ft=ft, interpret=interpret),
                a, b))
    return times


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------

def select_best(m: int, n: int, k: int, *, in_bytes: int = 4,
                ft_level: str = "off", spec=None,
                measure: Optional[bool] = None,
                max_tile: int = MAX_TILE,
                candidates: Optional[Sequence[KernelParams]] = None,
                batch: int = 1, groups: int = 0) -> KernelParams:
    """The search: enumerate → score (hardware when available, roofline
    model otherwise) → deterministic winner (ties → larger tiles). The
    measured path times the base 2-D kernel of the requested FT level
    (epilogue chains and the batch axis perturb runtime well under timer
    noise on hardware; the modeled path accounts batch/group counts
    exactly)."""
    cands = list(candidates if candidates is not None else
                 enumerate_candidates(m, n, k, in_bytes=in_bytes,
                                      ft_level=ft_level, spec=spec,
                                      max_tile=max_tile, groups=groups))
    if not cands:
        raise ValueError(f"no legal tile candidates for {(m, n, k)}")
    if measure is None:
        measure = can_measure()
    if measure:
        scores = [t * 1e-6 for t in measure_candidates(
            m, n, k, cands, in_bytes=in_bytes, ft_level=ft_level)]
    else:
        scores = [predicted_time_s(m, n, k, p, in_bytes=in_bytes,
                                   ft_level=ft_level, spec=spec,
                                   batch=batch, groups=groups)
                  for p in cands]
    return min(zip(scores, cands),
               key=lambda sp: (sp[0], -sp[1].bm * sp[1].bn, -sp[1].bk))[1]


# ---------------------------------------------------------------------------
# Planner cost model (PR 10 — additive; the autotune scoring above is pinned
# by the tune-campaign cache diff and is deliberately untouched)
# ---------------------------------------------------------------------------

#: In-kernel GEMM count per population kind: how many k-loop GEMMs one
#: logical site launch runs (flash fwd = QK + PV; the 2-D/fused/batched/
#: grouped/tgmm kinds are one GEMM each).
_PLAN_GEMMS = {"flash": 2}

#: Reference k-tile for the step-verify count — matches MAX_TILE so the
#: model's verify cadence tracks what the autotuner would actually pick
#: for a large-k problem without consulting (or populating) the tune cache.
_PLAN_BK_REF = MAX_TILE


def ft_plan_base(kind: str, m: int, n: int, k: int, batch: int = 1,
                 in_bytes: int = 4) -> Tuple[float, float]:
    """(flops, hbm_bytes) of one *unprotected* launch of a site population.

    Deliberately tile-free: the planner prices sites against each other on
    pure problem geometry (a dims-only roofline), so planning never reads —
    or writes — the autotune cache. For ``kind == "flash"`` the convention
    is m = query rows, n = KV rows, k = head dim, batch = batch·heads; the
    QK and PV GEMMs both count, and K/V stream once in the model (the
    re-stream factor cancels in the overhead *delta* the planner uses)."""
    gemms = _PLAN_GEMMS.get(kind, 1)
    flops = gemms * 2.0 * m * n * k * batch
    if kind == "flash":
        bytes_ = (m * k + 2.0 * n * k + m * k) * in_bytes * batch
    elif kind == "tgmm":
        # Output-stationary dw: m is the reduction (buffer-row) dim; the
        # (k, n) output is written once per group in f32 — batch carries
        # the group count here.
        bytes_ = (m * k + m * n) * in_bytes + max(batch, 1) * k * n * 4.0
    else:
        bytes_ = (m * k + k * n + m * n) * in_bytes * batch
        if kind == "grouped":
            bytes_ = (m * k + m * n) * in_bytes + batch * k * n * in_bytes
    return flops, bytes_


def ft_plan_cost(kind: str, m: int, n: int, k: int, batch: int = 1,
                 in_bytes: int = 4, *, action: str = "correct",
                 verify: str = "step") -> Tuple[float, float]:
    """(base_time_s, ft_overhead_time_s) for one site population under a
    protection rung — the roofline *delta*, so memory-bound sites absorb
    their checksum FLOPs for free (Kosaian & Rashmi, arXiv 2104.09455)
    while compute-bound ones pay the full maintenance + verify price.

    Maintenance (any enabled action): running column + row checksums touch
    each streamed operand element once and fold it with a MAC —
    ≈ 2·(M·K + K·N) FLOPs per GEMM. Verify: ≈ 3·M·N per pass (two checksum
    reductions of the accumulator + compare), `verify="step"` paying it
    every ⌈K/bk_ref⌉ steps vs once at `"final"`; `action="correct"` adds the
    branchless rank-1 correction update ≈ 2·M·N per pass."""
    flops, bytes_ = ft_plan_base(kind, m, n, k, batch, in_bytes)
    base = roofline.kernel_time_s(flops, bytes_)
    if action == "off":
        return base, 0.0
    gemms = _PLAN_GEMMS.get(kind, 1)
    maint = gemms * 2.0 * (m * k + k * n) * batch
    n_verify = max(1, math.ceil(k / _PLAN_BK_REF)) if verify == "step" else 1
    per_pass = 3.0 * m * n + (2.0 * m * n if action == "correct" else 0.0)
    verify_flops = gemms * per_pass * n_verify * batch
    prot = roofline.kernel_time_s(flops + maint + verify_flops, bytes_)
    return base, max(prot - base, 0.0)


# ---------------------------------------------------------------------------
# Ragged-tile fitting (masked dispatch)
# ---------------------------------------------------------------------------

def fit_tile(dim: int, max_tile: int, align: int) -> int:
    """Block edge for a ragged dimension: among multiples of `align` up to
    `max_tile`, minimize executed work `ceil(dim/c)·c`; break ties toward
    the larger tile. `fit_tile(100, 128, 8) == 104` — one masked tile
    instead of a zero-padded 128."""
    assert max_tile >= align > 0
    best = None
    for c in range(align, max_tile + 1, align):
        waste = math.ceil(dim / c) * c
        key = (waste, -c)
        if best is None or key < best[0]:
            best = (key, c)
    return best[1]
