"""Flash attention with fused online ABFT — the beyond-paper kernel family.

The paper's core insight is that ABFT only becomes ~free when its memory
operations are fused into a kernel that already holds the data in fast
memory. We apply that insight to the other GEMM-dominated hot spot of every
assigned architecture: attention — in BOTH directions.

Forward flash attention (online softmax over kv blocks; scores never touch
HBM) where BOTH in-kernel GEMMs are ABFT-protected per kv-step:

  * scores S = Q_blk·K_blkᵀ — verified against (eᵀQ)·Kᵀ and Q·(Kᵀe)
    *before* masking/softmax (the check is linear; the nonlinearity comes
    after);
  * delta  Δ = P·V_blk     — verified against (eᵀP)·V and P·(Ve); a located
    SEU is corrected branchlessly before Δ is rescaled into the
    accumulator.

With ``save_stats`` the forward additionally writes the per-row softmax
statistics (m = running row max of the scaled scores, l = running row sum
of exp) as extra VMEM outputs — the saved residual of the dedicated
backward (PR 5), which replaces the chunked-jnp oracle recompute:

  * `_flash_dq_kernel`  — q-block-stationary: recomputes S from (m, l),
    then dP = g·Vᵀ and dQ = Σ_kv dS·K, each GEMM checksum-verified and
    branchlessly corrected per kv-step;
  * `_flash_dkv_kernel` — kv-block-stationary (GQA folds the n_rep query
    heads of a KV head into the reduction walk): S recompute + dP = g·Vᵀ,
    dV = Σ_q Pᵀ·g and dK = Σ_q dSᵀ·Q, all verified per q-step.

So the four backward GEMMs of the attention train step (dP, dV, dQ, dK)
carry in-kernel ABFT exactly like the two forward ones; one SEU per
(stationary block × reduction step × GEMM) is detected AND corrected, and
the backward's HBM traffic is flash-shaped (Q, K, V, g, dQ, dK, dV + three
O(S) statistic columns — no S×S materialization, no O(chunk·S) oracle
transient).

Fully-masked query rows (a ragged Sq edge, or a causal row whose kv span is
empty) are *m-degenerate*: their running max never leaves −∞, so the
pre-fix kernel flushed `exp(0)=1` garbage weights (and `acc/1e-30` when
nothing accumulated). Degenerate rows are now zeroed at every step AND at
flush, their saved stats are written as (m=−∞, l=0), and the backward maps
l=0 to p≡0 — so both directions return exact zeros for such rows.

Stochastic SEU campaigns (`ft.inject_rate` > 0 with an injection key) run
IN-KERNEL through `templates.emit.stochastic_seu`: two words derived from
the campaign key ride in via scalar prefetch and a counter-based hash draws
one Bernoulli(rate) SEU per stationary output block per direction — so a
forced-flash fault campaign exercises the kernels it measures instead of
silently running clean (the MPGemmFI injector/kernel-disagreement pitfall).

Ragged sequence lengths take the masked dispatch of the GEMM kernels: the
true (Sq, Skv) ride in via scalar prefetch, kv blocks wholly past the true
Skv are skipped, and padded positions are masked after the (linear) score
verification and before softmax.

Launch construction lives in `templates.registry` (flash_fwd_call /
flash_dq_call / flash_dkv_call) and tile selection in `autotune.best_params`
under `templates.spec.FlashKernelSpec` variant keys (``/v_flashfwd*``,
``/v_flashbwd_dq``, ``/v_flashbwd_dkv``). Validated in interpret mode
against jnp oracles (tests/test_flashft.py, tests/test_flash_backward.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.policy import FTConfig, InjectionSpec
from repro.tools.trace import traced
from .templates import emit as temit
from .templates import registry as tregistry

F32EPS = float(jnp.finfo(jnp.float32).eps)
NEG_INF = -1e30
REPORT_WIDTH = temit.REPORT_WIDTH

#: Contract flag `models.blocks` checks before launching a stochastic
#: (`ft.inject_rate`-driven) campaign down the flash path: True means the
#: kernels honor the campaign key in-kernel (both directions). A build that
#: cannot (e.g. a future backend without the hook) must flip this so forced
#: campaigns raise instead of silently measuring a clean run.
SUPPORTS_STOCHASTIC_INJECTION = True

#: Deterministic backward-injection targets (`encode_bwd_injection`):
#: which of the four backward GEMMs the SEU lands in. "dp_q"/"dp_kv" hit the
#: dP = g·Vᵀ product inside the dq / dkv kernel respectively.
BWD_TARGETS = {"dp_q": 0, "dq": 1, "dp_kv": 0, "dv": 2, "dk": 3}
_DQ_KERNEL_TARGETS = ("dp_q", "dq")
_DKV_KERNEL_TARGETS = ("dp_kv", "dv", "dk")

#: Per-kernel salts for the stochastic hook — one independent stream per
#: direction from a single campaign key.
SALT_FWD, SALT_DQ, SALT_DKV, SALT_DECODE = 0x51, 0x52, 0x53, 0x54

_CONTRACT_ROWS = (((0,), (0,)), ((), ()))     # Aᵀ·B without a transpose


def _iota2(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _row_mask(q_start, bq, width, true_sq):
    """(bq, width) mask of live query rows (rows past true Sq are dead)."""
    return q_start + _iota2((bq, width), 0) < true_sq


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------

def _flash_ft_kernel(inj_ref, mag_ref, rng_ref, dims_ref,
                     q_ref, k_ref, v_ref,
                     *out_and_scratch,
                     kv_steps: int, q_blocks: int, bq: int, bkv: int,
                     dh: int, causal: bool, scale: float, corrects: bool,
                     rel_tau: float, protect_qk: bool, save_stats: bool,
                     inject_rate: float, bit_shift: int):
    refs = list(out_and_scratch)
    o_ref = refs.pop(0)
    m_out_ref = refs.pop(0) if save_stats else None
    l_out_ref = refs.pop(0) if save_stats else None
    rep_ref, acc_ref, m_ref, l_ref = refs

    h = pl.program_id(0)
    qi = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        rep_ref[...] = jnp.zeros_like(rep_ref)

    q_start = qi * bq
    kv_start = s * bkv
    true_sq = dims_ref[0]
    true_skv = dims_ref[1]
    # Causal positions are bottom-right aligned on the TRUE lengths: query
    # row i attends kv j iff j ≤ i + (Skv − Sq) — the decode/cross-length
    # convention (Sq == Skv ⇒ the familiar triangular mask). The offset is
    # dynamic (scalar-prefetched), which is what lets ragged Sq ≠ Skv run
    # causally on fitted blocks instead of falling back to padded shapes.
    c_off = true_skv - true_sq
    # Ragged dispatch: kv blocks wholly past the true Skv are skipped
    # (scalar-prefetched seq lens, not padded shapes, drive the loop).
    run = kv_start < true_skv
    if causal:
        run = run & (kv_start <= q_start + bq - 1 + c_off)

    # One stochastic SEU per (head, q-block) with probability inject_rate,
    # landing in the PV accumulator at a uniformly drawn (kv step, row,
    # col) — the in-kernel campaign hook (see templates.emit). The step is
    # drawn over the block's LIVE kv span, not the grid extent, so the
    # realized rate matches the nominal one under causal/ragged skipping.
    n_live = _live_kv_steps(true_skv, q_start, bq, bkv, c_off, causal)
    st_hit, st_step, st_row, st_col = temit.stochastic_seu(
        rng_ref, SALT_FWD, h * q_blocks + qi, n_live, bq, dh, inject_rate)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                 # (bkv, dh)
        v = v_ref[0].astype(jnp.float32)

        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if protect_qk:
            ck_col = jnp.dot(jnp.sum(q, 0, keepdims=True), k.T)   # (1,bkv)
            ck_row = jnp.dot(q, jnp.sum(k.T, 1, keepdims=True))   # (bq,1)
            d_col = jnp.sum(scores, 0, keepdims=True) - ck_col
            d_row = jnp.sum(scores, 1, keepdims=True) - ck_row
            tau_qk = jnp.maximum(
                rel_tau * F32EPS * dh
                * jnp.max(jnp.abs(q)) * jnp.max(jnp.abs(k)), 1e-30)
            scores, det_qk, mag_qk, row_qk, col_qk = \
                temit._locate_correct_full(scores, d_col, d_row, tau_qk,
                                           corrects, bq, bkv)
            temit._record(rep_ref, det_qk, mag_qk, row_qk + q_start,
                          col_qk + kv_start, d_col, d_row, tau_qk,
                          (s + 1.0) * 1.0, corrects)
        scores = scores * scale

        # ---- emulated SEU on the scores accumulator ----------------------
        enable, g_h, g_qi, g_s, g_row, g_col = (
            inj_ref[0], inj_ref[1], inj_ref[2], inj_ref[3], inj_ref[4],
            inj_ref[5])
        hit = ((enable == 1) & (g_h == h) & (g_qi == qi) & (g_s == s))
        # injection lands in the Δ=PV accumulator below (paper §5.3 semantics)

        # Ragged edge masking: padded KV positions (past the true Skv) and
        # padded/dead QUERY rows (past the true Sq) must not receive
        # attention — masked to -inf *after* the linear-GEMM checksum
        # verification above (zero-padded operand rows are checksum-neutral)
        # and *before* softmax, exactly like the causal mask. Dead query
        # rows therefore stay m-degenerate and flush as exact zeros below
        # instead of accumulating exp(0)=1 garbage weights.
        kpos = kv_start + _iota2((bq, bkv), 1)
        scores = jnp.where(kpos < true_skv, scores, NEG_INF)
        scores = jnp.where(_row_mask(q_start, bq, bkv, true_sq), scores,
                           NEG_INF)
        if causal:
            qpos = q_start + _iota2((bq, bkv), 0)
            scores = jnp.where(qpos + c_off >= kpos, scores, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, 1, keepdims=True))
        # m-degenerate rows (every position masked so far — dead ragged
        # rows, empty causal spans) would see exp(−∞ − (−∞)) = 1 here;
        # clamp the exponent and zero their weights so they accumulate
        # nothing.
        good = m_new > 0.5 * NEG_INF                      # (bq, 1)
        p = jnp.exp(jnp.minimum(scores - m_new, 0.0))     # (bq, bkv)
        p = jnp.where(good, p, 0.0)
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))  # (bq, 1)

        delta = jnp.dot(p, v, preferred_element_type=jnp.float32)  # (bq, dh)
        inj_mask = ((_iota2((bq, dh), 0) == g_row)
                    & (_iota2((bq, dh), 1) == g_col) & hit)
        delta = delta + jnp.where(inj_mask, mag_ref[0], 0.0)
        delta = temit.apply_seu(delta, st_row, st_col,
                                st_hit & (st_step == s), bit_shift)

        # ---- fused ABFT on the PV GEMM ------------------------------------
        ck_col = jnp.dot(jnp.sum(p, 0, keepdims=True), v)          # (1, dh)
        ck_row = jnp.dot(p, jnp.sum(v, 1, keepdims=True))          # (bq, 1)
        d_col = jnp.sum(delta, 0, keepdims=True) - ck_col
        d_row = jnp.sum(delta, 1, keepdims=True) - ck_row
        # Rounding-error accumulation stops at the true Skv: on a ragged
        # edge block only the live kv positions contribute to the p·V
        # reduction, so the threshold must not inflate to the full bkv
        # (same clamp as the masked GEMM template's k_elapsed).
        eff_kv = jnp.minimum(true_skv - kv_start, bkv).astype(jnp.float32)
        tau = jnp.maximum(rel_tau * F32EPS * eff_kv * jnp.max(jnp.abs(v)),
                          1e-30)
        delta, det_pv, mag_pv, row_pv, col_pv = temit._locate_correct_full(
            delta, d_col, d_row, tau, corrects, bq, dh)
        temit._record(rep_ref, det_pv, mag_pv, row_pv + q_start, col_pv,
                      d_col, d_row, tau, eff_kv, corrects)

        acc_ref[...] = acc_ref[...] * alpha + delta
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, 1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(s == kv_steps - 1)
    def _flush():
        # m-degenerate rows (m still −∞: dead ragged rows, empty causal kv
        # spans, or q-blocks whose every kv block was skipped) flush exact
        # zeros — never `garbage_acc / 1e-30` — and their saved statistics
        # are the degenerate markers (m=−∞, l=0) the backward kernels map
        # to p ≡ 0.
        m_fin = m_ref[...]
        l_fin = l_ref[...]
        good = (m_fin > 0.5 * NEG_INF) & (l_fin > 0.0)
        linv = jnp.where(good, 1.0 / jnp.maximum(l_fin, 1e-30), 0.0)
        o_ref[0] = (acc_ref[...] * linv).astype(o_ref.dtype)
        if save_stats:
            m_out_ref[0] = jnp.where(good, m_fin, NEG_INF
                                     ).astype(m_out_ref.dtype)
            l_out_ref[0] = jnp.where(good, l_fin, 0.0
                                     ).astype(l_out_ref.dtype)


# ---------------------------------------------------------------------------
# paged ragged decode kernel (PR 9)
# ---------------------------------------------------------------------------

def _flash_decode_kernel(inj_ref, mag_ref, rng_ref, len_ref, tbl_ref,
                         q_ref, k_ref, v_ref,
                         o_ref, rep_ref, acc_ref, m_ref, l_ref, *,
                         kv_steps: int, kvh: int, bq: int, page: int,
                         dh: int, scale: float, corrects: bool,
                         rel_tau: float, protect_qk: bool,
                         inject_rate: float, bit_shift: int):
    """Single-position paged decode with per-row ragged lengths.

    Grid (n_slots · n_kv_heads, max_pages): one grid row per (serving slot,
    kv head); its stationary q block holds that head's n_rep GQA query rows
    (zero-padded to the sublane-aligned bq — checksum-neutral, sliced off by
    the ops wrapper) at ONE decode position, and the reduction walk streams
    the slot's KV-cache pages. The page table (``tbl_ref``) is consumed by
    the K/V *index maps* — each kv step DMAs exactly the physical page the
    slot's table names, so thousands of slots share one pool with no dense
    padding; the body itself reads only the per-slot true length
    (``len_ref``, the ragged `int32[B]` replacing the forward's one
    (Sq, Skv) pair). Both GEMMs carry the same fused ABFT as the forward:
    S = QKᵀ verified before masking, Δ = PV verified with the τ clamped to
    the row's LIVE kv span (min(true_len − page·s, page)) so detection
    stays exact on ragged rows. Slots with true length 0 (dead slots
    streaming the null page) never execute a step and flush exact zeros via
    the m-degenerate clamp."""
    del tbl_ref                      # routing only — consumed by index maps
    g = pl.program_id(0)
    s = pl.program_id(1)
    slot = g // kvh

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        rep_ref[...] = jnp.zeros_like(rep_ref)

    true_len = len_ref[slot]
    kv_start = s * page
    run = kv_start < true_len

    # One stochastic SEU per (slot, kv head) grid row, step drawn over the
    # slot's LIVE page walk (ceil(len/page)) so the realized rate matches
    # the nominal one across ragged rows.
    n_live = jnp.maximum((true_len + page - 1) // page, 0)
    st_hit, st_step, st_row, st_col = temit.stochastic_seu(
        rng_ref, SALT_DECODE, g, n_live, bq, dh, inject_rate)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)               # (page, dh)
        v = v_ref[0, 0].astype(jnp.float32)

        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if protect_qk:
            ck_col = jnp.dot(jnp.sum(q, 0, keepdims=True), k.T)  # (1,page)
            ck_row = jnp.dot(q, jnp.sum(k.T, 1, keepdims=True))  # (bq, 1)
            d_col = jnp.sum(scores, 0, keepdims=True) - ck_col
            d_row = jnp.sum(scores, 1, keepdims=True) - ck_row
            tau_qk = jnp.maximum(
                rel_tau * F32EPS * dh
                * jnp.max(jnp.abs(q)) * jnp.max(jnp.abs(k)), 1e-30)
            scores, det_qk, mag_qk, row_qk, col_qk = \
                temit._locate_correct_full(scores, d_col, d_row, tau_qk,
                                           corrects, bq, page)
            temit._record(rep_ref, det_qk, mag_qk, row_qk,
                          col_qk + kv_start, d_col, d_row, tau_qk,
                          (s + 1.0) * 1.0, corrects)
        scores = scores * scale

        # ---- emulated SEU (deterministic campaign vector) ----------------
        enable, g_g, g_qi, g_s, g_row, g_col = (
            inj_ref[0], inj_ref[1], inj_ref[2], inj_ref[3], inj_ref[4],
            inj_ref[5])
        hit = ((enable == 1) & (g_g == g) & (g_qi == 0) & (g_s == s))

        # Per-row ragged masking: positions at or past the slot's true
        # length (including every position of a trailing NULL/garbage page)
        # are dead — masked AFTER the linear score verification, like the
        # forward's kv edge. Decode needs no causal term: the query IS
        # position true_len − 1, so the span mask is the causal mask.
        kpos = kv_start + _iota2((bq, page), 1)
        scores = jnp.where(kpos < true_len, scores, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, 1, keepdims=True))
        good = m_new > 0.5 * NEG_INF
        p = jnp.exp(jnp.minimum(scores - m_new, 0.0))     # (bq, page)
        p = jnp.where(good, p, 0.0)
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))

        delta = jnp.dot(p, v, preferred_element_type=jnp.float32)  # (bq,dh)
        inj_mask = ((_iota2((bq, dh), 0) == g_row)
                    & (_iota2((bq, dh), 1) == g_col) & hit)
        delta = delta + jnp.where(inj_mask, mag_ref[0], 0.0)
        delta = temit.apply_seu(delta, st_row, st_col,
                                st_hit & (st_step == s), bit_shift)

        # ---- fused ABFT on the PV GEMM -----------------------------------
        ck_col = jnp.dot(jnp.sum(p, 0, keepdims=True), v)          # (1, dh)
        ck_row = jnp.dot(p, jnp.sum(v, 1, keepdims=True))          # (bq, 1)
        d_col = jnp.sum(delta, 0, keepdims=True) - ck_col
        d_row = jnp.sum(delta, 1, keepdims=True) - ck_row
        # τ follows the row's live span on the final (partial) page, not
        # the full page width — the ragged-rows-stay-exact clamp.
        eff_kv = jnp.minimum(true_len - kv_start, page).astype(jnp.float32)
        tau = jnp.maximum(rel_tau * F32EPS * eff_kv * jnp.max(jnp.abs(v)),
                          1e-30)
        delta, det_pv, mag_pv, row_pv, col_pv = temit._locate_correct_full(
            delta, d_col, d_row, tau, corrects, bq, dh)
        temit._record(rep_ref, det_pv, mag_pv, row_pv, col_pv,
                      d_col, d_row, tau, eff_kv, corrects)

        acc_ref[...] = acc_ref[...] * alpha + delta
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, 1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(s == kv_steps - 1)
    def _flush():
        m_fin = m_ref[...]
        l_fin = l_ref[...]
        good = (m_fin > 0.5 * NEG_INF) & (l_fin > 0.0)
        linv = jnp.where(good, 1.0 / jnp.maximum(l_fin, 1e-30), 0.0)
        o_ref[0] = (acc_ref[...] * linv).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# backward kernels — shared per-step softmax/score recompute
# ---------------------------------------------------------------------------

def _recompute_p(q, k, m, linv, *, q_start, kv_start, bq, bkv, true_sq,
                 true_skv, c_off, causal, scale, rel_tau, corrects,
                 protect_qk, rep_ref):
    """Rebuild the (bq, bkv) probability block from the saved statistics:
    p = exp(scale·QKᵀ − m) / l with the kv-edge/causal/dead-row masks of the
    forward. The S = QKᵀ recompute is checksum-verified like the forward's
    (the backward's fifth GEMM). Degenerate rows (l=0 ⇒ linv=0) come out
    exactly zero. Returns (p, scores_scaled, det)."""
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    det = jnp.zeros((), bool)
    if protect_qk:
        ck_col = jnp.dot(jnp.sum(q, 0, keepdims=True), k.T)
        ck_row = jnp.dot(q, jnp.sum(k.T, 1, keepdims=True))
        d_col = jnp.sum(scores, 0, keepdims=True) - ck_col
        d_row = jnp.sum(scores, 1, keepdims=True) - ck_row
        tau_qk = jnp.maximum(
            rel_tau * F32EPS * q.shape[1]
            * jnp.max(jnp.abs(q)) * jnp.max(jnp.abs(k)), 1e-30)
        scores, det, mag, row_l, col_l = temit._locate_correct_full(
            scores, d_col, d_row, tau_qk, corrects, bq, bkv)
        temit._record(rep_ref, det, mag, row_l + q_start, col_l + kv_start,
                      d_col, d_row, tau_qk, 1.0, corrects)
    scores = scores * scale
    live = ((kv_start + _iota2((bq, bkv), 1) < true_skv)
            & _row_mask(q_start, bq, bkv, true_sq))
    if causal:
        qpos = q_start + _iota2((bq, bkv), 0)
        live = live & (qpos + c_off >= kv_start + _iota2((bq, bkv), 1))
    # exp is clamped so masked/degenerate entries cannot overflow before
    # they are zeroed (m is the row max over *live* positions only).
    p = jnp.exp(jnp.minimum(scores - m, 0.0)) * linv
    p = jnp.where(live, p, 0.0)
    return p, scores, det


def _verify_dp(dp, g, v, rep_ref, *, bq, bkv, dh, rel_tau, corrects,
               q_start, kv_start):
    """Checksum-verify (and correct) the dP = g·Vᵀ product."""
    ck_col = jnp.dot(jnp.sum(g, 0, keepdims=True), v.T)          # (1, bkv)
    ck_row = jnp.dot(g, jnp.sum(v, 0, keepdims=True).T)          # (bq, 1)
    d_col = jnp.sum(dp, 0, keepdims=True) - ck_col
    d_row = jnp.sum(dp, 1, keepdims=True) - ck_row
    tau = jnp.maximum(rel_tau * F32EPS * dh * jnp.max(jnp.abs(g))
                      * jnp.max(jnp.abs(v)), 1e-30)
    dp, det, mag, row_l, col_l = temit._locate_correct_full(
        dp, d_col, d_row, tau, corrects, bq, bkv)
    temit._record(rep_ref, det, mag, row_l + q_start, col_l + kv_start,
                  d_col, d_row, tau, float(dh), corrects)
    return dp


def _verify_delta(delta, a, b, eff, rep_ref, *, row_off, rel_tau, corrects,
                  transpose_a):
    """Checksum-verify (and correct) one accumulator delta of the backward
    GEMMs — the shared Huang–Abraham step for dQ = dS·K
    (``transpose_a=False``: delta = a·b) and dV = Pᵀ·g / dK = dSᵀ·Q
    (``transpose_a=True``: delta = aᵀ·b, contraction over rows, no
    materialized transpose). ``eff`` is the live contraction length driving
    the rounding-aware threshold."""
    if transpose_a:
        ck_col = jax.lax.dot_general(jnp.sum(a, 1, keepdims=True), b,
                                     _CONTRACT_ROWS)             # (1, n)
        ck_row = jax.lax.dot_general(a, jnp.sum(b, 1, keepdims=True),
                                     _CONTRACT_ROWS)             # (m, 1)
    else:
        ck_col = jnp.dot(jnp.sum(a, 0, keepdims=True), b)
        ck_row = jnp.dot(a, jnp.sum(b, 1, keepdims=True))
    d_col = jnp.sum(delta, 0, keepdims=True) - ck_col
    d_row = jnp.sum(delta, 1, keepdims=True) - ck_row
    tau = jnp.maximum(rel_tau * F32EPS * eff * jnp.max(jnp.abs(a))
                      * jnp.max(jnp.abs(b)), 1e-30)
    delta, det, mag, row_l, col_l = temit._locate_correct_full(
        delta, d_col, d_row, tau, corrects, *delta.shape)
    temit._record(rep_ref, det, mag, row_l + row_off, col_l, d_col, d_row,
                  tau, eff, corrects)
    return delta


def _live_kv_steps(true_skv, q_start, bq, bkv, c_off, causal: bool):
    """Number of kv steps a q-block actually executes (the ragged kv edge
    and, for causal dispatch, the bottom-right-aligned bound) — the live
    span the stochastic hook draws its step over."""
    kv_hi = true_skv
    if causal:
        kv_hi = jnp.minimum(kv_hi, q_start + bq + c_off)
    return jnp.maximum((kv_hi + bkv - 1) // bkv, 0)


def _flash_dq_kernel(inj_ref, mag_ref, rng_ref, dims_ref,
                     q_ref, k_ref, v_ref, g_ref, m_ref, l_ref, di_ref,
                     dq_ref, rep_ref, acc_ref, *,
                     kv_steps: int, q_blocks: int, bq: int, bkv: int,
                     dh: int, causal: bool, scale: float, corrects: bool,
                     rel_tau: float, protect_qk: bool, inject_rate: float,
                     bit_shift: int):
    """dQ = Σ_kv (P ∘ (g·Vᵀ − di))·scale·K — q-block stationary, kv blocks
    as the reduction walk (the forward's grid transposed onto gradients).
    Both in-step GEMMs (dP and the dQ delta) are verified per kv-step."""
    h = pl.program_id(0)
    qi = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rep_ref[...] = jnp.zeros_like(rep_ref)

    true_sq = dims_ref[0]
    true_skv = dims_ref[1]
    q_start = qi * bq
    kv_start = s * bkv
    c_off = true_skv - true_sq
    run = (kv_start < true_skv) & (q_start < true_sq)
    if causal:
        run = run & (kv_start <= q_start + bq - 1 + c_off)

    enable, target, g_h, g_blk, g_s, g_row, g_col = (inj_ref[i]
                                                     for i in range(7))
    det_hit = (enable == 1) & (g_h == h) & (g_blk == qi) & (g_s == s)
    n_live = _live_kv_steps(true_skv, q_start, bq, bkv, c_off, causal)
    st_hit, st_step, st_row, st_col = temit.stochastic_seu(
        rng_ref, SALT_DQ, h * q_blocks + qi, n_live, bq, dh, inject_rate)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, dh)
        v = v_ref[0].astype(jnp.float32)
        g = g_ref[0].astype(jnp.float32)                  # (bq, dh)
        m = m_ref[0]                                      # (bq, 1) f32
        l = l_ref[0]
        di = di_ref[0]
        linv = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)

        p, _, _ = _recompute_p(
            q, k, m, linv, q_start=q_start, kv_start=kv_start, bq=bq,
            bkv=bkv, true_sq=true_sq, true_skv=true_skv, c_off=c_off,
            causal=causal, scale=scale, rel_tau=rel_tau, corrects=corrects,
            protect_qk=protect_qk, rep_ref=rep_ref)

        dp = jnp.dot(g, v.T, preferred_element_type=jnp.float32)  # (bq,bkv)
        inj_dp = ((_iota2((bq, bkv), 0) == g_row)
                  & (_iota2((bq, bkv), 1) == g_col)
                  & det_hit & (target == BWD_TARGETS["dp_q"]))
        dp = dp + jnp.where(inj_dp, mag_ref[0], 0.0)
        dp = _verify_dp(dp, g, v, rep_ref, bq=bq, bkv=bkv, dh=dh,
                        rel_tau=rel_tau, corrects=corrects,
                        q_start=q_start, kv_start=kv_start)

        ds = p * (dp - di) * scale                        # (bq, bkv)
        delta = jnp.dot(ds, k, preferred_element_type=jnp.float32)
        inj_dq = ((_iota2((bq, dh), 0) == g_row)
                  & (_iota2((bq, dh), 1) == g_col)
                  & det_hit & (target == BWD_TARGETS["dq"]))
        delta = delta + jnp.where(inj_dq, mag_ref[0], 0.0)
        delta = temit.apply_seu(delta, st_row, st_col,
                                st_hit & (st_step == s), bit_shift)

        eff_kv = jnp.minimum(true_skv - kv_start, bkv).astype(jnp.float32)
        delta = _verify_delta(delta, ds, k, eff_kv, rep_ref,
                              row_off=q_start, rel_tau=rel_tau,
                              corrects=corrects, transpose_a=False)
        acc_ref[...] += delta

    @pl.when(s == kv_steps - 1)
    def _flush():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_dkv_kernel(inj_ref, mag_ref, rng_ref, dims_ref,
                      q_ref, g_ref, m_ref, l_ref, di_ref, k_ref, v_ref,
                      dk_ref, dv_ref, rep_ref, dk_acc, dv_acc, *,
                      q_steps: int, n_rep: int, kv_blocks: int, bq: int,
                      bkv: int, dh: int, causal: bool, scale: float,
                      corrects: bool, rel_tau: float, protect_qk: bool,
                      inject_rate: float, bit_shift: int):
    """dV = Σ_q Pᵀ·g and dK = Σ_q dSᵀ·Q·scale — kv-block stationary. The
    reduction walk covers (n_rep × q-blocks): GQA is served by the same
    query-head index maps as the forward (query head b·n_rep + r reads KV
    head b), so the per-KV-head gradient sums its n_rep query heads without
    repeat-materializing anything. All three in-step GEMMs verified."""
    b = pl.program_id(0)
    kvi = pl.program_id(1)
    r = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when((r == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)
        rep_ref[...] = jnp.zeros_like(rep_ref)

    true_sq = dims_ref[0]
    true_skv = dims_ref[1]
    q_start = qi * bq
    kv_start = kvi * bkv
    c_off = true_skv - true_sq
    run = (kv_start < true_skv) & (q_start < true_sq)
    if causal:
        run = run & (kv_start <= q_start + bq - 1 + c_off)

    h_q = b * n_rep + r                      # the query head of this step
    enable, target, g_h, g_blk, g_s, g_row, g_col = (inj_ref[i]
                                                     for i in range(7))
    det_hit = (enable == 1) & (g_h == h_q) & (g_blk == kvi) & (g_s == qi)
    # Live (r, qi) span of this kv block: q blocks past the true Sq and,
    # for causal dispatch, q blocks wholly above the bottom-right bound
    # never execute — the stochastic step is drawn over the live walk only
    # (uniform realized rate), and compared against the step's live index.
    qi_hi = jnp.minimum((true_sq + bq - 1) // bq, q_steps)
    qi_lo = (jnp.maximum((kv_start - c_off) // bq, 0) if causal
             else jnp.zeros((), jnp.int32))
    span = jnp.maximum(qi_hi - qi_lo, 0)
    n_live = jnp.where(kv_start < true_skv, n_rep * span, 0)
    st_hit, st_step, st_row, st_col = temit.stochastic_seu(
        rng_ref, SALT_DKV, b * kv_blocks + kvi, n_live, bkv, dh,
        inject_rate)
    step_idx = r * span + (qi - qi_lo)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
        g = g_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, dh)
        v = v_ref[0].astype(jnp.float32)
        m = m_ref[0]
        l = l_ref[0]
        di = di_ref[0]
        linv = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)

        p, _, _ = _recompute_p(
            q, k, m, linv, q_start=q_start, kv_start=kv_start, bq=bq,
            bkv=bkv, true_sq=true_sq, true_skv=true_skv, c_off=c_off,
            causal=causal, scale=scale, rel_tau=rel_tau, corrects=corrects,
            protect_qk=protect_qk, rep_ref=rep_ref)

        dp = jnp.dot(g, v.T, preferred_element_type=jnp.float32)  # (bq,bkv)
        inj_dp = ((_iota2((bq, bkv), 0) == g_row)
                  & (_iota2((bq, bkv), 1) == g_col)
                  & det_hit & (target == BWD_TARGETS["dp_kv"]))
        dp = dp + jnp.where(inj_dp, mag_ref[0], 0.0)
        dp = _verify_dp(dp, g, v, rep_ref, bq=bq, bkv=bkv, dh=dh,
                        rel_tau=rel_tau, corrects=corrects,
                        q_start=q_start, kv_start=kv_start)

        eff_q = jnp.maximum(
            jnp.minimum(true_sq - q_start, bq), 1).astype(jnp.float32)

        # ---- dV delta: Pᵀ·g ---------------------------------------------
        dv_delta = jax.lax.dot_general(p, g, _CONTRACT_ROWS,
                                       preferred_element_type=jnp.float32)
        inj_dv = ((_iota2((bkv, dh), 0) == g_row)
                  & (_iota2((bkv, dh), 1) == g_col)
                  & det_hit & (target == BWD_TARGETS["dv"]))
        dv_delta = dv_delta + jnp.where(inj_dv, mag_ref[0], 0.0)
        dv_delta = temit.apply_seu(dv_delta, st_row, st_col,
                                   st_hit & (st_step == step_idx), bit_shift)
        dv_delta = _verify_delta(dv_delta, p, g, eff_q, rep_ref,
                                 row_off=kv_start, rel_tau=rel_tau,
                                 corrects=corrects, transpose_a=True)
        dv_acc[...] += dv_delta

        # ---- dK delta: dSᵀ·Q --------------------------------------------
        ds = p * (dp - di) * scale                        # (bq, bkv)
        dk_delta = jax.lax.dot_general(ds, q, _CONTRACT_ROWS,
                                       preferred_element_type=jnp.float32)
        inj_dk = ((_iota2((bkv, dh), 0) == g_row)
                  & (_iota2((bkv, dh), 1) == g_col)
                  & det_hit & (target == BWD_TARGETS["dk"]))
        dk_delta = dk_delta + jnp.where(inj_dk, mag_ref[0], 0.0)
        dk_delta = _verify_delta(dk_delta, ds, q, eff_q, rep_ref,
                                 row_off=kv_start, rel_tau=rel_tau,
                                 corrects=corrects, transpose_a=True)
        dk_acc[...] += dk_delta

    @pl.when((r == n_rep - 1) & (qi == q_steps - 1))
    def _flush():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# jit'd entry points (launch construction lives in templates.registry)
# ---------------------------------------------------------------------------

@traced("kernel/flashft/fwd")
@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal", "ft",
                                             "interpret", "protect_qk",
                                             "scale", "n_rep", "save_stats"))
def flash_ft_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       inj_idx: jax.Array, inj_mag: jax.Array,
                       dims: Optional[jax.Array] = None,
                       rng: Optional[jax.Array] = None, *,
                       bq: int = 128, bkv: int = 128, causal: bool = True,
                       ft: FTConfig, interpret: bool = False,
                       protect_qk: bool = True, scale: float = None,
                       n_rep: int = 1, save_stats: bool = False):
    """q: (BH, Sq, dh); k, v: (BH/n_rep, Skv, dh); dh lane-aligned (pad to
    128 in the ops wrapper). ``n_rep`` is the GQA query-group width: query
    head h reads KV head h // n_rep straight through the K/V *index maps*,
    so grouped-query attention runs without repeat-materializing the KV
    operands. inj_idx int32[6] = [enable, bh, q_block, kv_step, row, col];
    inj_mag f32[1]; dims int32[2] true (Sq, Skv) for the masked ragged path
    (None → the padded shapes are the true lengths); rng int32[3] =
    [enable, seed0, seed1] drives the in-kernel stochastic SEU hook
    (`encode_rng`; None → disabled). Returns (out (BH, Sq, dh), report) —
    or (out, m, l, report) with ``save_stats`` (the per-row softmax
    statistics (BH, Sq, 1) f32 the dedicated backward consumes)."""
    bh, sq, dh = q.shape
    bkvh, skv, _ = k.shape
    assert bh == bkvh * n_rep, (q.shape, k.shape, n_rep)
    assert sq % bq == 0 and skv % bkv == 0, (q.shape, k.shape, bq, bkv)
    if dims is None:
        dims = jnp.array([sq, skv], jnp.int32)
    if rng is None:
        rng = jnp.zeros((3,), jnp.int32)
    # dh here may be the 128-padded width; callers pass the true-dh scale
    scale = scale if scale is not None else dh ** -0.5
    return tregistry.flash_fwd_call(
        q, k, v, inj_idx, inj_mag, rng, dims, bq=bq, bkv=bkv, causal=causal,
        ft=ft, interpret=interpret, protect_qk=protect_qk, scale=scale,
        n_rep=n_rep, save_stats=save_stats)


@traced("kernel/flashft/decode")
@functools.partial(jax.jit, static_argnames=("kvh", "ft", "interpret",
                                             "protect_qk", "scale"))
def flash_ft_decode_attention(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, inj_idx: jax.Array,
                              inj_mag: jax.Array, lengths: jax.Array,
                              page_table: jax.Array,
                              rng: Optional[jax.Array] = None, *,
                              kvh: int, ft: FTConfig,
                              interpret: bool = False,
                              protect_qk: bool = True,
                              scale: float = None):
    """Paged ragged decode: q (B·kvh, bq, dh) — one stationary block per
    (slot, kv head) holding the head's n_rep GQA query rows at the slot's
    current position; k_pages/v_pages (n_pages, kvh, page, dh) — ONE
    layer's shared page pool; lengths int32[B] per-slot true kv lengths
    (the ragged vector; 0 = dead slot → exact-zero output); page_table
    int32[B, max_pages] physical page ids (NULL-padded), scalar-prefetched
    into the K/V index maps. inj_idx int32[6] = [enable, g, 0, kv_step,
    row, col] with g = slot·kvh + head (`encode_injection(spec, bh=g)`);
    rng int32[3] the stochastic hook (`encode_rng`). Returns
    (out (B·kvh, bq, dh), report (B·kvh, 1, W))."""
    g_rows, bq, dh = q.shape
    n_pages, kvh_p, page, dh_k = k_pages.shape
    assert kvh_p == kvh and dh_k == dh, (k_pages.shape, kvh, dh)
    assert g_rows == page_table.shape[0] * kvh, (q.shape, page_table.shape,
                                                 kvh)
    assert lengths.shape == (page_table.shape[0],), (lengths.shape,
                                                     page_table.shape)
    if rng is None:
        rng = jnp.zeros((3,), jnp.int32)
    scale = scale if scale is not None else dh ** -0.5
    return tregistry.flash_decode_call(
        q, k_pages, v_pages, inj_idx, inj_mag, rng, lengths, page_table,
        kvh=kvh, ft=ft, interpret=interpret, protect_qk=protect_qk,
        scale=scale)


@traced("kernel/flashft/dq")
@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal", "ft",
                                             "interpret", "protect_qk",
                                             "scale", "n_rep"))
def flash_ft_dq(q: jax.Array, k: jax.Array, v: jax.Array, g: jax.Array,
                m: jax.Array, l: jax.Array, di: jax.Array,
                inj_idx: jax.Array, inj_mag: jax.Array, dims: jax.Array,
                rng: Optional[jax.Array] = None, *,
                bq: int = 128, bkv: int = 128, causal: bool = True,
                ft: FTConfig, interpret: bool = False,
                protect_qk: bool = True, scale: float = None,
                n_rep: int = 1):
    """The dQ half of the dedicated flash backward: ONE Pallas launch over
    the saved (m, l) statistics and the precomputed di = rowsum(g ∘ o) —
    zero chunked-oracle recompute, no S×S transient. Operands padded to the
    (bq, bkv)-fitted grid by the ops wrapper; m/l/di are (BH, Sq, 1) f32
    with degenerate rows marked (m=−∞, l=0). inj_idx is the int32[7]
    deterministic-SEU vector (`encode_bwd_injection`). Returns (dq, rep)."""
    bh, sq, dh = q.shape
    assert bh == k.shape[0] * n_rep, (q.shape, k.shape, n_rep)
    assert sq % bq == 0 and k.shape[1] % bkv == 0, (q.shape, k.shape, bq,
                                                    bkv)
    if rng is None:
        rng = jnp.zeros((3,), jnp.int32)
    scale = scale if scale is not None else dh ** -0.5
    return tregistry.flash_dq_call(
        q, k, v, g, m, l, di, inj_idx, inj_mag, rng, dims, bq=bq, bkv=bkv,
        causal=causal, ft=ft, interpret=interpret, protect_qk=protect_qk,
        scale=scale, n_rep=n_rep)


@traced("kernel/flashft/dkv")
@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal", "ft",
                                             "interpret", "protect_qk",
                                             "scale", "n_rep"))
def flash_ft_dkv(q: jax.Array, k: jax.Array, v: jax.Array, g: jax.Array,
                 m: jax.Array, l: jax.Array, di: jax.Array,
                 inj_idx: jax.Array, inj_mag: jax.Array, dims: jax.Array,
                 rng: Optional[jax.Array] = None, *,
                 bq: int = 128, bkv: int = 128, causal: bool = True,
                 ft: FTConfig, interpret: bool = False,
                 protect_qk: bool = True, scale: float = None,
                 n_rep: int = 1):
    """The dK/dV half of the dedicated flash backward: ONE kv-stationary
    Pallas launch whose reduction walk covers the n_rep GQA query heads ×
    q blocks of each KV head (same K/V index maps as the forward — nothing
    repeat-materialized). Returns (dk, dv, rep) per KV head."""
    bh, sq, dh = q.shape
    assert bh == k.shape[0] * n_rep, (q.shape, k.shape, n_rep)
    assert sq % bq == 0 and k.shape[1] % bkv == 0, (q.shape, k.shape, bq,
                                                    bkv)
    if rng is None:
        rng = jnp.zeros((3,), jnp.int32)
    scale = scale if scale is not None else dh ** -0.5
    return tregistry.flash_dkv_call(
        q, k, v, g, m, l, di, inj_idx, inj_mag, rng, dims, bq=bq, bkv=bkv,
        causal=causal, ft=ft, interpret=interpret, protect_qk=protect_qk,
        scale=scale, n_rep=n_rep)


# ---------------------------------------------------------------------------
# injection encoders
# ---------------------------------------------------------------------------

def encode_injection(spec: Optional[InjectionSpec], bh: int = 0,
                     q_block: int = 0):
    if spec is None:
        return (jnp.zeros((6,), jnp.int32), jnp.zeros((1,), jnp.float32))
    idx = jnp.array([1, bh, q_block, spec.k_step, spec.row, spec.col],
                    jnp.int32)
    return idx, jnp.array([spec.magnitude], jnp.float32)


def encode_bwd_injection(spec: Optional[InjectionSpec], target: str = "dq",
                         bh: int = 0, blk: int = 0):
    """Deterministic SEU vectors for the backward kernels. ``target`` names
    the backward GEMM the SEU lands in — "dp_q"/"dq" (dq kernel; ``blk`` is
    the q-block, ``spec.k_step`` the kv step) or "dp_kv"/"dv"/"dk" (dkv
    kernel; ``blk`` is the kv block, ``spec.k_step`` the q step; ``bh`` is
    always the QUERY head). Returns (inj_dq int32[7], inj_dkv int32[7],
    mag f32[1]) with only the targeted kernel's vector enabled."""
    zero = jnp.zeros((7,), jnp.int32)
    if spec is None:
        return zero, zero, jnp.zeros((1,), jnp.float32)
    if target not in BWD_TARGETS:
        raise ValueError(f"unknown backward injection target {target!r}; "
                         f"one of {tuple(BWD_TARGETS)}")
    vec = jnp.array([1, BWD_TARGETS[target], bh, blk, spec.k_step,
                     spec.row, spec.col], jnp.int32)
    mag = jnp.array([spec.magnitude], jnp.float32)
    if target in _DQ_KERNEL_TARGETS:
        return vec, zero, mag
    return zero, vec, mag


def encode_rng(key: Optional[jax.Array], ft: FTConfig) -> jax.Array:
    """int32[3] = [enable, seed0, seed1] for the in-kernel stochastic SEU
    hook — seeds derived from the campaign key; disabled (zeros) when no
    key is supplied or the policy's inject_rate is 0."""
    if key is None or ft.inject_rate <= 0.0:
        return jnp.zeros((3,), jnp.int32)
    seeds = jax.random.randint(key, (2,), 0, jnp.iinfo(jnp.int32).max,
                               dtype=jnp.int32)
    return jnp.concatenate([jnp.ones((1,), jnp.int32), seeds])
