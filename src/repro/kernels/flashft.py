"""Flash attention with fused online ABFT — the beyond-paper kernel.

The paper's core insight is that ABFT only becomes ~free when its memory
operations are fused into a kernel that already holds the data in fast
memory. We apply that insight to the other GEMM-dominated hot spot of every
assigned architecture: attention.

Forward flash attention (online softmax over kv blocks; scores never touch
HBM) where BOTH in-kernel GEMMs are ABFT-protected per kv-step:

  * scores S = Q_blk·K_blkᵀ — verified against (eᵀQ)·Kᵀ and Q·(Kᵀe)
    *before* masking/softmax (the check is linear; the nonlinearity comes
    after);
  * delta  Δ = P·V_blk     — verified against (eᵀP)·V and P·(Ve); a located
    SEU is corrected branchlessly before Δ is rescaled into the
    accumulator.

One SEU per (q-block × kv-step) interval is detected AND corrected —
matching the paper's SEU model at the same granularity as its threadblock
k-loop. The HBM traffic is exactly flash attention's (Q, K, V, O — no S×S
materialization), so the memory-roofline term for attention drops from
O(S²)-scaled to O(S)-scaled; checksum traffic is VMEM-only.

Ragged sequence lengths take the masked dispatch of the GEMM kernels: the
true (Sq, Skv) ride in via scalar prefetch, kv blocks wholly past the true
Skv are skipped, and padded KV positions are masked to -inf after the
(linear) score verification and before softmax — so the ops wrapper fits
the seq blocks to the ragged lengths instead of padding to full class
tiles, and non-causal ragged Skv is exact.

Validated in interpret mode against ref.flash_ft_ref (tests/test_flashft.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams as _CompilerParams

from repro.core.policy import FTConfig, InjectionSpec

F32EPS = float(jnp.finfo(jnp.float32).eps)
NEG_INF = -1e30
REPORT_WIDTH = 8


def _iota2(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _verify_correct(mat, d_col, d_row, tau, corrects):
    """Branchless locate+correct of one SEU in `mat` from residuals."""
    bm, bn = mat.shape
    dc = d_col[0, :]
    dr = d_row[:, 0]
    col = jnp.argmax(jnp.abs(dc)).astype(jnp.int32)
    row = jnp.argmax(jnp.abs(dr)).astype(jnp.int32)
    detected = jnp.maximum(jnp.max(jnp.abs(dc)), jnp.max(jnp.abs(dr))) > tau
    mag = jnp.where(detected, jnp.sum(jnp.where(
        jax.lax.iota(jnp.int32, bn) == col, dc, 0.0)), 0.0)
    if corrects:
        hit = ((_iota2((bm, bn), 0) == row) & (_iota2((bm, bn), 1) == col)
               & detected)
        mat = mat - jnp.where(hit, mag, 0.0)
    return mat, detected, mag


def _flash_ft_kernel(inj_ref, mag_ref, dims_ref,
                     q_ref, k_ref, v_ref,
                     o_ref, rep_ref,
                     acc_ref, m_ref, l_ref,
                     *, kv_steps: int, bq: int, bkv: int, dh: int,
                     causal: bool, scale: float, corrects: bool,
                     rel_tau: float, protect_qk: bool):
    h = pl.program_id(0)
    qi = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        rep_ref[...] = jnp.zeros_like(rep_ref)

    q_start = qi * bq
    kv_start = s * bkv
    true_sq = dims_ref[0]
    true_skv = dims_ref[1]
    # Causal positions are bottom-right aligned on the TRUE lengths: query
    # row i attends kv j iff j ≤ i + (Skv − Sq) — the decode/cross-length
    # convention (Sq == Skv ⇒ the familiar triangular mask). The offset is
    # dynamic (scalar-prefetched), which is what lets ragged Sq ≠ Skv run
    # causally on fitted blocks instead of falling back to padded shapes.
    c_off = true_skv - true_sq
    # Ragged dispatch: kv blocks wholly past the true Skv are skipped
    # (scalar-prefetched seq lens, not padded shapes, drive the loop).
    run = kv_start < true_skv
    if causal:
        run = run & (kv_start <= q_start + bq - 1 + c_off)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                 # (bkv, dh)
        v = v_ref[0].astype(jnp.float32)

        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        det_qk = jnp.zeros((), bool)
        mag_qk = jnp.zeros(())
        if protect_qk:
            ck_col = jnp.dot(jnp.sum(q, 0, keepdims=True), k.T)   # (1,bkv)
            ck_row = jnp.dot(q, jnp.sum(k.T, 1, keepdims=True))   # (bq,1)
            d_col = jnp.sum(scores, 0, keepdims=True) - ck_col
            d_row = jnp.sum(scores, 1, keepdims=True) - ck_row
            tau_qk = jnp.maximum(
                rel_tau * F32EPS * dh
                * jnp.max(jnp.abs(q)) * jnp.max(jnp.abs(k)), 1e-30)
            scores, det_qk, mag_qk = _verify_correct(
                scores, d_col, d_row, tau_qk, corrects)
        scores = scores * scale

        # ---- emulated SEU on the scores accumulator ----------------------
        enable, g_h, g_qi, g_s, g_row, g_col = (
            inj_ref[0], inj_ref[1], inj_ref[2], inj_ref[3], inj_ref[4],
            inj_ref[5])
        hit = ((enable == 1) & (g_h == h) & (g_qi == qi) & (g_s == s))
        # injection lands in the Δ=PV accumulator below (paper §5.3 semantics)

        # Ragged edge masking: padded KV positions (past the true Skv) must
        # not receive attention — masked to -inf *after* the linear-GEMM
        # checksum verification above (zero-padded K rows are
        # checksum-neutral) and *before* softmax, exactly like the causal
        # mask. This is what lets the ops wrapper fit bq/bkv to the ragged
        # lengths instead of padding either dispatch to full class tiles.
        # The causal∧kv-edge conjunction uses the TRUE lengths: causal with
        # Sq ≠ Skv is bottom-right aligned via the dynamic offset above.
        kpos = kv_start + _iota2((bq, bkv), 1)
        scores = jnp.where(kpos < true_skv, scores, NEG_INF)
        if causal:
            qpos = q_start + _iota2((bq, bkv), 0)
            scores = jnp.where(qpos + c_off >= kpos, scores, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, 1, keepdims=True))
        p = jnp.exp(scores - m_new)                       # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)

        delta = jnp.dot(p, v, preferred_element_type=jnp.float32)  # (bq, dh)
        inj_mask = ((_iota2((bq, dh), 0) == g_row)
                    & (_iota2((bq, dh), 1) == g_col) & hit)
        delta = delta + jnp.where(inj_mask, mag_ref[0], 0.0)

        # ---- fused ABFT on the PV GEMM ------------------------------------
        ck_col = jnp.dot(jnp.sum(p, 0, keepdims=True), v)          # (1, dh)
        ck_row = jnp.dot(p, jnp.sum(v, 1, keepdims=True))          # (bq, 1)
        d_col = jnp.sum(delta, 0, keepdims=True) - ck_col
        d_row = jnp.sum(delta, 1, keepdims=True) - ck_row
        # Rounding-error accumulation stops at the true Skv: on a ragged
        # edge block only the live kv positions contribute to the p·V
        # reduction, so the threshold must not inflate to the full bkv
        # (same clamp as the masked GEMM template's k_elapsed).
        eff_kv = jnp.minimum(true_skv - kv_start, bkv).astype(jnp.float32)
        tau = jnp.maximum(rel_tau * F32EPS * eff_kv * jnp.max(jnp.abs(v)),
                          1e-30)
        delta, det_pv, mag_pv = _verify_correct(delta, d_col, d_row, tau,
                                                corrects)

        acc_ref[...] = acc_ref[...] * alpha + delta
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, 1, keepdims=True)
        m_ref[...] = m_new

        det = det_qk | det_pv
        detf = det.astype(jnp.float32)
        rep_ref[0, 0, 0] += detf
        rep_ref[0, 0, 1] += detf if corrects else 0.0
        rep_ref[0, 0, 4] = jnp.where(det_pv, mag_pv, rep_ref[0, 0, 4])
        rep_ref[0, 0, 5] = jnp.maximum(
            rep_ref[0, 0, 5],
            jnp.maximum(jnp.max(jnp.abs(d_col)), jnp.max(jnp.abs(d_row))))
        rep_ref[0, 0, 6] = tau

    @pl.when(s == kv_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal", "ft",
                                             "interpret", "protect_qk",
                                             "scale", "n_rep"))
def flash_ft_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       inj_idx: jax.Array, inj_mag: jax.Array,
                       dims: Optional[jax.Array] = None, *,
                       bq: int = 128, bkv: int = 128, causal: bool = True,
                       ft: FTConfig, interpret: bool = False,
                       protect_qk: bool = True, scale: float = None,
                       n_rep: int = 1):
    """q: (BH, Sq, dh); k, v: (BH/n_rep, Skv, dh); dh lane-aligned (pad to
    128 in the ops wrapper). ``n_rep`` is the GQA query-group width: query
    head h reads KV head h // n_rep straight through the K/V *index maps*,
    so grouped-query attention runs without repeat-materializing the KV
    operands (the chunked-jnp path's grouped-bdot trick, in-kernel).
    inj_idx int32[6] = [enable, bh, q_block, kv_step, row, col]; inj_mag
    f32[1]; dims int32[2] true (Sq, Skv) for the masked ragged path (None →
    the padded shapes are the true lengths). Returns
    (out (BH, Sq, dh), report)."""
    bh, sq, dh = q.shape
    bkvh, skv, _ = k.shape
    assert bh == bkvh * n_rep, (q.shape, k.shape, n_rep)
    assert sq % bq == 0 and skv % bkv == 0, (q.shape, k.shape, bq, bkv)
    grid = (bh, sq // bq, skv // bkv)
    if dims is None:
        dims = jnp.array([sq, skv], jnp.int32)
    # dh here may be the 128-padded width; callers pass the true-dh scale
    scale = scale if scale is not None else dh ** -0.5

    kernel = functools.partial(
        _flash_ft_kernel, kv_steps=grid[2], bq=bq, bkv=bkv, dh=dh,
        causal=causal, scale=scale, corrects=ft.corrects,
        rel_tau=ft.rel_tau, protect_qk=protect_qk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, s, *_: (b, i, 0)),
            pl.BlockSpec((1, bkv, dh),
                         lambda b, i, s, *_: (b // n_rep, s, 0)),
            pl.BlockSpec((1, bkv, dh),
                         lambda b, i, s, *_: (b // n_rep, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, s, *_: (b, i, 0)),
            pl.BlockSpec((1, 1, REPORT_WIDTH), lambda b, i, s, *_: (b, i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, sq // bq, REPORT_WIDTH), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(inj_idx, inj_mag, dims, q, k, v)


def encode_injection(spec: Optional[InjectionSpec], bh: int = 0,
                     q_block: int = 0):
    if spec is None:
        return (jnp.zeros((6,), jnp.int32), jnp.zeros((1,), jnp.float32))
    idx = jnp.array([1, bh, q_block, spec.k_step, spec.row, spec.col],
                    jnp.int32)
    return idx, jnp.array([spec.magnitude], jnp.float32)
