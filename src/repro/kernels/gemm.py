"""Baseline high-performance GEMM Pallas kernel (paper §3 analogue).

The paper builds SGEMM up through threadblock tiling (shared memory), thread
tiling (registers), warp tiling, vectorized access, and double-buffered
prefetching. On TPU the same ladder collapses into the Pallas/Mosaic model:

  * threadblock tile  → BlockSpec (bm, bn) output block in VMEM
  * k-loop            → third ("arbitrary") grid dimension; Mosaic
                        multiple-buffers the HBM→VMEM operand streams across
                        sequential grid steps — the double-buffered prefetch
                        of §3.1.7 is the *default* here, which is exactly the
                        hardware-adaptation point of DESIGN.md §2
  * thread/warp tile  → MXU 128×128 systolic sub-tiles; Mosaic owns register
                        allocation, we control it through tile alignment
  * vectorized access → (8,128)-aligned VREG-shaped tiles
  * accumulator       → f32 VMEM scratch that lives across the k grid steps

`gemm()` is the raw kernel entry (shape must be tile-divisible; ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams as _CompilerParams

from .autotune import KernelParams


def _gemm_kernel(a_ref, b_ref, out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("params", "interpret", "out_dtype"))
def gemm(a: jax.Array, b: jax.Array, *, params: KernelParams,
         interpret: bool = False, out_dtype=None) -> jax.Array:
    """C = A @ B for tile-divisible (M, K) × (K, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = params.bm, params.bn, params.bk
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, params)
    out_dtype = out_dtype or a.dtype
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(a, b)


def _gemm_masked_kernel(dims_ref,                    # scalar prefetch
                        a_ref, b_ref, out_ref, acc_ref,
                        *, k_steps: int, bm: int, bn: int, bk: int):
    """Ragged-shape GEMM: the true (m, n, k) arrive via scalar prefetch and
    the final partial row/col/k tiles are masked in-kernel, so callers pad
    only to the fitted tile grid (≈ hardware alignment) instead of to full
    class tiles — irregular shapes stop paying padding FLOPs. Masking both
    operands (not just one) also makes the kernel indifferent to *garbage*
    in the padded region (NaN/Inf-safe), which the conformance tests
    exploit."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    s = pl.program_id(2)
    tm, tn, tk = dims_ref[0], dims_ref[1], dims_ref[2]

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _iota(shape, d):
        return jax.lax.broadcasted_iota(jnp.int32, shape, d)

    a = a_ref[...]
    b = b_ref[...]
    a_ok = ((i * bm + _iota((bm, bk), 0) < tm)
            & (s * bk + _iota((bm, bk), 1) < tk))
    b_ok = ((s * bk + _iota((bk, bn), 0) < tk)
            & (j * bn + _iota((bk, bn), 1) < tn))
    a = jnp.where(a_ok, a, jnp.zeros_like(a))
    b = jnp.where(b_ok, b, jnp.zeros_like(b))
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(s == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("params", "interpret",
                                             "out_dtype"))
def gemm_masked(a: jax.Array, b: jax.Array, dims: jax.Array, *,
                params: KernelParams, interpret: bool = False,
                out_dtype=None) -> jax.Array:
    """C = A @ B where A/B are padded only to the fitted tile grid and
    `dims` = int32[3] true (m, n, k). Tile constraints are the hardware
    ones — bm multiple of the sublane count (8 for f32), bn/bk multiples of
    the 128-lane MXU edge — not the class-tile multiples `gemm` needs."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = params.bm, params.bn, params.bk
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape,
                                                        params)
    out_dtype = out_dtype or a.dtype
    grid = (m // bm, n // bn, k // bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s, *_: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s, *_: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s, *_: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_gemm_masked_kernel, k_steps=grid[2],
                          bm=bm, bn=bn, bk=bk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(dims, a, b)


def naive_gemm(a: jax.Array, b: jax.Array, *, interpret: bool = False,
               out_dtype=None) -> jax.Array:
    """§3.1.1 'naive' rung of the optimization ladder: one grid step per
    (128,128) output tile with the whole K row/col streamed in one block —
    no k-tiling, no accumulator reuse. Exists so the step-wise benchmark
    (Fig. 9 analogue) has a bottom rung."""
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype

    def kernel(a_ref, b_ref, out_ref):
        out_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                               preferred_element_type=jnp.float32
                               ).astype(out_ref.dtype)

    bm = min(m, 128)
    bn = min(n, 128)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(a, b)
