"""Baseline high-performance GEMM entry points (paper §3 analogue).

The paper builds SGEMM up through threadblock tiling (shared memory), thread
tiling (registers), warp tiling, vectorized access, and double-buffered
prefetching. On TPU the same ladder collapses into the Pallas/Mosaic model:

  * threadblock tile  → BlockSpec (bm, bn) output block in VMEM
  * k-loop            → third ("arbitrary") grid dimension; Mosaic
                        multiple-buffers the HBM→VMEM operand streams across
                        sequential grid steps — the double-buffered prefetch
                        of §3.1.7 is the *default* here, which is exactly the
                        hardware-adaptation point of DESIGN.md §2
  * thread/warp tile  → MXU 128×128 systolic sub-tiles; Mosaic owns register
                        allocation, we control it through tile alignment
  * vectorized access → (8,128)-aligned VREG-shaped tiles
  * accumulator       → f32 VMEM scratch that lives across the k grid steps

Since PR 2 the kernel bodies are *generated*: `gemm()` and `gemm_masked()`
are registry lookups (`templates.registry.kernel_call`) on the plain and
masked non-FT `KernelSpec`s — the same single-source template that also
emits every FT and fused-epilogue variant. Only `naive_gemm` (the bottom
rung of the step-wise benchmark ladder) stays hand-written.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .autotune import KernelParams
from .templates import registry
from .templates.spec import KernelSpec

_PLAIN = KernelSpec(ft_level="off", masked=False)
_MASKED = KernelSpec(ft_level="off", masked=True)


def gemm(a: jax.Array, b: jax.Array, *, params: KernelParams,
         interpret: bool = False, out_dtype=None) -> jax.Array:
    """C = A @ B for tile-divisible (M, K) × (K, N)."""
    out, _ = registry.kernel_call(a, b, spec=_PLAIN, params=params,
                                  interpret=interpret, out_dtype=out_dtype)
    return out


def gemm_masked(a: jax.Array, b: jax.Array, dims: jax.Array, *,
                params: KernelParams, interpret: bool = False,
                out_dtype=None) -> jax.Array:
    """Ragged-shape GEMM: A/B are padded only to the fitted tile grid and
    `dims` = int32[3] true (m, n, k); the kernel masks the partial
    row/col/k edge tiles in-kernel (NaN/Inf-safe in the padded region).
    Tile constraints are the hardware ones — bm a multiple of the sublane
    count (8 for f32), bn/bk multiples of the 128-lane MXU edge — not the
    class-tile multiples `gemm` needs."""
    out, _ = registry.kernel_call(a, b, dims=dims, spec=_MASKED,
                                  params=params, interpret=interpret,
                                  out_dtype=out_dtype)
    return out


def naive_gemm(a: jax.Array, b: jax.Array, *, interpret: bool = False,
               out_dtype=None) -> jax.Array:
    """§3.1.1 'naive' rung of the optimization ladder: one grid step per
    (128,128) output tile with the whole K row/col streamed in one block —
    no k-tiling, no accumulator reuse. Exists so the step-wise benchmark
    (Fig. 9 analogue) has a bottom rung."""
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype

    def kernel(a_ref, b_ref, out_ref):
        out_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                               preferred_element_type=jnp.float32
                               ).astype(out_ref.dtype)

    bm = min(m, 128)
    bn = min(n, 128)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(a, b)
