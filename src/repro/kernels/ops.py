"""jit'd public wrappers around the Pallas kernels.

Handles: autotuned parameter selection (`autotune.best_params`, backed by
the candidate search + persistent tuning cache — the codegen front-end),
ragged-shape dispatch (tile-divisible shapes run the plain kernels; ragged
shapes run the masked kernels padded only to a fitted tile grid instead of
full class tiles — see `dispatch_info`), backend fallback (interpret=True
automatically off-TPU so the same call sites run on CPU in tests), and
report plumbing.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK
from . import autotune, ftgemm, gemm, search


def _should_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def dispatch_info(m: int, n: int, k: int,
                  params: Optional[autotune.KernelParams] = None, *,
                  in_bytes: int = 4, ft_level: str = "off") -> Dict:
    """Pure dispatch decision for a (M, N, K) GEMM.

    path="padded": the shape divides the class tiles — run the plain kernel
    (no padding at all in that case). path="masked": ragged shape — run the
    masked kernel on a *fitted* tile grid (`search.fit_tile` per dim:
    sublane-aligned bm, MXU-aligned bn/bk) carrying true dims via scalar
    prefetch.

    `padded_flop_ratio` is executed FLOPs over the hardware floor (the
    sublane/lane-aligned problem no TPU kernel can go below) — 1.0 means
    zero avoidable padding. The old full-padding path is reported alongside
    as `padded_path_ratio` for comparison (the codegen benchmark's metric).
    """
    p = params or autotune.best_params(m, n, k, in_bytes, ft_level=ft_level)
    sub = search.sublane(in_bytes)
    align_m = autotune.MXU if ft_level == "tile" else sub
    q = autotune.KernelParams(
        bm=search.fit_tile(m, p.bm, align_m),
        bn=search.fit_tile(n, p.bn, autotune.MXU),
        bk=search.fit_tile(k, p.bk, autotune.MXU),
        shape_class=p.shape_class)
    mp, np_, kp = autotune.padded_shape(m, n, k, p)
    me, ne, ke = search.executed_dims(m, n, k, q)
    hw = (autotune._round_up(m, align_m) * autotune._round_up(n, autotune.MXU)
          * autotune._round_up(k, autotune.MXU))
    divisible = (m % p.bm == 0 and n % p.bn == 0 and k % p.bk == 0)
    path = "padded" if divisible else "masked"
    executed = mp * np_ * kp if divisible else me * ne * ke
    return {
        "path": path,
        "params": p,
        "masked_params": q,
        "executed_shape": (mp, np_, kp) if divisible else (me, ne, ke),
        "executed_flops": 2.0 * executed,
        "hw_aligned_flops": 2.0 * hw,
        "padded_flop_ratio": executed / hw,
        "padded_path_ratio": (mp * np_ * kp) / hw,
    }


def matmul(a: jax.Array, b: jax.Array, *,
           params: Optional[autotune.KernelParams] = None,
           interpret: Optional[bool] = None,
           out_dtype=None) -> jax.Array:
    """High-performance non-FT GEMM (paper §3): C = A @ B, any (M, K, N).
    Tile-divisible shapes run the plain kernel; ragged shapes dispatch to
    the masked kernel on a fitted grid (no full-padding fallback)."""
    m, k = a.shape
    _, n = b.shape
    p = params or autotune.best_params(m, n, k, a.dtype.itemsize)
    info = dispatch_info(m, n, k, p, in_bytes=a.dtype.itemsize)
    if info["path"] == "masked":
        q = info["masked_params"]
        me, ne, ke = info["executed_shape"]
        out = gemm.gemm_masked(_pad2(a, me, ke), _pad2(b, ke, ne),
                               jnp.array([m, n, k], jnp.int32), params=q,
                               interpret=_should_interpret(interpret),
                               out_dtype=out_dtype)
        return out[:m, :n]
    out = gemm.gemm(a, b, params=p,
                    interpret=_should_interpret(interpret),
                    out_dtype=out_dtype)
    return out


def ft_matmul(a: jax.Array, b: jax.Array, *,
              ft: FTConfig = ONLINE_BLOCK,
              spec: Optional[InjectionSpec] = None,
              params: Optional[autotune.KernelParams] = None,
              interpret: Optional[bool] = None,
              out_dtype=None) -> jax.Array:
    """Fused fault-tolerant GEMM (paper §4). Returns the corrected C."""
    out, _ = ft_matmul_report(a, b, ft=ft, spec=spec, params=params,
                              interpret=interpret, out_dtype=out_dtype)
    return out


def flash_ft(q: jax.Array, k: jax.Array, v: jax.Array, *,
             ft: FTConfig = ONLINE_BLOCK, causal: bool = True,
             spec: Optional[InjectionSpec] = None,
             inj_bh: int = 0, inj_q_block: int = 0,
             bq: int = 128, bkv: int = 128,
             interpret: Optional[bool] = None,
             protect_qk: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Flash attention with fused in-kernel ABFT (see kernels/flashft.py).
    q: (BH, Sq, dh); k, v: (BH, Skv, dh). Pads dh to the 128-lane MXU edge
    and seq dims to block multiples (zero pads are ABFT- and softmax-neutral
    for K/V because masked; Q pads are sliced off). Returns (out, report)."""
    from . import flashft
    bh, sq, dh = q.shape
    skv = k.shape[1]
    dh_p = ((dh + 127) // 128) * 128
    bq = min(bq, ((sq + 127) // 128) * 128)
    bkv = min(bkv, ((skv + 127) // 128) * 128)
    sq_p = ((sq + bq - 1) // bq) * bq
    skv_p = ((skv + bkv - 1) // bkv) * bkv

    def pad3(x, s_to, d_to):
        return jnp.pad(x, ((0, 0), (0, s_to - x.shape[1]),
                           (0, d_to - x.shape[2])))

    qp, kp, vp = pad3(q, sq_p, dh_p), pad3(k, skv_p, dh_p), pad3(v, skv_p,
                                                                 dh_p)
    # padded KV rows must not receive attention: causal masking covers Q
    # pads; for KV pads beyond skv add -inf via a huge negative K? — zero K
    # gives score 0 which *would* leak for non-causal; guard by masking in
    # the kernel only through causal. For non-causal callers we require
    # skv % bkv == 0 (asserted).
    if not causal:
        assert skv == skv_p, "non-causal flash_ft needs block-aligned Skv"
    inj_idx, inj_mag = flashft.encode_injection(spec, inj_bh, inj_q_block)
    out, rep = flashft.flash_ft_attention(
        qp, kp, vp, inj_idx, inj_mag, bq=bq, bkv=bkv, causal=causal, ft=ft,
        interpret=_should_interpret(interpret), protect_qk=protect_qk,
        scale=dh ** -0.5)
    return out[:, :sq, :dh], rep


def ft_matmul_report(a: jax.Array, b: jax.Array, *,
                     ft: FTConfig = ONLINE_BLOCK,
                     spec: Optional[InjectionSpec] = None,
                     params: Optional[autotune.KernelParams] = None,
                     interpret: Optional[bool] = None,
                     out_dtype=None) -> Tuple[jax.Array, jax.Array]:
    """FT-GEMM returning (C, report[gm, gn, 8]) — see ftgemm.REPORT_WIDTH.
    Ragged shapes dispatch to the masked kernel; the checksum math is
    masked identically, so ABFT detection/correction works on the ragged
    edge tiles."""
    m, k = a.shape
    _, n = b.shape
    p = params or autotune.best_params(m, n, k, a.dtype.itemsize,
                                       ft_level=ft.level)
    inj_idx, inj_mag = ftgemm.encode_injection(spec)
    info = dispatch_info(m, n, k, p, in_bytes=a.dtype.itemsize,
                         ft_level=ft.level)
    if info["path"] == "masked":
        q = info["masked_params"]
        me, ne, ke = info["executed_shape"]
        out, rep = ftgemm.ft_gemm(
            _pad2(a, me, ke), _pad2(b, ke, ne), inj_idx, inj_mag,
            params=q, ft=ft, interpret=_should_interpret(interpret),
            out_dtype=out_dtype, dims=jnp.array([m, n, k], jnp.int32))
        return out[:m, :n], rep
    out, rep = ftgemm.ft_gemm(
        a, b, inj_idx, inj_mag,
        params=p, ft=ft, interpret=_should_interpret(interpret),
        out_dtype=out_dtype)
    return out, rep
