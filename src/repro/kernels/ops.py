"""jit'd public wrappers around the Pallas kernels.

`gemm_call` is the front door of the template subsystem: it resolves a
`templates.KernelSpec` (FT level × epilogue chain × dtypes) against the
concrete problem — variant-aware autotuned parameters (`autotune.best_params`,
backed by the candidate search + persistent tuning cache), ragged-shape
dispatch (tile-divisible shapes run the plain variant; ragged shapes run the
masked variant padded only to a fitted tile grid instead of full class tiles
— see `dispatch_info`), backend fallback (interpret=True automatically
off-TPU so the same call sites run on CPU in tests), operand padding for the
fused epilogue aux inputs, and report plumbing. `matmul`, `ft_matmul*` and
`fused_matmul` are thin specializations of it.

Element widths are always derived from the *actual operand dtype*
(`a.dtype.itemsize`) — never assumed 4 — so bf16/fp16 problems get the
correct sublane alignment, fitted tiles, and VMEM budgets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK, FT_OFF
from repro.tools.trace import traced
from . import autotune, ftgemm, gemm, search
from .templates import BatchedKernelSpec, KernelSpec, registry
from .templates import spec as spec_mod


def _should_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad the trailing two dims to (rows, cols) — any leading batch
    dims pass through (shared by the 2-D and batched/grouped dispatchers;
    zero padding is ABFT-neutral)."""
    pr, pc = rows - x.shape[-2], cols - x.shape[-1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)])


def dispatch_info(m: int, n: int, k: int,
                  params: Optional[autotune.KernelParams] = None, *,
                  in_bytes: Optional[int] = None, dtype=None,
                  ft_level: str = "off",
                  spec: Optional[KernelSpec] = None) -> Dict:
    """Pure dispatch decision for a (M, N, K) GEMM.

    Element width comes from `dtype` (preferred) or `in_bytes`; pass the
    actual operand dtype — bf16/fp16 problems have a different sublane floor
    (16/32 rows) and VMEM budget than f32, so a defaulted width would fit
    wrong tiles. (Falls back to 4 bytes with neither given, for
    structural-only queries.)

    path="padded": the shape divides the class tiles — run the plain kernel
    (no padding at all in that case). path="masked": ragged shape — run the
    masked kernel on a *fitted* tile grid (`search.fit_tile` per dim:
    sublane-aligned bm, MXU-aligned bn/bk) carrying true dims via scalar
    prefetch.

    `padded_flop_ratio` is executed FLOPs over the hardware floor (the
    sublane/lane-aligned problem no TPU kernel can go below) — 1.0 means
    zero avoidable padding. The old full-padding path is reported alongside
    as `padded_path_ratio` for comparison (the codegen benchmark's metric).
    """
    if in_bytes is None:
        in_bytes = jnp.dtype(dtype).itemsize if dtype is not None else 4
    p = params or autotune.best_params(m, n, k, in_bytes, ft_level=ft_level,
                                       spec=spec)
    sub = search.sublane(in_bytes)
    align_m = autotune.MXU if ft_level == "tile" else sub
    q = autotune.KernelParams(
        bm=search.fit_tile(m, p.bm, align_m),
        bn=search.fit_tile(n, p.bn, autotune.MXU),
        bk=search.fit_tile(k, p.bk, autotune.MXU),
        shape_class=p.shape_class)
    mp, np_, kp = autotune.padded_shape(m, n, k, p)
    me, ne, ke = search.executed_dims(m, n, k, q)
    hw = (autotune._round_up(m, align_m) * autotune._round_up(n, autotune.MXU)
          * autotune._round_up(k, autotune.MXU))
    divisible = (m % p.bm == 0 and n % p.bn == 0 and k % p.bk == 0)
    path = "padded" if divisible else "masked"
    executed = mp * np_ * kp if divisible else me * ne * ke
    return {
        "path": path,
        "params": p,
        "masked_params": q,
        "executed_shape": (mp, np_, kp) if divisible else (me, ne, ke),
        "executed_flops": 2.0 * executed,
        "hw_aligned_flops": 2.0 * hw,
        "padded_flop_ratio": executed / hw,
        "padded_path_ratio": (mp * np_ * kp) / hw,
    }


@traced("kernel/gemm")
def gemm_call(spec: KernelSpec, a: jax.Array, b: jax.Array, *,
              bias: Optional[jax.Array] = None,
              residual: Optional[jax.Array] = None,
              ft: Optional[FTConfig] = None,
              inject: Optional[InjectionSpec] = None,
              params: Optional[autotune.KernelParams] = None,
              interpret: Optional[bool] = None,
              out_dtype=None,
              key: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """The template subsystem's front door: run any registered kernel
    variant on an arbitrary (M, K) × (K, N) problem.

    spec      — the variant: FT level, epilogue chain, dtypes. `spec.masked`
                is advisory; the dispatcher re-resolves it from the shape
                (tile-divisible → plain, ragged → masked fitted grid).
    bias      — (N,) or (1, N) vector when the chain contains "bias".
    residual  — (M, N) array when the chain contains "residual".
    ft        — FTConfig for FT specs (verify schedule, correction, τ);
                defaults to online-correcting at `spec.ft_level`.
    inject    — optional deterministic SEU (tests/benchmarks).
    key       — PRNG key for the in-kernel stochastic SEU hook; armed only
                when ``ft.inject_rate > 0`` (see `flashft.encode_rng`).

    Returns (C, report) — report is None for non-FT specs, else the
    per-block [detected, corrected, row, col, magnitude, max_residual, τ,
    k_elapsed] array of `ftgemm`. Multi-output specs (``spec.extra_outputs``)
    return ((C, extra…), report) with every output sliced back to (M, N).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    in_bytes = a.dtype.itemsize
    ft_level = spec.ft_level
    if ft is None:
        ft = FTConfig(level=ft_level) if spec.ft else FT_OFF
    if spec.ft != ft.enabled or (spec.ft and ft.level != ft_level):
        raise ValueError(f"FTConfig(level={ft.level!r}, action={ft.action!r})"
                         f" disagrees with spec.ft_level={ft_level!r}")

    p = params or autotune.best_params(m, n, k, in_bytes, ft_level=ft_level,
                                       spec=spec)
    info = dispatch_info(m, n, k, p, in_bytes=in_bytes, ft_level=ft_level,
                         spec=spec)
    masked = info["path"] == "masked"
    rspec = dataclasses.replace(spec, masked=masked)
    rp = info["masked_params"] if masked else p
    me, ne, ke = info["executed_shape"]

    if bias is not None:
        bias = bias.reshape(1, -1)
        assert bias.shape[1] == n, (bias.shape, n)
        bias = _pad2(bias, 1, ne)       # zero pads keep the checksum fold exact
    if residual is not None:
        assert residual.shape == (m, n), (residual.shape, (m, n))
        residual = _pad2(residual, me, ne)

    inj_idx = inj_mag = rng = dims = None
    if rspec.ft:
        from . import flashft
        inj_idx, inj_mag = ftgemm.encode_injection(inject)
        rng = flashft.encode_rng(key, ft)
    if masked:
        dims = jnp.array([m, n, k], jnp.int32)
        a = _pad2(a, me, ke)
        b = _pad2(b, ke, ne)

    out, rep = registry.kernel_call(
        a, b, bias=bias, residual=residual, inj_idx=inj_idx,
        inj_mag=inj_mag, rng=rng, dims=dims, spec=rspec, params=rp, ft=ft,
        interpret=_should_interpret(interpret), out_dtype=out_dtype)
    if masked:
        out = (tuple(o[:m, :n] for o in out) if spec.extra_outputs
               else out[:m, :n])
    return out, rep


def matmul(a: jax.Array, b: jax.Array, *,
           params: Optional[autotune.KernelParams] = None,
           interpret: Optional[bool] = None,
           out_dtype=None) -> jax.Array:
    """High-performance non-FT GEMM (paper §3): C = A @ B, any (M, K, N).
    Tile-divisible shapes run the plain kernel; ragged shapes dispatch to
    the masked kernel on a fitted grid (no full-padding fallback)."""
    out, _ = gemm_call(KernelSpec(), a, b, params=params,
                       interpret=interpret, out_dtype=out_dtype)
    return out


@traced("kernel/fused_matmul")
def fused_matmul(a: jax.Array, b: jax.Array, *,
                 bias: Optional[jax.Array] = None,
                 act: Optional[str] = None,
                 residual: Optional[jax.Array] = None,
                 ft: FTConfig = FT_OFF,
                 inject: Optional[InjectionSpec] = None,
                 params: Optional[autotune.KernelParams] = None,
                 interpret: Optional[bool] = None,
                 out_dtype=None,
                 save_act_grad: bool = False,
                 key: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Canonical fused-epilogue GEMM: C = act(A·B + bias) + residual in one
    kernel — the matmul→bias→activation sequence without the second HBM
    round-trip. With an enabled `ft`, the linear epilogue prefix is folded
    into the checksum comparison so online ABFT verifies (and corrects)
    post-epilogue. Returns (C, report|None).

    ``save_act_grad=True`` (requires ``act``) runs the multi-output variant:
    the kernel additionally writes act'(A·B + bias) — evaluated on the
    verified/corrected accumulator — and the return becomes
    ((C, act_grad), report|None). This is the saved residual
    `core.ft_dot_fused`'s backward consumes instead of recomputing the
    pre-activation GEMM."""
    spec = spec_mod.fused(bias=bias is not None, act=act,
                          residual=residual is not None,
                          ft_level=ft.level if ft.enabled else "off")
    if save_act_grad:
        spec = dataclasses.replace(spec, extra_outputs=("act_grad",))
    return gemm_call(spec, a, b, bias=bias, residual=residual, ft=ft,
                     inject=inject, params=params, interpret=interpret,
                     out_dtype=out_dtype, key=key)


@traced("kernel/grouped_gemm")
def grouped_gemm_call(spec: KernelSpec, a: jax.Array, b: jax.Array, *,
                      group_ids: Optional[jax.Array] = None,
                      n_groups: Optional[int] = None,
                      ft: Optional[FTConfig] = None,
                      inject: Optional[InjectionSpec] = None,
                      inj_batch: int = 0,
                      params: Optional[autotune.KernelParams] = None,
                      interpret: Optional[bool] = None,
                      out_dtype=None,
                      key: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """The batched/grouped front door (PR 3) — `gemm_call`'s sibling for the
    leading-batch-axis variant space, dispatching on operand ranks:

      * a (B, M, K), b (B, K, N) or (K, N): uniform batched GEMM — ONE
        Pallas launch with a leading batch grid axis (this is what
        `core.ft_batched_dot`'s pallas backend emits for attention QK/PV
        and per-expert matmuls). Ragged (m, n, k) shared by the batch takes
        the masked fitted-tile path.
      * a (T, K), b (G, K, N) with ``group_ids`` int32 (T,): ragged grouped
        GEMM — y[t] = a[t] @ b[group_ids[t]] over a group-sorted buffer
        with zero capacity padding; detection/correction run per group
        (`core.ft_grouped_matmul` / `models.moe` route here).
      * a (T, K), b (T, N) with ``group_ids`` int32 (T,) and ``n_groups``:
        the grouped *transpose* GEMM ("tgmm", PR 4) —
        dw[g] = Σ_{t: group_ids[t]=g} a[t] ⊗ b[t], i.e. the (G, K, N)
        per-group outer-product sum of the MoE backward dw, run as ONE
        output-stationary Pallas kernel with per-group checksums
        (`core.ft_grouped_matmul`'s backward routes here).

    `spec` may be a plain `KernelSpec` (promoted to `BatchedKernelSpec`) or
    a `BatchedKernelSpec`; masked/shared_b/grouped/tgmm are re-resolved
    from the operands. Returns (C, report|None)."""
    from . import grouped as grouped_mod

    bspec = BatchedKernelSpec(
        ft_level=spec.ft_level, epilogue=spec.epilogue,
        acc_dtype=spec.acc_dtype, out_dtype=spec.out_dtype)
    if a.ndim == 3:
        assert group_ids is None, "uniform batched GEMM takes no group_ids"
        return grouped_mod.batched_gemm_call(
            bspec, a, b, ft=ft, inject=inject, inj_batch=inj_batch,
            params=params, interpret=interpret, out_dtype=out_dtype,
            key=key)
    assert a.ndim == 2 and group_ids is not None, (a.shape, group_ids)
    if b.ndim == 2:                      # tgmm: two row-aligned buffers
        assert n_groups is not None, "tgmm dispatch needs n_groups"
        return grouped_mod.tgmm_matmul_rows(
            dataclasses.replace(bspec, epilogue=(), tgmm=True), a, b,
            group_ids, n_groups=n_groups, ft=ft, inject=inject,
            params=params, interpret=interpret, out_dtype=out_dtype,
            key=key)
    assert b.ndim == 3, (a.shape, b.shape)
    return grouped_mod.grouped_matmul_rows(
        dataclasses.replace(bspec, grouped=True), a, b, group_ids, ft=ft,
        inject=inject, params=params, interpret=interpret,
        out_dtype=out_dtype, key=key)


def ft_matmul(a: jax.Array, b: jax.Array, *,
              ft: FTConfig = ONLINE_BLOCK,
              spec: Optional[InjectionSpec] = None,
              params: Optional[autotune.KernelParams] = None,
              interpret: Optional[bool] = None,
              out_dtype=None) -> jax.Array:
    """Fused fault-tolerant GEMM (paper §4). Returns the corrected C."""
    out, _ = ft_matmul_report(a, b, ft=ft, spec=spec, params=params,
                              interpret=interpret, out_dtype=out_dtype)
    return out


def ft_matmul_report(a: jax.Array, b: jax.Array, *,
                     ft: FTConfig = ONLINE_BLOCK,
                     spec: Optional[InjectionSpec] = None,
                     params: Optional[autotune.KernelParams] = None,
                     interpret: Optional[bool] = None,
                     out_dtype=None,
                     key: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """FT-GEMM returning (C, report[gm, gn, 8]) — see ftgemm.REPORT_WIDTH.
    Ragged shapes dispatch to the masked kernel; the checksum math is
    masked identically, so ABFT detection/correction works on the ragged
    edge tiles."""
    return gemm_call(KernelSpec(ft_level=ft.level), a, b, ft=ft,
                     inject=spec, params=params, interpret=interpret,
                     out_dtype=out_dtype, key=key)


def _flash_spec(ft: FTConfig, direction: str, dh_p: int,
                save_stats: bool = False):
    from .templates.spec import FlashKernelSpec
    return FlashKernelSpec(ft_level=ft.level if ft.enabled else "off",
                           direction=direction, dh=dh_p,
                           save_stats=save_stats)


def _flash_fit(dim: int, cap: int, align: int) -> int:
    """Fitted flash block edge: ≤ cap (the autotuned/user tile), ≤ the
    128-padded dim (never over-tile), aligned to `align`."""
    cap = max(min(cap, ((dim + 127) // 128) * 128), align)
    return search.fit_tile(dim, cap, align)


def _pad3(x, s_to, d_to, value=0.0):
    return jnp.pad(x, ((0, 0), (0, s_to - x.shape[1]),
                       (0, d_to - x.shape[2])), constant_values=value)


def _check_flash_injection(kernel: str, *, head: int, n_heads: int,
                           blk: int, n_blks: int, step: int, n_steps: int,
                           q_span, kv_span, sq: int, skv: int,
                           causal: bool) -> None:
    """A deterministic flash InjectionSpec addresses a concrete grid cell;
    with autotuned (bq, bkv) the grid shape is no longer fixed, so a stale
    (block, step) target could fall outside the grid — or on a cell the
    causal/ragged dispatch skips — and the SEU would silently never land.
    That is exactly the silently-clean-campaign failure mode this kernel
    family exists to prevent, so fail loudly instead. ``q_span``/``kv_span``
    are the (start, stop) row/col ranges of the targeted cell."""
    ok = (0 <= head < n_heads and 0 <= blk < n_blks
          and 0 <= step < n_steps)
    if ok:
        (q0, q1), (kv0, _) = q_span, kv_span
        ok = q0 < sq and kv0 < skv and (
            not causal or kv0 <= q1 - 1 + (skv - sq))
    if not ok:
        raise ValueError(
            f"{kernel}: deterministic injection targets head {head} of "
            f"{n_heads}, block {blk} of {n_blks}, step {step} of {n_steps} "
            f"— a cell the fitted grid never executes (autotuned/fitted "
            f"tiles, ragged true lengths, or causal skipping). The SEU "
            f"would silently never land; pin bq/bkv or fix the injection "
            f"target.")


@traced("kernel/flash_ft")
def flash_ft(q: jax.Array, k: jax.Array, v: jax.Array, *,
             ft: FTConfig = ONLINE_BLOCK, causal: bool = True,
             spec: Optional[InjectionSpec] = None,
             inj_bh: int = 0, inj_q_block: int = 0,
             bq: Optional[int] = None, bkv: Optional[int] = None,
             interpret: Optional[bool] = None,
             protect_qk: bool = True,
             n_rep: int = 1, save_stats: bool = False,
             key: Optional[jax.Array] = None):
    """Flash attention with fused in-kernel ABFT (see kernels/flashft.py).
    q: (BH, Sq, dh); k, v: (BH/n_rep, Skv, dh) — ``n_rep`` is the GQA
    query-group width (query head h reads KV head h//n_rep via the K/V
    index maps; KV is never repeat-materialized). Pads dh to the 128-lane
    MXU edge; the sequence dims take the masked ragged path: true (Sq, Skv)
    ride in via scalar prefetch, blocks are *fitted* to the ragged lengths
    (sublane-aligned bq, lane-aligned bkv — no padding to full class
    tiles), and padded KV positions are masked to -inf in-kernel. Ragged
    Skv is exact for non-causal AND causal dispatch: the in-kernel
    causal∧kv-edge mask is bottom-right aligned on the true lengths
    (query i attends kv j iff j ≤ i + Skv − Sq), so causal cross-length
    attention (Skv ≥ Sq, the decode convention) no longer needs padded
    shapes.

    ``bq``/``bkv`` default to the autotuned tiles (`autotune.best_params`
    under the ``/v_flashfwd*`` variant key); pass explicit values to pin
    the grid (tests that address report blocks do). ``key`` drives the
    in-kernel stochastic SEU hook when ``ft.inject_rate > 0`` — one
    Bernoulli(rate) SEU per (head, q-block) lands in the PV accumulator at
    a hash-drawn (step, row, col), so fault campaigns exercise the kernel
    itself. ``save_stats`` additionally returns the per-row softmax
    statistics for the dedicated backward.

    Returns (out, report) — or (out, m, l, report) with ``save_stats``
    (m, l are (BH, Sq) f32; degenerate rows hold (−∞, 0))."""
    from . import flashft
    bh, sq, dh = q.shape
    skv = k.shape[1]
    assert bh == k.shape[0] * n_rep, (q.shape, k.shape, n_rep)
    assert not causal or skv >= sq, (
        "causal flash_ft is bottom-right aligned: needs Skv >= Sq "
        f"(got Sq={sq}, Skv={skv})")
    in_bytes = q.dtype.itemsize
    sub = search.sublane(in_bytes)
    dh_p = ((dh + 127) // 128) * 128
    fspec = _flash_spec(ft, "fwd", dh_p, save_stats)
    if bq is None or bkv is None:
        p = autotune.best_params(sq, skv, dh_p, in_bytes,
                                 ft_level=fspec.ft_level, spec=fspec,
                                 batch=bh)
        bq = p.bm if bq is None else bq
        bkv = p.bn if bkv is None else bkv
    bq = _flash_fit(sq, bq, sub)
    bkv = _flash_fit(skv, bkv, autotune.MXU)
    sq_p = ((sq + bq - 1) // bq) * bq
    skv_p = ((skv + bkv - 1) // bkv) * bkv

    if spec is not None:
        _check_flash_injection(
            "flash_ft", head=inj_bh, n_heads=bh, blk=inj_q_block,
            n_blks=sq_p // bq, step=spec.k_step, n_steps=skv_p // bkv,
            q_span=(inj_q_block * bq, (inj_q_block + 1) * bq),
            kv_span=(spec.k_step * bkv, (spec.k_step + 1) * bkv),
            sq=sq, skv=skv, causal=causal)
    qp, kp, vp = (_pad3(q, sq_p, dh_p), _pad3(k, skv_p, dh_p),
                  _pad3(v, skv_p, dh_p))
    inj_idx, inj_mag = flashft.encode_injection(spec, inj_bh, inj_q_block)
    rng = flashft.encode_rng(key, ft)
    dims = jnp.array([sq, skv], jnp.int32)
    res = flashft.flash_ft_attention(
        qp, kp, vp, inj_idx, inj_mag, dims, rng, bq=bq, bkv=bkv,
        causal=causal, ft=ft, interpret=_should_interpret(interpret),
        protect_qk=protect_qk, scale=dh ** -0.5, n_rep=n_rep,
        save_stats=save_stats)
    if save_stats:
        out, m, l, rep = res
        return out[:, :sq, :dh], m[:, :sq, 0], l[:, :sq, 0], rep
    out, rep = res
    return out[:, :sq, :dh], rep


@traced("kernel/flash_decode")
def flash_ft_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    lengths: jax.Array, page_table: jax.Array, *,
                    ft: FTConfig = ONLINE_BLOCK,
                    spec: Optional[InjectionSpec] = None, inj_g: int = 0,
                    interpret: Optional[bool] = None,
                    protect_qk: bool = True,
                    key: Optional[jax.Array] = None):
    """Paged single-position flash decode with per-row ragged lengths
    (PR 9) — the serving engine's attention kernel.

    q: (B, H, dh) — one query position per serving slot; k_pages/v_pages:
    (n_pages, KVH, page, dh) — ONE layer of the shared page pool
    (`train.kv_cache`); lengths: int32[B] per-slot TRUE kv lengths (the
    ragged vector that replaces the forward's one (Sq, Skv) pair; 0 marks
    a dead slot, which returns exact zeros); page_table: int32[B,
    max_pages] physical page ids, scalar-prefetched into the kernel's K/V
    index maps so each (slot, head) grid row streams exactly its own
    pages out of the pool.

    dh must be lane-aligned (128-multiple) — the paged pool is laid out at
    kernel geometry, so there is no pad-and-slice here; callers with
    smaller head dims take the gather+dense oracle path
    (`models.blocks.paged_decode_attention`). The GQA query group of each
    kv head (n_rep = H // KVH rows) is the stationary block, zero-padded
    to the sublane edge (checksum-neutral; garbage rows sliced off).

    ``spec``/``inj_g`` land a deterministic SEU in grid row ``inj_g``
    (= slot·KVH + head) at kv step ``spec.k_step``; ``key`` drives the
    stochastic in-kernel hook (salt ``SALT_DECODE``). Returns
    (out (B, H, dh), report (B·KVH, 1, W))."""
    from . import flashft
    b, h, dh = q.shape
    n_pages, kvh, page, dh_k = k_pages.shape
    assert v_pages.shape == k_pages.shape, (k_pages.shape, v_pages.shape)
    assert dh == dh_k, (q.shape, k_pages.shape)
    assert h % kvh == 0, (h, kvh)
    if dh % 128 != 0:
        raise ValueError(f"flash_ft_decode needs a lane-aligned head dim "
                         f"(128-multiple), got {dh} — use the dense "
                         f"decode_attention oracle path")
    max_pages = page_table.shape[1]
    assert page_table.shape[0] == b and lengths.shape == (b,), \
        (page_table.shape, lengths.shape, b)
    n_rep = h // kvh
    in_bytes = q.dtype.itemsize
    sub = search.sublane(in_bytes)
    bq = -(-n_rep // sub) * sub
    # Keep the decode variant in the tuning pipeline: the lookup records /
    # reuses the ``/v_flashdecode`` cache entry whose streamed block chose
    # the page size (`kv_cache.plan_pages` consults the same spec), and
    # validates this geometry against the variant's VMEM model.
    fspec = _flash_spec(ft, "decode", dh)
    autotune.best_params(bq, max(max_pages * page, autotune.MXU), dh,
                         in_bytes, ft_level=fspec.ft_level, spec=fspec,
                         batch=b * kvh)

    if spec is not None:
        if not (0 <= inj_g < b * kvh and 0 <= spec.k_step < max_pages):
            raise ValueError(
                f"flash_ft_decode: deterministic injection targets grid "
                f"row {inj_g} of {b * kvh}, kv step {spec.k_step} of "
                f"{max_pages} — outside the decode grid, the SEU would "
                f"silently never land")
    inj_idx, inj_mag = flashft.encode_injection(spec, inj_g, 0)
    rng = flashft.encode_rng(key, ft)

    qg = q.reshape(b * kvh, n_rep, dh)
    if bq > n_rep:
        qg = jnp.pad(qg, ((0, 0), (0, bq - n_rep), (0, 0)))
    out, rep = flashft.flash_ft_decode_attention(
        qg, k_pages, v_pages, inj_idx, inj_mag,
        lengths.astype(jnp.int32), page_table.astype(jnp.int32), rng,
        kvh=kvh, ft=ft, interpret=_should_interpret(interpret),
        protect_qk=protect_qk, scale=dh ** -0.5)
    return out[:, :n_rep].reshape(b, h, dh), rep


@traced("kernel/flash_ft_bwd")
def flash_ft_bwd(q: jax.Array, k: jax.Array, v: jax.Array, o: jax.Array,
                 m: jax.Array, l: jax.Array, g: jax.Array, *,
                 ft: FTConfig = ONLINE_BLOCK, causal: bool = True,
                 n_rep: int = 1, key: Optional[jax.Array] = None,
                 inject: Optional[InjectionSpec] = None,
                 inj_target: str = "dq", inj_bh: int = 0, inj_blk: int = 0,
                 bq: Optional[int] = None, bkv: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 protect_qk: bool = True):
    """The dedicated flash-attention backward (PR 5): dQ/dK/dV as TWO
    Pallas launches over the forward-saved (m, l) statistics — zero
    chunked-oracle recompute, no S×S transient, and all four backward
    GEMMs (dP = g·Vᵀ, dV = Pᵀ·g, dQ = dS·K, dK = dSᵀ·Q) plus the in-kernel
    S recompute carry the same checksum-verify + branchless-correct ABFT
    as the forward.

    q, o, g: (BH, Sq, dh); k, v: (BH/n_rep, Skv, dh); m, l: (BH, Sq) f32
    from ``flash_ft(..., save_stats=True)``. di = rowsum(g ∘ o) is the one
    elementwise preprocess (no GEMM). Each direction autotunes its own
    (bq, bkv) under its ``/v_flashbwd_*`` variant key; GQA reuses the
    forward's K/V index maps, with the dkv kernel folding the n_rep query
    heads of a KV head into its reduction walk — dk/dv come back per KV
    head, never repeat-materialized.

    ``inject``/``inj_target`` land a deterministic SEU inside one named
    backward GEMM ("dp_q"|"dq"|"dp_kv"|"dv"|"dk" — see
    `flashft.encode_bwd_injection`); ``key`` drives the stochastic
    in-kernel hook like the forward. Returns
    (dq, dk, dv, report_dq, report_dkv)."""
    from . import flashft
    bh, sq, dh = q.shape
    bkvh, skv, _ = k.shape
    assert bh == bkvh * n_rep, (q.shape, k.shape, n_rep)
    assert o.shape == q.shape and g.shape == q.shape, (o.shape, g.shape)
    assert m.shape[:2] == (bh, sq) and l.shape[:2] == (bh, sq), \
        (m.shape, l.shape, (bh, sq))
    assert not causal or skv >= sq, (
        "causal flash_ft_bwd is bottom-right aligned: needs Skv >= Sq "
        f"(got Sq={sq}, Skv={skv})")
    in_bytes = q.dtype.itemsize
    sub = search.sublane(in_bytes)
    dh_p = ((dh + 127) // 128) * 128
    itp = _should_interpret(interpret)
    scale = dh ** -0.5
    neg_inf = flashft.NEG_INF

    # The one elementwise preprocess of the flash backward (no GEMM).
    di = jnp.sum(g.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    m3 = m.reshape(bh, sq, 1).astype(jnp.float32)
    l3 = l.reshape(bh, sq, 1).astype(jnp.float32)
    di3 = di.reshape(bh, sq, 1)

    inj_dq, inj_dkv, inj_mag = flashft.encode_bwd_injection(
        inject, inj_target, inj_bh, inj_blk)
    rng = flashft.encode_rng(key, ft)
    dims = jnp.array([sq, skv], jnp.int32)

    def fitted(direction, stat_dim, stream_dim, batch):
        fspec = _flash_spec(ft, direction, dh_p)
        if bq is not None and bkv is not None:
            return bq, bkv
        p = autotune.best_params(stat_dim, stream_dim, dh_p, in_bytes,
                                 ft_level=fspec.ft_level, spec=fspec,
                                 batch=batch)
        if direction == "dq":
            return (p.bm if bq is None else bq,
                    p.bn if bkv is None else bkv)
        return (p.bn if bq is None else bq,
                p.bm if bkv is None else bkv)

    def padded(bq_f, bkv_f):
        sq_p = ((sq + bq_f - 1) // bq_f) * bq_f
        skv_p = ((skv + bkv_f - 1) // bkv_f) * bkv_f
        # Padded query rows carry the degenerate-stat markers (m=−∞, l=0)
        # so both backward kernels see p ≡ 0 there — exact zeros, no
        # reliance on the cotangent being zero-padded.
        return (_pad3(q, sq_p, dh_p), _pad3(k, skv_p, dh_p),
                _pad3(v, skv_p, dh_p), _pad3(g, sq_p, dh_p),
                _pad3(m3, sq_p, 1, value=neg_inf), _pad3(l3, sq_p, 1),
                _pad3(di3, sq_p, 1))

    bq_q, bkv_q = fitted("dq", sq, skv, bh)
    bq_q = _flash_fit(sq, bq_q, sub)
    bkv_q = _flash_fit(skv, bkv_q, autotune.MXU)
    if inject is not None and inj_target in ("dp_q", "dq"):
        _check_flash_injection(
            f"flash_ft_bwd[{inj_target}]", head=inj_bh, n_heads=bh,
            blk=inj_blk, n_blks=-(-sq // bq_q), step=inject.k_step,
            n_steps=-(-skv // bkv_q),
            q_span=(inj_blk * bq_q, (inj_blk + 1) * bq_q),
            kv_span=(inject.k_step * bkv_q, (inject.k_step + 1) * bkv_q),
            sq=sq, skv=skv, causal=causal)
    dq, rep_dq = flashft.flash_ft_dq(
        *padded(bq_q, bkv_q), inj_dq, inj_mag, dims, rng, bq=bq_q,
        bkv=bkv_q, causal=causal, ft=ft, interpret=itp,
        protect_qk=protect_qk, scale=scale, n_rep=n_rep)

    bq_k, bkv_k = fitted("dkv", skv, sq, bkvh)
    bq_k = _flash_fit(sq, bq_k, sub)
    bkv_k = _flash_fit(skv, bkv_k, autotune.MXU)
    if inject is not None and inj_target in ("dp_kv", "dv", "dk"):
        _check_flash_injection(
            f"flash_ft_bwd[{inj_target}]", head=inj_bh, n_heads=bh,
            blk=inj_blk, n_blks=-(-skv // bkv_k), step=inject.k_step,
            n_steps=-(-sq // bq_k),
            q_span=(inject.k_step * bq_k, (inject.k_step + 1) * bq_k),
            kv_span=(inj_blk * bkv_k, (inj_blk + 1) * bkv_k),
            sq=sq, skv=skv, causal=causal)
    dk, dv, rep_dkv = flashft.flash_ft_dkv(
        *padded(bq_k, bkv_k), inj_dkv, inj_mag, dims, rng, bq=bq_k,
        bkv=bkv_k, causal=causal, ft=ft, interpret=itp,
        protect_qk=protect_qk, scale=scale, n_rep=n_rep)
    return (dq[:, :sq, :dh], dk[:, :skv, :dh], dv[:, :skv, :dh],
            rep_dq, rep_dkv)
