"""jit'd public wrappers around the Pallas kernels.

Handles: shape-class parameter selection (the codegen front-end), zero
padding to tile multiples (ABFT-neutral: checksums of zero rows/cols are
zero), backend fallback (interpret=True automatically off-TPU so the same
call sites run on CPU in tests), and report plumbing.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import FTConfig, InjectionSpec, ONLINE_BLOCK
from . import autotune, ftgemm, gemm


def _should_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def matmul(a: jax.Array, b: jax.Array, *,
           params: Optional[autotune.KernelParams] = None,
           interpret: Optional[bool] = None,
           out_dtype=None) -> jax.Array:
    """High-performance non-FT GEMM (paper §3): C = A @ B, any (M, K, N)."""
    m, k = a.shape
    _, n = b.shape
    p = params or autotune.build_params(m, n, k, in_bytes=a.dtype.itemsize)
    mp, np_, kp = autotune.padded_shape(m, n, k, p)
    out = gemm.gemm(_pad2(a, mp, kp), _pad2(b, kp, np_), params=p,
                    interpret=_should_interpret(interpret),
                    out_dtype=out_dtype)
    return out[:m, :n]


def ft_matmul(a: jax.Array, b: jax.Array, *,
              ft: FTConfig = ONLINE_BLOCK,
              spec: Optional[InjectionSpec] = None,
              params: Optional[autotune.KernelParams] = None,
              interpret: Optional[bool] = None,
              out_dtype=None) -> jax.Array:
    """Fused fault-tolerant GEMM (paper §4). Returns the corrected C."""
    out, _ = ft_matmul_report(a, b, ft=ft, spec=spec, params=params,
                              interpret=interpret, out_dtype=out_dtype)
    return out


def flash_ft(q: jax.Array, k: jax.Array, v: jax.Array, *,
             ft: FTConfig = ONLINE_BLOCK, causal: bool = True,
             spec: Optional[InjectionSpec] = None,
             inj_bh: int = 0, inj_q_block: int = 0,
             bq: int = 128, bkv: int = 128,
             interpret: Optional[bool] = None,
             protect_qk: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Flash attention with fused in-kernel ABFT (see kernels/flashft.py).
    q: (BH, Sq, dh); k, v: (BH, Skv, dh). Pads dh to the 128-lane MXU edge
    and seq dims to block multiples (zero pads are ABFT- and softmax-neutral
    for K/V because masked; Q pads are sliced off). Returns (out, report)."""
    from . import flashft
    bh, sq, dh = q.shape
    skv = k.shape[1]
    dh_p = ((dh + 127) // 128) * 128
    bq = min(bq, ((sq + 127) // 128) * 128)
    bkv = min(bkv, ((skv + 127) // 128) * 128)
    sq_p = ((sq + bq - 1) // bq) * bq
    skv_p = ((skv + bkv - 1) // bkv) * bkv

    def pad3(x, s_to, d_to):
        return jnp.pad(x, ((0, 0), (0, s_to - x.shape[1]),
                           (0, d_to - x.shape[2])))

    qp, kp, vp = pad3(q, sq_p, dh_p), pad3(k, skv_p, dh_p), pad3(v, skv_p,
                                                                 dh_p)
    # padded KV rows must not receive attention: causal masking covers Q
    # pads; for KV pads beyond skv add -inf via a huge negative K? — zero K
    # gives score 0 which *would* leak for non-causal; guard by masking in
    # the kernel only through causal. For non-causal callers we require
    # skv % bkv == 0 (asserted).
    if not causal:
        assert skv == skv_p, "non-causal flash_ft needs block-aligned Skv"
    inj_idx, inj_mag = flashft.encode_injection(spec, inj_bh, inj_q_block)
    out, rep = flashft.flash_ft_attention(
        qp, kp, vp, inj_idx, inj_mag, bq=bq, bkv=bkv, causal=causal, ft=ft,
        interpret=_should_interpret(interpret), protect_qk=protect_qk,
        scale=dh ** -0.5)
    return out[:, :sq, :dh], rep


def ft_matmul_report(a: jax.Array, b: jax.Array, *,
                     ft: FTConfig = ONLINE_BLOCK,
                     spec: Optional[InjectionSpec] = None,
                     params: Optional[autotune.KernelParams] = None,
                     interpret: Optional[bool] = None,
                     out_dtype=None) -> Tuple[jax.Array, jax.Array]:
    """FT-GEMM returning (C, report[gm, gn, 8]) — see ftgemm.REPORT_WIDTH."""
    m, k = a.shape
    _, n = b.shape
    p = params or autotune.build_params(m, n, k, in_bytes=a.dtype.itemsize)
    mp, np_, kp = autotune.padded_shape(m, n, k, p)
    inj_idx, inj_mag = ftgemm.encode_injection(spec)
    out, rep = ftgemm.ft_gemm(
        _pad2(a, mp, kp), _pad2(b, kp, np_), inj_idx, inj_mag,
        params=p, ft=ft, interpret=_should_interpret(interpret),
        out_dtype=out_dtype)
    return out[:m, :n], rep
