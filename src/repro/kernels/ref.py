"""Pure-jnp oracles for the Pallas kernels.

`matmul_ref`   — the GEMM oracle (f32 accumulation, like the MXU).
`ft_matmul_ref`— the fault-tolerant GEMM oracle: mirrors the *semantics* of
                 the fused kernel (inject → detect → locate → correct) using
                 the shared checksum algebra in repro.core.abft, so kernel
                 sweeps can assert_allclose against it bit-for-bit behaviour
                 (same f32 checksum accumulation, same branchless correction).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import abft
from repro.core.policy import FTConfig, InjectionSpec
from repro.core.fault_injection import inject_spec


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def fused_matmul_ref(a: jax.Array, b: jax.Array,
                     bias: Optional[jax.Array] = None,
                     residual: Optional[jax.Array] = None,
                     chain: Optional[tuple] = None,
                     out_dtype=None) -> jax.Array:
    """Unfused two-pass oracle for the fused-epilogue kernel variants: the
    f32 GEMM accumulator followed by the epilogue chain applied as separate
    jnp ops (`templates.epilogues.reference_apply` — the same formulas the
    emitter inlines, so fused and unfused agree to rounding). `chain=None`
    derives the canonical bias→(no act)→residual order from the operands;
    pass an explicit chain (e.g. ("bias", "gelu")) to mirror a spec."""
    from .templates import epilogues
    out_dtype = out_dtype or a.dtype
    if chain is None:
        chain = ((("bias",) if bias is not None else ())
                 + (("residual",) if residual is not None else ()))
    acc = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    acc = epilogues.reference_apply(
        chain, acc,
        bias=None if bias is None else bias.reshape(1, -1),
        residual=residual)
    return acc.astype(out_dtype)


class FTRefOut(NamedTuple):
    out: jax.Array
    detected: jax.Array   # bool scalar
    row: jax.Array        # int32 global row of the corrected element
    col: jax.Array
    magnitude: jax.Array  # f32


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """Plain attention oracle for the flash-FT kernel.
    q: (BH, Sq, dh); k, v: (BH, Skv, dh). Causal masking is bottom-right
    aligned for Sq ≠ Skv (query i attends kv j iff j ≤ i + Skv − Sq — the
    decode/cross-length convention; identical to the triangular mask when
    Sq == Skv)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = (jnp.arange(sq)[:, None] + (sk - sq)
                >= jnp.arange(sk)[None, :])
        scores = jnp.where(mask[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def ft_matmul_ref(a: jax.Array, b: jax.Array, ft: FTConfig,
                  spec: Optional[InjectionSpec] = None,
                  out_dtype=None) -> FTRefOut:
    """Oracle for the fused FT-GEMM kernel on a single (M, N) output tile.

    The kernel verifies per k-step; under the SEU model (≤1 error per
    verification interval) the end state is identical to verifying once at
    the end, which is what this oracle does — tests inject exactly one error.
    """
    out_dtype = out_dtype or a.dtype
    acc = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    ck = abft.product_checksums(a, b)
    acc = inject_spec(acc, spec)
    tau = (jnp.asarray(ft.static_tau, jnp.float32) if ft.static_tau is not None
           else abft.threshold(a, b, ft.rel_tau))
    out, v = abft.detect_and_correct(acc, ck, tau, corrects=ft.corrects)
    return FTRefOut(out=out.astype(out_dtype), detected=v.detected,
                    row=v.row, col=v.col, magnitude=v.magnitude)
