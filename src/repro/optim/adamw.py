"""AdamW in pure JAX, with an optional int8-quantized moment mode ("q8").

q8 stores m and v as per-tensor absmax-scaled int8 — 4 bytes/param of
optimizer state instead of 8 — which is what lets arctic-480b train on a
single 256-chip v5e pod (DESIGN.md §4; the dry-run memory analysis depends
on it). Quantization error is re-absorbed each step because the moments are
re-quantized from the freshly updated f32 values (no error feedback needed
at β≤0.999 for the magnitudes involved; validated by the convergence-parity
test).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    q8: bool = False


BLOCK = 256
_V_FLOOR = 1e-24


def _blocks(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def _q8_linear(x: jax.Array) -> Dict[str, jax.Array]:
    """Block-wise signed linear int8 (first moment)."""
    b, _ = _blocks(x)
    scale = jnp.maximum(jnp.max(jnp.abs(b), axis=1), 1e-30) / 127.0
    q = jnp.round(b / scale[:, None]).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _dq8_linear(st, shape) -> jax.Array:
    flat = (st["q"].astype(jnp.float32) * st["s"][:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def _q8_log(x: jax.Array) -> Dict[str, jax.Array]:
    """Block-wise log-domain int8 for the (non-negative) second moment —
    uniform *relative* error across v's huge dynamic range (a per-tensor
    linear scale zeroes small v entries and blows up the update)."""
    b, _ = _blocks(x)
    b = jnp.maximum(b, _V_FLOOR)      # floor AFTER padding (pad zeros → log 0)
    lg = jnp.log(b)
    lo = jnp.min(lg, axis=1)
    hi = jnp.max(lg, axis=1)
    step = jnp.maximum(hi - lo, 1e-6) / 254.0
    q = jnp.round((lg - lo[:, None]) / step[:, None] - 127.0
                  ).astype(jnp.int8)
    return {"q": q, "lo": lo.astype(jnp.float32),
            "st": step.astype(jnp.float32)}


def _dq8_log(st, shape) -> jax.Array:
    lg = ((st["q"].astype(jnp.float32) + 127.0) * st["st"][:, None]
          + st["lo"][:, None])
    flat = jnp.exp(lg).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    v = flat[:n].reshape(shape)
    return jnp.where(v <= _V_FLOOR * 2, 0.0, v)


def init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros_m(p):
        if cfg.q8:
            return _q8_linear(jnp.zeros(p.shape, jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    def zeros_v(p):
        if cfg.q8:
            return _q8_log(jnp.zeros(p.shape, jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zeros_m, params),
        "v": jax.tree.map(zeros_v, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _read_m(st, shape, q8: bool) -> jax.Array:
    return _dq8_linear(st, shape) if q8 else st


def _read_v(st, shape, q8: bool) -> jax.Array:
    return _dq8_log(st, shape) if q8 else st


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(params, grads, state, cfg: AdamWConfig,
          lr_scale: jax.Array | float = 1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m_leaf, v_leaf):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _read_m(m_leaf, p.shape, cfg.q8) + (1 - cfg.b1) * g
        v = cfg.b2 * _read_v(v_leaf, p.shape, cfg.q8) + (1 - cfg.b2) * g * g
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (update + wd
                                              * p.astype(jnp.float32))
        return (new_p.astype(p.dtype),
                _q8_linear(m) if cfg.q8 else m,
                _q8_log(v) if cfg.q8 else v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_bytes_per_param(cfg: AdamWConfig) -> int:
    return 2 if cfg.q8 else 8
