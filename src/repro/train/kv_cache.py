"""Paged/block KV cache for the FT serving engine (PR 9).

Dense per-slot KV caches pay ``n_slots × max_len`` HBM whether or not a
slot is live — the padding the paper's §Perf accounting calls avoidable.
This module replaces that layout with a vLLM/JetStream-style *page pool*:

  * the pool holds ``n_pages`` fixed-size pages per layer, shaped
    ``(n_layers, n_pages, n_kv_heads, page_size, head_dim)`` — the
    trailing two dims are (sublane, lane)-shaped so ONE page is exactly
    one kv block of the paged flash decode kernel
    (`kernels.flashft._flash_decode_kernel`), streamed in through a
    scalar-prefetched page-table index map;
  * a host-side `PageAllocator` (free list) hands pages to slots on
    demand — a slot holds ⌈length/page_size⌉ pages, never max_len;
  * **page 0 is the reserved null/trash page**: unallocated page-table
    entries (and the whole row of a dead slot) point at it, so the
    engine's batched scatters for dead slots land harmlessly and no
    branchy gather/scatter masking is needed device-side. It is never
    allocated and never read by a live slot.

The device-side cache is a plain pytree of arrays (jit/donation
friendly); the allocator is the single mutable owner of the page table
and lengths — the engine pushes `numpy` table/length snapshots to the
device each step (a few KiB). Allocator invariants (no page aliased
across live slots, free-list conservation, null page never allocated)
are queryable via `check_invariants` — the property-test surface
(tests/test_kv_cache.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: The reserved trash page: never allocated, never read by a live slot.
NULL_PAGE = 0


# ---------------------------------------------------------------------------
# sizing: the autotuner picks the page edge
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagePlan:
    """Resolved paged-cache geometry for one (model, engine) config."""
    page_size: int       # tokens per page (the decode kernel's kv block)
    max_pages: int       # page-table width = pages per slot at max_len
    n_pages: int         # pool size INCLUDING the reserved null page
    n_slots: int
    max_len: int

    def hbm_bytes_per_slot(self, cfg, dtype_bytes: int = 2) -> int:
        """K+V pool bytes per slot at full occupancy (the benchmark's
        HBM-per-slot figure; excludes the shared null page)."""
        per_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim \
            * dtype_bytes
        usable = (self.n_pages - 1) * self.page_size
        return per_tok * usable // max(self.n_slots, 1)

    def dense_hbm_bytes_per_slot(self, cfg, dtype_bytes: int = 2) -> int:
        """The slot-based dense baseline: max_len tokens per slot, always."""
        per_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim \
            * dtype_bytes
        return per_tok * self.max_len


def plan_pages(cfg, ft, *, n_slots: int, max_len: int,
               dtype=jnp.bfloat16, page_size: Optional[int] = None,
               slack: float = 1.0) -> PagePlan:
    """Derive the paged-cache geometry. The page edge defaults to the
    autotuned streamed-block (bn) of the ``flashdecode`` variant
    (`templates.FlashKernelSpec(direction="decode")`) — the same tile the
    kernel wants to stream per step, so gather granularity and kernel
    block are one number. ``slack`` scales the pool (1.0 = every slot can
    reach max_len; < 1.0 oversubscribes HBM for bursty traffic)."""
    from repro.kernels import autotune, search
    from repro.kernels.templates.spec import FlashKernelSpec

    in_bytes = jnp.dtype(dtype).itemsize
    sub = search.sublane(in_bytes)
    dh_p = -(-cfg.head_dim // 128) * 128
    n_rep = cfg.n_heads // cfg.n_kv_heads
    bq = -(-n_rep // sub) * sub
    level = ft.level if ft.enabled else "off"
    if page_size is None:
        fspec = FlashKernelSpec(ft_level=level, direction="decode", dh=dh_p)
        p = autotune.best_params(bq, max(max_len, autotune.MXU), dh_p,
                                 in_bytes, ft_level=level, spec=fspec,
                                 batch=n_slots * cfg.n_kv_heads)
        page_size = p.bn
    page_size = max(sub, min(page_size, -(-max_len // sub) * sub))
    assert page_size % sub == 0, (page_size, sub)
    max_pages = -(-max_len // page_size)
    n_pages = 1 + max(max_pages, int(round(n_slots * max_pages * slack)))
    return PagePlan(page_size=page_size, max_pages=max_pages,
                    n_pages=n_pages, n_slots=n_slots, max_len=max_len)


# ---------------------------------------------------------------------------
# host-side allocator
# ---------------------------------------------------------------------------

class PageAllocator:
    """Free-list page allocator over the shared pool (host-side).

    The allocator owns the authoritative page table and per-slot lengths
    as numpy arrays; the engine snapshots them to the device each step.
    All methods are O(pages touched); none touch the device.
    """

    def __init__(self, n_pages: int, n_slots: int, max_pages: int,
                 page_size: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (one is the reserved null "
                             f"page), got {n_pages}")
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.page_size = page_size
        # pop() hands out low page ids first
        self._free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self.page_table = np.full((n_slots, max_pages), NULL_PAGE, np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self.n_alloc = np.zeros((n_slots,), np.int32)   # pages per slot
        self.live = np.zeros((n_slots,), bool)

    # -- queries -----------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def pages_for(self, length: int) -> int:
        return -(-int(length) // self.page_size)

    def free_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(~self.live)]

    def can_admit(self, length: int) -> bool:
        return (bool((~self.live).any())
                and self.pages_for(length) + 1 <= self.n_free)

    def live_pages(self) -> Dict[int, List[int]]:
        return {int(s): self.page_table[s, :self.n_alloc[s]].tolist()
                for s in np.flatnonzero(self.live)}

    # -- mutations ---------------------------------------------------------

    def alloc_slot(self, length: int) -> Tuple[int, List[int]]:
        """Claim the lowest free slot and allocate pages for ``length``
        tokens. Returns (slot, pages)."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        slot = free[0]
        need = self.pages_for(length)
        if need > self.max_pages:
            raise ValueError(f"length {length} needs {need} pages > "
                             f"max_pages {self.max_pages}")
        if need > self.n_free:
            raise RuntimeError(f"pool exhausted: need {need} pages, "
                               f"{self.n_free} free")
        self.live[slot] = True
        self.lengths[slot] = 0
        self.ensure(slot, length)
        return slot, self.page_table[slot, :need].tolist()

    def ensure(self, slot: int, new_length: int) -> List[int]:
        """Grow ``slot`` to hold ``new_length`` tokens, allocating pages as
        needed. Returns the newly allocated pages (possibly empty)."""
        if not self.live[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        need = self.pages_for(new_length)
        if need > self.max_pages:
            raise ValueError(f"length {new_length} needs {need} pages > "
                             f"max_pages {self.max_pages}")
        new: List[int] = []
        while self.n_alloc[slot] < need:
            if not self._free:
                raise RuntimeError("page pool exhausted")
            page = self._free.pop()
            self.page_table[slot, self.n_alloc[slot]] = page
            self.n_alloc[slot] += 1
            new.append(page)
        self.lengths[slot] = new_length
        return new

    def free_slot(self, slot: int) -> List[int]:
        """Return a finished slot's pages to the free list. The table row
        reverts to all-NULL so subsequent dead-slot scatters hit the trash
        page."""
        if not self.live[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        pages = self.page_table[slot, :self.n_alloc[slot]].tolist()
        self._free.extend(pages)
        self.page_table[slot] = NULL_PAGE
        self.lengths[slot] = 0
        self.n_alloc[slot] = 0
        self.live[slot] = False
        return pages

    # -- invariants (the property-test surface) ----------------------------

    def check_invariants(self) -> None:
        """Raise AssertionError on any broken allocator invariant."""
        free = self._free
        assert NULL_PAGE not in free, "null page entered the free list"
        assert len(set(free)) == len(free), "duplicate page in free list"
        live = self.live_pages()
        owned: Dict[int, int] = {}
        for slot, pages in live.items():
            assert len(pages) == self.n_alloc[slot]
            assert self.pages_for(self.lengths[slot]) <= len(pages)
            for pg in pages:
                assert pg != NULL_PAGE, f"slot {slot} owns the null page"
                assert pg not in owned, \
                    f"page {pg} aliased by slots {owned[pg]} and {slot}"
                owned[pg] = slot
        overlap = set(owned) & set(free)
        assert not overlap, f"pages both live and free: {sorted(overlap)}"
        # conservation: every non-null page is either live or free
        assert len(owned) + len(free) == self.n_pages - 1, \
            (len(owned), len(free), self.n_pages)
        for s in np.flatnonzero(~self.live):
            assert (self.page_table[s] == NULL_PAGE).all(), \
                f"dead slot {int(s)} holds table entries"
            assert self.lengths[s] == 0 and self.n_alloc[s] == 0

    def snapshot(self) -> Tuple[jax.Array, jax.Array]:
        """Device copies of (page_table, lengths) for the decode step."""
        return jnp.asarray(self.page_table), jnp.asarray(self.lengths)


# ---------------------------------------------------------------------------
# device-side cache ops (pure functions over the cache pytree)
# ---------------------------------------------------------------------------

def init_paged_cache(n_layers: int, n_pages: int, n_slots: int,
                     max_pages: int, n_kv_heads: int, page_size: int,
                     head_dim: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Fresh paged cache pytree. Layout: pages hold (kv_head, position,
    lane) with (page_size, head_dim) as the trailing two dims — one page ≡
    one kv block of the paged decode kernel."""
    kv = (n_layers, n_pages, n_kv_heads, page_size, head_dim)
    return {
        "k_pages": jnp.zeros(kv, dtype),
        "v_pages": jnp.zeros(kv, dtype),
        "page_table": jnp.full((n_slots, max_pages), NULL_PAGE, jnp.int32),
        "length": jnp.zeros((n_slots,), jnp.int32),
    }


def write_prefill(cache: Dict[str, Any], slot, table_row: jax.Array,
                  ks: jax.Array, vs: jax.Array, length: int
                  ) -> Dict[str, Any]:
    """Scatter one slot's prefill KV into its pages.

    table_row int32[max_pages] — the slot's allocator row (NULL-padded:
    unused entries write zero padding into the trash page); ks/vs
    (n_layers, S, n_kv_heads, head_dim) with S ≤ max_pages·page_size.
    Also records ``length`` for the slot."""
    k_pages = cache["k_pages"]
    page = k_pages.shape[3]
    mp = table_row.shape[0]
    n_l, s, kvh, dh = ks.shape
    cap = mp * page
    assert s <= cap, (s, cap)

    def place(pages_arr, x):
        xp = jnp.pad(x.astype(pages_arr.dtype),
                     ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        # (L, MP, page, KVH, dh) → (L, MP, KVH, page, dh): the value for an
        # advanced index on the pool's page axis.
        xp = xp.reshape(n_l, mp, page, kvh, dh).transpose(0, 1, 3, 2, 4)
        return pages_arr.at[:, table_row].set(xp)

    return {
        "k_pages": place(k_pages, ks),
        "v_pages": place(cache["v_pages"], vs),
        "page_table": cache["page_table"].at[slot].set(table_row),
        "length": cache["length"].at[slot].set(length),
    }


def append_layer(pages: jax.Array, kv_new: jax.Array, table: jax.Array,
                 pos: jax.Array) -> jax.Array:
    """Write one token's K (or V) for every slot into ONE layer's pool.
    pages (P, KVH, page, dh); kv_new (B, KVH, dh); table (B, MP);
    pos int32[B] — the target position (the slot's current length). Dead
    slots (all-NULL rows) scatter into the trash page."""
    page = pages.shape[2]
    mp = table.shape[1]
    b = table.shape[0]
    pidx = jnp.minimum(pos // page, mp - 1)
    target = table[jnp.arange(b), pidx]                    # (B,)
    offs = pos % page
    # Advanced indices on dims (0: page id, 2: in-page offset) around the
    # kv-head slice → the value carries (B, KVH, dh).
    return pages.at[target, :, offs].set(kv_new.astype(pages.dtype))


def append_token(cache: Dict[str, Any], k_new: jax.Array, v_new: jax.Array
                 ) -> Dict[str, Any]:
    """Append one token per slot across all layers. k_new/v_new
    (n_layers, B, n_kv_heads, head_dim), written at each slot's current
    ``length``; lengths advance by one (dead all-NULL slots write into the
    trash page and their length stays meaningful to the caller only)."""
    table, pos = cache["page_table"], cache["length"]
    app = jax.vmap(append_layer, in_axes=(0, 0, None, None))
    return {
        "k_pages": app(cache["k_pages"], k_new, table, pos),
        "v_pages": app(cache["v_pages"], v_new, table, pos),
        "page_table": table,
        "length": pos + 1,
    }


def gather_layer(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Dense (B, max_pages·page, KVH, dh) view of ONE layer's pool through
    the page table (NULL entries read the trash page → positions past a
    slot's length are garbage and must stay masked by `length`)."""
    g = pages[table]                         # (B, MP, KVH, page, dh)
    b, mp, kvh, page, dh = g.shape
    return g.transpose(0, 1, 3, 2, 4).reshape(b, mp * page, kvh, dh)


def gather_dense(cache: Dict[str, Any]) -> Tuple[jax.Array, jax.Array]:
    """Dense (n_layers, B, S_max, KVH, dh) K and V views — the oracle
    layout `models.blocks.decode_attention` consumes (and the property
    tests' paged ≡ dense reference)."""
    gat = jax.vmap(gather_layer, in_axes=(0, None))
    return (gat(cache["k_pages"], cache["page_table"]),
            gat(cache["v_pages"], cache["page_table"]))
