"""Batched serving: prefill + decode with KV cache, greedy/temperature
sampling, and request batching (slot-based).

The jitted step functions are exactly what the decode/prefill dry-run cells
lower — serving here and serving on the 256-chip mesh are the same code.

FT telemetry (PR 8): `make_serve_fns(..., with_report=True)` wraps the
prefill/decode bodies in a `telemetry` scope so each jitted call *also*
returns its per-site FTReport — the model's serve paths contribute
per-layer scoped rows only when such a scope is open, so the default
`with_report=False` program is unchanged. `generate(..., sink=...)` feeds
those per-step reports to a `tools.metrics.MetricsSink` (one sink step per
decoded token batch), so decode-path SDCs land in the same JSONL stream —
and the same storm detector — as training."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import telemetry
from repro.models import model_zoo
from repro.models.blocks import Ctx


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    batch_slots: int = 8
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early


def make_serve_fns(cfg: ModelConfig, run: RunConfig, *,
                   with_report: bool = False) -> Tuple[Callable, Callable]:
    """Build the jitted (prefill_fn, decode_fn) pair. With ``with_report``
    each returns an extra trailing `telemetry.FTReport` (per-site, per-layer
    rows) for the request batch — the serve-side telemetry feed."""
    mod = model_zoo.module_for(cfg)
    # Every family's serve paths gate per-layer scoping on an open ft_scope
    # (PR 9): transformer (PR 8), ssm/hybrid/encdec scan bodies carry the
    # scoped report the same way, so with_report works across the zoo.
    dtype = jnp.bfloat16 if run.dtype == "bfloat16" else jnp.float32
    ctx = Ctx(ft=run.ft, key=None, dtype=dtype, attn_shard=run.attn_shard,
              attn_impl=run.attn_impl)

    def prefill_fn(params, tokens, cache, extra=None):
        kw = {}
        if cfg.family == "vlm" and extra is not None:
            kw["extra_embeds"] = extra
        if cfg.family == "encdec" and extra is not None:
            kw["frames"] = extra
        if not with_report:
            return mod.prefill(params, tokens, cache, cfg, ctx,
                               chunk=run.attn_chunk, **kw)
        (logits, new_cache), rep = telemetry.scoped(
            lambda: mod.prefill(params, tokens, cache, cfg, ctx,
                                chunk=run.attn_chunk, **kw))
        return logits, new_cache, rep

    def decode_fn(params, token, cache):
        if not with_report:
            return mod.decode_step(params, token, cache, cfg, ctx)
        (logits, new_cache), rep = telemetry.scoped(
            lambda: mod.decode_step(params, token, cache, cfg, ctx))
        return logits, new_cache, rep

    return jax.jit(prefill_fn), jax.jit(decode_fn, donate_argnums=(2,))


def _sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature
                                  ).astype(jnp.int32)


def generate(params, prompts: np.ndarray, cfg: ModelConfig, run: RunConfig,
             sc: ServeConfig, *, max_new_tokens: int = 32,
             extra=None, seed: int = 0, sink=None) -> np.ndarray:
    """Batch-generate continuations. prompts: (B, S_prompt) int32.

    `sink` — optional `tools.metrics.MetricsSink`: the prefill report and
    every decode step's report are recorded (one sink step per model call),
    attributing decode-path SDCs per site/layer like training steps."""
    mod = model_zoo.module_for(cfg)
    with_report = sink is not None
    prefill_fn, decode_fn = make_serve_fns(cfg, run,
                                           with_report=with_report)
    b = prompts.shape[0]
    dtype = jnp.bfloat16 if run.dtype == "bfloat16" else jnp.float32
    cache = mod.init_cache(cfg, b, sc.max_len, dtype)
    serve_step = 0

    def _emit(rep, phase: str):
        nonlocal serve_step
        sink.record_ft(rep, step=serve_step)
        sink.gauge("phase", phase)
        sink.count("requests" if phase == "prefill" else "decoded_tokens",
                   b)
        sink.step_end(serve_step)
        serve_step += 1

    if with_report:
        logits, cache, rep = prefill_fn(params, jnp.asarray(prompts), cache,
                                        extra)
        _emit(rep, "prefill")
    else:
        logits, cache = prefill_fn(params, jnp.asarray(prompts), cache,
                                   extra)
    key = jax.random.PRNGKey(seed)
    tokens: List[jax.Array] = []
    tok = _sample(logits.reshape(b, -1), sc.temperature, key)[:, None]
    done = np.zeros((b,), bool)
    for i in range(max_new_tokens):
        tokens.append(tok)
        if with_report:
            logits, cache, rep = decode_fn(params, tok, cache)
            _emit(rep, "decode")
        else:
            logits, cache = decode_fn(params, tok, cache)
        key = jax.random.fold_in(key, i)
        tok = _sample(logits.reshape(b, -1), sc.temperature, key)[:, None]
        if sc.eos_id >= 0:
            done |= np.asarray(tok[:, 0] == sc.eos_id)
            if done.all():
                tokens.append(tok)
                break
    return np.concatenate([np.asarray(t) for t in tokens], axis=1)
