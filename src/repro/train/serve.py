"""Batched serving: prefill + decode with KV cache, greedy/temperature
sampling, and request batching (slot-based).

The jitted step functions are exactly what the decode/prefill dry-run cells
lower — serving here and serving on the 256-chip mesh are the same code.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model_zoo
from repro.models.blocks import Ctx


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    batch_slots: int = 8
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early


def make_serve_fns(cfg: ModelConfig, run: RunConfig
                   ) -> Tuple[Callable, Callable]:
    mod = model_zoo.module_for(cfg)
    dtype = jnp.bfloat16 if run.dtype == "bfloat16" else jnp.float32
    ctx = Ctx(ft=run.ft, key=None, dtype=dtype, attn_shard=run.attn_shard,
              attn_impl=run.attn_impl)

    def prefill_fn(params, tokens, cache, extra=None):
        kw = {}
        if cfg.family == "vlm" and extra is not None:
            kw["extra_embeds"] = extra
        if cfg.family == "encdec" and extra is not None:
            kw["frames"] = extra
        return mod.prefill(params, tokens, cache, cfg, ctx,
                           chunk=run.attn_chunk, **kw)

    def decode_fn(params, token, cache):
        return mod.decode_step(params, token, cache, cfg, ctx)

    return jax.jit(prefill_fn), jax.jit(decode_fn, donate_argnums=(2,))


def _sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature
                                  ).astype(jnp.int32)


def generate(params, prompts: np.ndarray, cfg: ModelConfig, run: RunConfig,
             sc: ServeConfig, *, max_new_tokens: int = 32,
             extra=None, seed: int = 0) -> np.ndarray:
    """Batch-generate continuations. prompts: (B, S_prompt) int32."""
    mod = model_zoo.module_for(cfg)
    prefill_fn, decode_fn = make_serve_fns(cfg, run)
    b = prompts.shape[0]
    dtype = jnp.bfloat16 if run.dtype == "bfloat16" else jnp.float32
    cache = mod.init_cache(cfg, b, sc.max_len, dtype)
    logits, cache = prefill_fn(params, jnp.asarray(prompts), cache, extra)
    key = jax.random.PRNGKey(seed)
    tokens: List[jax.Array] = []
    tok = _sample(logits.reshape(b, -1), sc.temperature, key)[:, None]
    done = np.zeros((b,), bool)
    for i in range(max_new_tokens):
        tokens.append(tok)
        logits, cache = decode_fn(params, tok, cache)
        key = jax.random.fold_in(key, i)
        tok = _sample(logits.reshape(b, -1), sc.temperature, key)[:, None]
        if sc.eos_id >= 0:
            done |= np.asarray(tok[:, 0] == sc.eos_id)
            if done.all():
                tokens.append(tok)
                break
    return np.concatenate([np.asarray(t) for t in tokens], axis=1)
