"""Training step + loop.

`make_train_step` builds the jit-able pure step:
    (params, opt_state, batch, step) → (params, opt_state, metrics)
with — in one function — the full fault-tolerance stack:
  * every GEMM (fwd + bwd) ABFT-protected per RunConfig.ft;
  * per-step FTReport (SDC detections/corrections) in the metrics;
  * optional SEU injection campaign (run.ft.inject_rate + per-step key);
  * optional int8 error-feedback gradient compression (cross-pod sync);
  * gradient-accumulation microbatching (memory ↔ throughput knob).

`train` is the host loop: data pipeline with O(1) resume, async
checkpointing, SIGTERM preemption save, straggler watchdog.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import telemetry
from repro.core.policy import FTConfig
from repro.distributed import compress as compress_lib
from repro.models import model_zoo
from repro.models.blocks import Ctx
from repro.optim import adamw, schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    total_steps: int = 1000
    warmup_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 200
    compress_grads: bool = False
    inject_every: int = 0        # inject SEUs every N steps (0 = never)


def make_train_step(cfg: ModelConfig, run: RunConfig,
                    opt_cfg: adamw.AdamWConfig, tc: TrainConfig
                    ) -> Callable:
    mod = model_zoo.module_for(cfg)
    dtype = jnp.bfloat16 if run.dtype == "bfloat16" else jnp.float32
    remat = run.remat if run.remat != "none" else False

    def train_step(params, opt_state, batch, step, inject_key=None):
        ctx = Ctx(ft=run.ft, key=inject_key, dtype=dtype,
                  attn_shard=run.attn_shard, attn_impl=run.attn_impl)

        def loss_f(p, b):
            loss, metrics = mod.loss_fn(p, b, cfg, ctx, remat=remat,
                                        chunk=run.attn_chunk)
            return loss, metrics

        if run.microbatch and run.microbatch > 1:
            n_micro = run.microbatch
            split = lambda x: x.reshape((n_micro, -1) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def micro_step(carry, mb):
                (loss, mets), g = jax.value_and_grad(loss_f, has_aux=True
                                                     )(params, mb)
                acc_g, acc_l = carry
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + loss), mets

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), mets = jax.lax.scan(
                micro_step, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            # FT counters SUM across microbatches (event counts — and f32
            # since PR 1, so a dtype-keyed sum-vs-mean branch would silently
            # average them); float metrics average.
            mets = dict(mets)
            ft_stacked = mets.pop("ft", None)
            metrics = jax.tree.map(jnp.mean, mets)
            if ft_stacked is not None:
                metrics["ft"] = telemetry.reduce_microbatch(ft_stacked)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_f, has_aux=True
                                                        )(params, batch)

        if tc.compress_grads:
            grads, new_err = compress_lib.compress_decompress(
                grads, opt_state["ef_error"])
        lr_scale = schedule.warmup_cosine(
            step, warmup=tc.warmup_steps, total=tc.total_steps)
        new_params, new_opt, opt_metrics = adamw.apply(
            params, grads, opt_state["adam"], opt_cfg, lr_scale)
        new_state = {"adam": new_opt}
        if tc.compress_grads:
            new_state["ef_error"] = new_err
        elif "ef_error" in opt_state:
            new_state["ef_error"] = opt_state["ef_error"]
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def init_opt_state(params, opt_cfg: adamw.AdamWConfig,
                   tc: TrainConfig) -> Dict[str, Any]:
    state = {"adam": adamw.init(params, opt_cfg)}
    if tc.compress_grads:
        state["ef_error"] = compress_lib.init_error(params)
    return state


# ---------------------------------------------------------------------------
# host loop
# ---------------------------------------------------------------------------

class Watchdog:
    """Step-time straggler detector: flags steps slower than
    mean + k·std over a trailing window (the per-host signal a pod-level
    controller aggregates to evict slow nodes)."""

    def __init__(self, window: int = 50, k: float = 3.0,
                 clock: Callable[[], float] = time.monotonic):
        self.window, self.k, self.clock = window, k, clock
        self.times: list = []
        self.stragglers: list = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = self.clock()

    def stop(self, step: int) -> bool:
        dt = self.clock() - self._t0
        hist = self.times[-self.window:]
        slow = False
        if len(hist) >= 10:
            mean = sum(hist) / len(hist)
            var = sum((x - mean) ** 2 for x in hist) / len(hist)
            slow = dt > mean + self.k * (var ** 0.5) and dt > 1.5 * mean
            if slow:
                self.stragglers.append((step, dt, mean))
        self.times.append(dt)
        return slow


def train(cfg: ModelConfig, run: RunConfig, shape: ShapeConfig,
          tc: TrainConfig, *, batch_override: Optional[int] = None,
          ckpt_dir: Optional[str] = None, resume: bool = False,
          stop_at: Optional[int] = None,
          log: Callable[[str], None] = print,
          sink=None) -> Dict[str, Any]:
    """End-to-end training entry (examples/train_lm.py and launch/train.py
    call this). Single-host; under a mesh the same code path works with
    jit-sharded params (see launch/train.py).

    `sink` — optional `repro.tools.metrics.MetricsSink`: every step's FT
    report, loss/step-time/tokens-per-sec gauges, and SDC-storm alerts flow
    through it to the attached emitters (JSONL for offline analysis)."""
    from repro.checkpoint.ckpt import Checkpointer
    from repro.data import pipeline as data_lib
    from repro.tools.trace import span

    mod = model_zoo.module_for(cfg)
    dtype = jnp.bfloat16 if run.dtype == "bfloat16" else jnp.float32
    opt_cfg = adamw.AdamWConfig(
        lr=run.learning_rate, weight_decay=run.weight_decay,
        grad_clip=run.grad_clip, q8=(run.opt_state == "q8"))
    params = mod.init(cfg, jax.random.PRNGKey(run.seed), dtype)
    opt_state = init_opt_state(params, opt_cfg, tc)
    start_step = 0
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if resume and ckpt and ckpt.latest_step() is not None:
        tree, start_step, _ = ckpt.restore(
            {"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        log(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, run, opt_cfg, tc),
                      donate_argnums=(0, 1))
    pipe = data_lib.for_model(cfg, shape, seed=run.seed,
                              batch=batch_override)
    wd = Watchdog()
    history = []
    preempted = {"flag": False}

    def on_sigterm(signum, frame):
        preempted["flag"] = True

    old = signal.signal(signal.SIGTERM, on_sigterm)
    try:
        it = pipe.iter_from(start_step)
        end_step = min(stop_at, tc.total_steps) if stop_at else tc.total_steps
        launches: Optional[int] = None
        for step in range(start_step, end_step):
            with span("data"):
                batch = {k: jnp.asarray(v) for k, v in next(it).items()}
                if "patches" in batch:
                    batch["patches"] = batch["patches"].astype(dtype)
                if "frames" in batch:
                    batch["frames"] = batch["frames"].astype(dtype)
            inject_key = None
            if tc.inject_every and step % tc.inject_every == 0:
                inject_key = jax.random.PRNGKey(step)
            if sink is not None and launches is None:
                # One-time pallas launch count of the step program (audit
                # traces the un-jitted step; the count is a program
                # property, constant across steps).
                from repro.tools import audit
                launches = audit.count_primitives(
                    make_train_step(cfg, run, opt_cfg, tc), params,
                    opt_state, batch, jnp.asarray(step), inject_key)
            wd.start()
            with span("step"):
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.asarray(step), inject_key)
                jax.block_until_ready(metrics["loss"])
            slow = wd.stop(step)
            ft = metrics.get("ft")
            if sink is not None:
                with span("metrics"):
                    dt = wd.times[-1]
                    if ft is not None:
                        sink.record_ft(ft, step=step)
                    tokens = int(batch["tokens"].size) \
                        if "tokens" in batch else 0
                    sink.count("tokens", tokens)
                    sink.step_end(
                        step, loss=float(metrics["loss"]), step_time_s=dt,
                        tokens_per_s=(tokens / dt if dt > 0 else 0.0),
                        pallas_launches=launches or 0)
            if step % tc.log_every == 0 or step == tc.total_steps - 1:
                msg = (f"step {step:5d} loss {float(metrics['loss']):.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f}")
                if ft is not None:
                    msg += (f" sdc_det {int(ft.detected)}"
                            f" sdc_fix {int(ft.corrected)}")
                if slow:
                    msg += " [STRAGGLER]"
                log(msg)
                history.append({"step": step,
                                "loss": float(metrics["loss"])})
            if ckpt and (step + 1) % tc.ckpt_every == 0:
                with span("checkpoint"):
                    ckpt.save_async(step + 1,
                                    {"params": params, "opt": opt_state})
            if preempted["flag"]:
                log(f"SIGTERM at step {step}: checkpointing and exiting")
                if ckpt:
                    ckpt.save(step + 1, {"params": params, "opt": opt_state})
                break
        if ckpt:
            ckpt.wait()
    finally:
        signal.signal(signal.SIGTERM, old)
    return {"params": params, "opt_state": opt_state, "history": history,
            "stragglers": wd.stragglers,
            "final_step": step + 1 if "step" in dir() else start_step}
