"""Continuous-batching FT serving engine over the paged KV cache (PR 9).

`train/serve.py` is the slot-*batch* baseline: one prefill fills every slot,
decode runs until the whole batch finishes, and each slot owns a dense
(max_len, KVH, dh) cache stripe whether it uses it or not. This module is
the vLLM/Orca-style engine on top of `train/kv_cache.py`:

  * requests are admitted into *slots* as they arrive (FIFO) whenever the
    page pool has room — prefill for one request interleaves with decode
    steps for the others instead of gating a whole batch;
  * each slot's KV lives in pool pages routed by a host-authoritative page
    table, so HBM scales with tokens actually held, not n_slots × max_len;
  * every decode step is ONE jitted `transformer.paged_decode_step` call
    over all slots — per-layer flashft decode launches with the page table
    and per-slot ragged lengths scalar-prefetched, dead slots riding along
    into the reserved null page;
  * finished slots return their pages to the free list immediately, which
    is what admits the next queued request.

FT telemetry threads through exactly like `serve.generate`: with a
`tools.metrics.MetricsSink` attached, the engine opens a telemetry scope
around each jitted call and feeds the per-site/per-layer FTReport to the
sink (one sink step per prefill or decode call), so serving SDCs land in
the same JSONL stream — and the same storm detector — as training. The
engine additionally records serving-shape metrics per step: live slots,
free pages, decoded tokens, and a TTFT histogram at admission.

Length bookkeeping: `PageAllocator.ensure(slot, n)` reserves *capacity*;
the device-visible `cache["length"]` is the engine's decoded-so-far count
(`cur_len`) — ensure runs for `cur_len + 1` BEFORE each step so the page
for the incoming token exists, while the kernel masks at `cur_len`.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import telemetry
from repro.models import transformer as tfm
from repro.models.blocks import Ctx
from . import kv_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    t_submit: float = 0.0


@dataclasses.dataclass
class Result:
    rid: int
    prompt_len: int
    tokens: List[int]             # generated tokens (eos included if hit)
    ttft_s: float                 # submit → first token (prefill) latency


@dataclasses.dataclass
class EngineConfig:
    max_len: int = 512            # prompt + generated ceiling per request
    n_slots: int = 8
    max_new_tokens: int = 32      # default per-request budget
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop early
    page_size: Optional[int] = None   # None = autotuned (kv_cache.plan_pages)
    slack: float = 1.0            # pool oversubscription (<1 may exhaust)
    seed: int = 0


class ServeEngine:
    """Continuous-batching serving engine for the transformer families
    (dense / moe — the architectures with a (S, KVH, dh) KV cache).

    Usage::

        eng = ServeEngine(params, cfg, run, EngineConfig(...), sink=sink)
        eng.submit(prompt_a); eng.submit(prompt_b)
        results = eng.run()           # or: while eng.step(): ...

    Per-request prefill runs unpadded at batch 1 (one retrace per distinct
    prompt length — synthetic-traffic benchmarks should draw from a few
    length buckets), writes the prompt KV into freshly allocated pages, and
    samples the first token (TTFT). Decode steps advance every live slot
    through one `paged_decode_step` call.
    """

    def __init__(self, params, cfg: ModelConfig, run: RunConfig,
                 ec: EngineConfig, *, sink=None,
                 clock=time.perf_counter):
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"paged serving needs the transformer KV layout; family "
                f"{cfg.family!r} is a ROADMAP follow-up")
        self.params = params
        self.cfg = cfg
        self.ec = ec
        self.sink = sink
        self._clock = clock
        self.dtype = jnp.bfloat16 if run.dtype == "bfloat16" else jnp.float32
        self.ctx = Ctx(ft=run.ft, key=None, dtype=self.dtype,
                       attn_shard=run.attn_shard, attn_impl=run.attn_impl)
        self.plan = kv_cache.plan_pages(
            cfg, run.ft, n_slots=ec.n_slots, max_len=ec.max_len,
            dtype=self.dtype, page_size=ec.page_size, slack=ec.slack)
        p = self.plan
        self.alloc = kv_cache.PageAllocator(p.n_pages, p.n_slots,
                                            p.max_pages, p.page_size)
        self.cache = kv_cache.init_paged_cache(
            cfg.n_layers, p.n_pages, p.n_slots, p.max_pages, cfg.n_kv_heads,
            p.page_size, cfg.head_dim, self.dtype)
        n = ec.n_slots
        self.cur_len = np.zeros((n,), np.int32)     # prompt + decoded so far
        self.next_tok = np.zeros((n,), np.int32)    # sampled, not yet in KV
        self.n_new = np.zeros((n,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * n
        self.gen: List[List[int]] = [[] for _ in range(n)]
        self.ttft: List[float] = [0.0] * n
        self.queue: Deque[Request] = collections.deque()
        self.results: List[Result] = []
        self._rid = 0
        self._serve_step = 0
        self._key = jax.random.PRNGKey(ec.seed)
        self._draws = 0

        with_report = sink is not None
        ctx = self.ctx

        def prefill_fn(params, tokens, dcache):
            if not with_report:
                return tfm.prefill(params, tokens, dcache, cfg, ctx)
            (logits, nc), rep = telemetry.scoped(
                lambda: tfm.prefill(params, tokens, dcache, cfg, ctx))
            return logits, nc, rep

        def decode_fn(params, tok, pcache):
            if not with_report:
                return tfm.paged_decode_step(params, tok, pcache, cfg, ctx)
            (logits, nc), rep = telemetry.scoped(
                lambda: tfm.paged_decode_step(params, tok, pcache, cfg, ctx))
            return logits, nc, rep

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    # -- request intake ----------------------------------------------------

    def submit(self, prompt, max_new_tokens: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        mnt = self.ec.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        if mnt < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if len(prompt) + mnt > self.plan.max_len:
            raise ValueError(
                f"prompt_len {len(prompt)} + max_new {mnt} exceeds "
                f"max_len {self.plan.max_len}")
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, prompt, mnt, self._clock()))
        return rid

    # -- internals ---------------------------------------------------------

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.ec.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._draws += 1
        k = jax.random.fold_in(self._key, self._draws)
        return np.asarray(
            jax.random.categorical(k, logits / self.ec.temperature),
            np.int32)

    def _emit(self, rep, phase: str, n_tokens: int) -> None:
        sink = self.sink
        sink.record_ft(rep, step=self._serve_step)
        sink.gauge("phase", phase)
        sink.gauge("live_slots", sum(r is not None for r in self.slot_req))
        sink.gauge("free_pages", self.alloc.n_free)
        sink.count("decoded_tokens" if phase == "decode" else "prefill_tokens",
                   n_tokens)
        sink.step_end(self._serve_step)
        self._serve_step += 1

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        assert req is not None
        self.results.append(Result(req.rid, len(req.prompt),
                                   list(self.gen[slot]), self.ttft[slot]))
        self.alloc.free_slot(slot)
        self.slot_req[slot] = None
        self.gen[slot] = []
        self.cur_len[slot] = 0
        self.next_tok[slot] = 0
        self.n_new[slot] = 0

    def _admit(self) -> None:
        """FIFO-admit queued requests while a slot AND pages are free.
        Runs the request's (batch-1, unpadded) prefill, scatters the prompt
        KV into freshly allocated pages, and samples the first token."""
        while self.queue and self.alloc.can_admit(len(self.queue[0].prompt)):
            req = self.queue.popleft()
            L = len(req.prompt)
            slot, _ = self.alloc.alloc_slot(L)
            dcache = tfm.init_cache(self.cfg, 1, L, self.dtype)
            toks = jnp.asarray(req.prompt[None], jnp.int32)
            if self.sink is not None:
                logits, dcache, rep = self._prefill(self.params, toks, dcache)
            else:
                logits, dcache = self._prefill(self.params, toks, dcache)
            self.cache = kv_cache.write_prefill(
                self.cache, slot, jnp.asarray(self.alloc.page_table[slot]),
                dcache["k"][:, 0], dcache["v"][:, 0], L)
            tok = int(self._sample(logits.reshape(1, -1))[0])
            now = self._clock()
            self.slot_req[slot] = req
            self.cur_len[slot] = L
            self.next_tok[slot] = tok
            self.n_new[slot] = 1
            self.gen[slot] = [tok]
            self.ttft[slot] = now - req.t_submit
            if self.sink is not None:
                self.sink.count("requests", 1)
                self.sink.histogram("ttft_s", self.ttft[slot])
                self._emit(rep, "prefill", L)
            if self._done(slot, tok):
                self._finish(slot)

    def _done(self, slot: int, tok: int) -> bool:
        req = self.slot_req[slot]
        return (self.n_new[slot] >= req.max_new_tokens
                or (self.ec.eos_id >= 0 and tok == self.ec.eos_id))

    # -- the engine loop ---------------------------------------------------

    def step(self) -> bool:
        """Admit what fits, then run ONE decode step over every live slot.
        Returns False when the engine is fully drained (no live slots and
        an empty queue) — i.e. `while eng.step(): pass` serves everything."""
        self._admit()
        live = [s for s in range(self.ec.n_slots)
                if self.slot_req[s] is not None]
        if not live:
            if self.queue:
                # Idle engine (every page free) yet the head request still
                # does not fit: it never will — fail loudly instead of
                # spinning. Reachable only with a pool sized below one
                # worst-case request (slack ≪ 1 or tiny max_pages).
                raise RuntimeError(
                    f"request rid={self.queue[0].rid} (prompt_len="
                    f"{len(self.queue[0].prompt)}) cannot be admitted even "
                    f"by an idle engine: page pool too small "
                    f"({self.alloc.n_free} free pages)")
            return False
        for s in live:
            self.alloc.ensure(s, int(self.cur_len[s]) + 1)
        self.cache["page_table"] = jnp.asarray(self.alloc.page_table)
        self.cache["length"] = jnp.asarray(self.cur_len)
        tok = jnp.asarray(self.next_tok[:, None], jnp.int32)
        if self.sink is not None:
            logits, self.cache, rep = self._decode(self.params, tok,
                                                   self.cache)
        else:
            logits, self.cache = self._decode(self.params, tok, self.cache)
        nxt = self._sample(logits.reshape(self.ec.n_slots, -1))
        if self.sink is not None:
            self._emit(rep, "decode", len(live))
        for s in live:
            self.cur_len[s] += 1
            t = int(nxt[s])
            self.next_tok[s] = t
            self.gen[s].append(t)
            self.n_new[s] += 1
            if self._done(s, t):
                self._finish(s)
        return True

    def run(self) -> List[Result]:
        """Drain the queue; returns results sorted by request id."""
        while self.step():
            pass
        self.alloc.check_invariants()
        return sorted(self.results, key=lambda r: r.rid)
