"""Fault-tolerance policy configuration.

Mirrors the paper's design space:
  * level   — where checksums are maintained (paper: thread/warp/threadblock;
              here: "inner"/"tile"/"block", see DESIGN.md §2.1). The jnp path
              only distinguishes fused vs non-fused; the Pallas kernel
              implements all three.
  * action  — "correct" = online ABFT (paper §4, detect AND correct on the
              fly); "detect" = offline ABFT (§5.5, detect-only; caller must
              recompute); "off" = no fault tolerance.
  * fused   — True: checksum memory traffic fused with the GEMM (the paper's
              contribution); False: the Ding-2011-style non-fused baseline
              (separate encode / multiply / verify passes over HBM).
  * verify  — "step": verify every k-step (online, corrects one SEU per
              interval → many per GEMM); "final": verify once per output tile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class FTConfig:
    action: str = "correct"          # "off" | "detect" | "correct"
    level: str = "block"             # "inner" | "tile" | "block"
    fused: bool = True               # False = Ding-2011 non-fused baseline
    verify: str = "step"             # "step" | "final"
    # Relative checksum tolerance multiplier. The absolute threshold is
    #   tau = rel_tau * eps(dtype) * K * max|A| * max|B|
    # (a standard ABFT rounding bound; rel_tau absorbs the constants).
    rel_tau: float = 64.0
    # Accumulate checksums in f32 even for bf16 GEMMs.
    checksum_dtype: str = "float32"
    # Protect batched attention GEMMs (QK^T, PV) too.
    protect_attention: bool = True
    # Backend for the local GEMM: "xla" (jnp, GSPMD-friendly) or "pallas".
    backend: str = "xla"
    # Optional static detection threshold. None ⇒ dynamic rounding-aware
    # threshold (costs a max-reduction over each operand). A hillclimb lever:
    # a calibrated static tau removes two operand passes per GEMM.
    static_tau: Optional[float] = None
    # Stochastic SEU injection (error-injection campaigns; 0.0 = off).
    # Probability that a given protected GEMM suffers one flipped accumulator
    # element this step, when an injection key is supplied.
    inject_rate: float = 0.0
    inject_bit_shift: int = 8

    @property
    def enabled(self) -> bool:
        return self.action != "off"

    @property
    def corrects(self) -> bool:
        return self.action == "correct"

    def replace(self, **kw) -> "FTConfig":
        return dataclasses.replace(self, **kw)


#: Paper's flagship configuration — fused threadblock-level online ABFT.
ONLINE_BLOCK = FTConfig(action="correct", level="block", fused=True)
#: Offline (detect-only) ABFT of §5.5.
OFFLINE_DETECT = FTConfig(action="detect", level="block", fused=True)
#: Prior state of the art (Ding et al. 2011): non-fused online ABFT.
NONFUSED_BASELINE = FTConfig(action="correct", level="block", fused=False)
#: Fault tolerance disabled.
FT_OFF = FTConfig(action="off")


@dataclasses.dataclass(frozen=True)
class InjectionSpec:
    """A single emulated SEU: flip the accumulator at (row, col) by
    ``magnitude`` after k-step ``k_step`` (paper §5.3: 'errors are inserted in
    the register of the accumulated result by adding a numerical offset')."""
    row: int
    col: int
    magnitude: float
    k_step: int = 0

    def as_tuple(self):
        return (self.row, self.col, self.magnitude, self.k_step)


NO_INJECTION: Optional[InjectionSpec] = None
