"""Fault-tolerance policy configuration.

Mirrors the paper's design space:
  * level   — where checksums are maintained (paper: thread/warp/threadblock;
              here: "inner"/"tile"/"block", see DESIGN.md §2.1). The jnp path
              only distinguishes fused vs non-fused; the Pallas kernel
              implements all three.
  * action  — "correct" = online ABFT (paper §4, detect AND correct on the
              fly); "detect" = offline ABFT (§5.5, detect-only; caller must
              recompute); "off" = no fault tolerance.
  * fused   — True: checksum memory traffic fused with the GEMM (the paper's
              contribution); False: the Ding-2011-style non-fused baseline
              (separate encode / multiply / verify passes over HBM).
  * verify  — "step": verify every k-step (online, corrects one SEU per
              interval → many per GEMM); "final": verify once per output tile.

PR 10 adds the *per-site* layer on top of the single `FTConfig`:

  * `FTPolicy` — ordered (site-pattern → FTConfig) override rules with a
    default fallthrough. Everything that used to take one `FTConfig`
    (`Ctx.ft`, `RunConfig.ft`, the `core.ft_gemm` dispatch fronts) now
    accepts an FTConfig OR an FTPolicy; `resolve_ft(ft, site)` is the one
    coercion point. A bare FTConfig resolves to itself for every site, so
    legacy configs are bit-identical by construction.
  * `plan_ft` — the static planner: per-site roofline-predicted FT overhead
    (memory-bound sites absorb checksum FLOPs nearly free — Kosaian &
    Rashmi, arXiv 2104.09455) drives a greedy
    overhead-per-protected-FLOP assignment under a global overhead budget.
  * `EscalationController` — the runtime loop closure: subscribes to
    `telemetry.StormDetector.on_alert` and promotes a storming site
    (detect→correct, final→step) for a cool-down window; `current_policy()`
    returns a fresh frozen policy, so jit retraces exactly when the
    resolved level actually changes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import json
from typing import Dict, List, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class FTConfig:
    action: str = "correct"          # "off" | "detect" | "correct"
    level: str = "block"             # "inner" | "tile" | "block"
    fused: bool = True               # False = Ding-2011 non-fused baseline
    verify: str = "step"             # "step" | "final"
    # Relative checksum tolerance multiplier. The absolute threshold is
    #   tau = rel_tau * eps(dtype) * K * max|A| * max|B|
    # (a standard ABFT rounding bound; rel_tau absorbs the constants).
    rel_tau: float = 64.0
    # Accumulate checksums in f32 even for bf16 GEMMs.
    checksum_dtype: str = "float32"
    # Protect batched attention GEMMs (QK^T, PV) too.
    protect_attention: bool = True
    # Backend for the local GEMM: "xla" (jnp, GSPMD-friendly) or "pallas".
    backend: str = "xla"
    # Optional static detection threshold. None ⇒ dynamic rounding-aware
    # threshold (costs a max-reduction over each operand). A hillclimb lever:
    # a calibrated static tau removes two operand passes per GEMM.
    static_tau: Optional[float] = None
    # Stochastic SEU injection (error-injection campaigns; 0.0 = off).
    # Probability that a given protected GEMM suffers one flipped accumulator
    # element this step, when an injection key is supplied.
    inject_rate: float = 0.0
    inject_bit_shift: int = 8

    @property
    def enabled(self) -> bool:
        return self.action != "off"

    @property
    def corrects(self) -> bool:
        return self.action == "correct"

    def replace(self, **kw) -> "FTConfig":
        return dataclasses.replace(self, **kw)


#: Paper's flagship configuration — fused threadblock-level online ABFT.
ONLINE_BLOCK = FTConfig(action="correct", level="block", fused=True)
#: Offline (detect-only) ABFT of §5.5.
OFFLINE_DETECT = FTConfig(action="detect", level="block", fused=True)
#: Prior state of the art (Ding et al. 2011): non-fused online ABFT.
NONFUSED_BASELINE = FTConfig(action="correct", level="block", fused=False)
#: Fault tolerance disabled.
FT_OFF = FTConfig(action="off")


@dataclasses.dataclass(frozen=True)
class InjectionSpec:
    """A single emulated SEU: flip the accumulator at (row, col) by
    ``magnitude`` after k-step ``k_step`` (paper §5.3: 'errors are inserted in
    the register of the accumulated result by adding a numerical offset')."""
    row: int
    col: int
    magnitude: float
    k_step: int = 0

    def as_tuple(self):
        return (self.row, self.col, self.magnitude, self.k_step)


NO_INJECTION: Optional[InjectionSpec] = None


# ---------------------------------------------------------------------------
# per-site policy (PR 10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FTPolicy:
    """Ordered site-pattern → `FTConfig` override rules.

    ``rules`` is an ordered tuple of ``(pattern, FTConfig)`` pairs; patterns
    are `fnmatch`-style globs over the PR-8 site registry labels
    (``"moe_gate"``, ``"attn_*"``, ``"dec_?k"``, …). `resolve` returns the
    FIRST matching rule's config, falling through to ``default``; a ``None``
    site (an unlabelled call) resolves to the default. Frozen and hashable,
    so a policy can ride `Ctx`/`RunConfig` straight into jit static
    arguments — promoting a site produces a *different* policy object and
    therefore a retrace, which is exactly how a runtime escalation switches
    the compiled kernels.

        FTPolicy(rules=(("moe_gate", ONLINE_BLOCK),
                        ("attn_*", OFFLINE_DETECT.replace(verify="final"))),
                 default=FT_OFF)
    """
    rules: Tuple[Tuple[str, FTConfig], ...] = ()
    default: FTConfig = ONLINE_BLOCK

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(
            (str(p), c) for p, c in self.rules))
        for pat, cfg in self.rules:
            if not isinstance(cfg, FTConfig):
                raise TypeError(f"rule {pat!r} maps to {type(cfg).__name__}, "
                                f"expected FTConfig")
        if not isinstance(self.default, FTConfig):
            raise TypeError("FTPolicy.default must be an FTConfig, got "
                            f"{type(self.default).__name__}")

    @staticmethod
    def uniform(ft: "FTConfig") -> "FTPolicy":
        """A rule-free policy: every site resolves to ``ft`` — behaviorally
        identical to threading the bare FTConfig."""
        return FTPolicy(rules=(), default=ft)

    def resolve(self, site: Optional[str]) -> FTConfig:
        if site is not None:
            for pat, cfg in self.rules:
                if fnmatch.fnmatchcase(site, pat):
                    return cfg
        return self.default

    def override(self, *rules: Tuple[str, FTConfig]) -> "FTPolicy":
        """A new policy with ``rules`` PREPENDED (they win over existing
        ones — first match takes precedence)."""
        return FTPolicy(rules=tuple(rules) + self.rules, default=self.default)

    def resolved_table(self, sites: Sequence[str]) -> Dict[str, FTConfig]:
        return {s: self.resolve(s) for s in sites}


FTLike = Union[FTConfig, FTPolicy]


def resolve_ft(ft: FTLike, site: Optional[str]) -> FTConfig:
    """THE per-site resolution point: FTConfig-or-FTPolicy → FTConfig.

    A bare FTConfig is returned unchanged (legacy behavior, bit-identical
    including tune-cache keys); a policy resolves the site label against its
    rules. Every dispatch front (`core.ft_gemm`, `kernels.ops`,
    `kernels.grouped.dispatch`, `models.blocks.Ctx`) calls this before any
    spec/params derivation, so the resolved per-site level flows into the
    existing template and autotune cache keys untouched."""
    if isinstance(ft, FTPolicy):
        return ft.resolve(site)
    return ft


def as_policy(ft: FTLike) -> FTPolicy:
    return ft if isinstance(ft, FTPolicy) else FTPolicy.uniform(ft)


def promote(ft: FTConfig) -> FTConfig:
    """Storm promotion: detect→correct and final→step. An "off" site stays
    off (it produces no detections, so it cannot storm — promoting it would
    silently change coverage outside the planner's budget)."""
    if not ft.enabled:
        return ft
    return ft.replace(action="correct", verify="step")


class EscalationController:
    """Runtime storm→policy loop closure (the PR-8 follow-on).

    Subscribes to `telemetry.StormDetector.on_alert` (directly or through
    `tools.metrics.MetricsSink.on_storm`): an alert PROMOTES the storming
    site (`promote`: detect→correct, final→step) for ``cooldown_steps``
    steps. `current_policy()` returns the base policy with one prepended
    rule per live promotion — a fresh frozen `FTPolicy`, so feeding it to a
    jitted step retraces iff the promotion set changed (`version` ticks on
    every change; cache it to skip rebuilding).

        detector = telemetry.StormDetector()
        esc = EscalationController(run.ft, cooldown_steps=32).attach(detector)
        ...
        detector.observe(step, site_counts)       # may fire -> promote
        loss = train_step(params, batch, esc.current_policy())
        esc.step_end(step)                        # expire cool-downs
    """

    def __init__(self, policy: FTLike, *, cooldown_steps: int = 64):
        self.base = as_policy(policy)
        self.cooldown_steps = int(cooldown_steps)
        self._promoted: Dict[str, int] = {}      # site -> expiry step
        self.version = 0

    def attach(self, detector) -> "EscalationController":
        """Subscribe to anything exposing ``on_alert(cb)`` (StormDetector)
        or ``on_storm(cb)`` (MetricsSink)."""
        sub = getattr(detector, "on_alert", None) or getattr(
            detector, "on_storm", None)
        if sub is None:
            raise TypeError(f"{type(detector).__name__} has neither "
                            f"on_alert nor on_storm")
        sub(self.handle_alert)
        return self

    def handle_alert(self, alert) -> None:
        base = self.base.resolve(alert.site)
        if promote(base) == base:
            return                               # already as strong as it gets
        expiry = int(alert.step) + self.cooldown_steps
        if self._promoted.get(alert.site) != expiry:
            self._promoted[alert.site] = expiry
            self.version += 1

    def step_end(self, step: int) -> None:
        expired = [s for s, e in self._promoted.items() if step >= e]
        for s in expired:
            del self._promoted[s]
        if expired:
            self.version += 1

    @property
    def promoted_sites(self) -> Dict[str, int]:
        return dict(self._promoted)

    def current_policy(self) -> FTPolicy:
        if not self._promoted:
            return self.base
        rules = tuple((site, promote(self.base.resolve(site)))
                      for site in sorted(self._promoted))
        return self.base.override(*rules)


# ---------------------------------------------------------------------------
# static planner: roofline-budgeted per-site FT levels
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SiteCost:
    """One aggregated GEMM population at a site, recorded at trace time by
    the `core.ft_gemm` / `models.blocks` dispatch fronts under
    `record_site_costs` (shapes are static, so `jax.eval_shape` is enough
    to collect them — no compute)."""
    site: str
    kind: str          # "2d" | "fused" | "batched" | "grouped" | "tgmm" | "flash"
    m: int
    n: int
    k: int
    batch: int = 1
    in_bytes: int = 4
    count: int = 1

    @property
    def flops(self) -> float:
        from repro.kernels import search
        return self.count * search.ft_plan_base(
            self.kind, self.m, self.n, self.k, self.batch, self.in_bytes)[0]

    def times(self, action: str, verify: str) -> Tuple[float, float]:
        """(base_time_s, ft_overhead_time_s) for this population under the
        given rung — `kernels.search.ft_plan_cost`'s roofline delta."""
        from repro.kernels import search
        base, over = search.ft_plan_cost(
            self.kind, self.m, self.n, self.k, self.batch, self.in_bytes,
            action=action, verify=verify)
        return self.count * base, self.count * over


_SITE_COSTS: Optional[Dict[tuple, SiteCost]] = None


@contextlib.contextmanager
def record_site_costs():
    """Collect `SiteCost` records from every protected dispatch front
    traced inside the context. Yields the dict; use with `jax.eval_shape`:

        with policy.record_site_costs() as costs:
            jax.eval_shape(loss_fn, params, batch)
        plan = policy.plan_ft(costs.values(), budget_frac=0.05)
    """
    global _SITE_COSTS
    prev, _SITE_COSTS = _SITE_COSTS, {}
    try:
        yield _SITE_COSTS
    finally:
        _SITE_COSTS = prev


def note_site(site: Optional[str], kind: str, m: int, n: int, k: int, *,
              batch: int = 1, in_bytes: int = 4) -> None:
    """Dispatch-front hook: record one launch's geometry (no-op unless a
    `record_site_costs` context is open and the call is site-labelled)."""
    if _SITE_COSTS is None or site is None:
        return
    key = (site, kind, int(m), int(n), int(k), int(batch), int(in_bytes))
    rec = _SITE_COSTS.get(key)
    if rec is None:
        _SITE_COSTS[key] = SiteCost(site, kind, int(m), int(n), int(k),
                                    int(batch), int(in_bytes))
    else:
        rec.count += 1


#: Protection rungs, weakest→strongest. Coverage means ≥ the first rung;
#: later rungs only strengthen an already-covered site.
LADDER: Tuple[Tuple[str, str], ...] = (
    ("detect", "final"), ("correct", "final"), ("correct", "step"))


@dataclasses.dataclass(frozen=True)
class SitePlan:
    site: str
    flops: float
    base_time_s: float
    action: str               # "off" | "detect" | "correct"
    verify: str
    overhead_s: float


@dataclasses.dataclass(frozen=True)
class FTPlan:
    """`plan_ft`'s result: the policy plus its predicted economics."""
    policy: FTPolicy
    budget_frac: float
    base_time_s: float
    overhead_s: float
    coverage: float                    # protected flops / total site flops
    sites: Tuple[SitePlan, ...]

    @property
    def overhead_frac(self) -> float:
        return self.overhead_s / self.base_time_s if self.base_time_s else 0.0

    def to_json(self) -> str:
        return json.dumps({
            "budget_frac": self.budget_frac,
            "base_time_s": self.base_time_s,
            "overhead_s": self.overhead_s,
            "overhead_frac": self.overhead_frac,
            "coverage": self.coverage,
            "sites": [dataclasses.asdict(s) for s in self.sites],
        }, indent=2, sort_keys=True)


def _aggregate(costs: Sequence[SiteCost]) -> Dict[str, List[SiteCost]]:
    by_site: Dict[str, List[SiteCost]] = {}
    for c in costs:
        by_site.setdefault(c.site, []).append(c)
    return by_site


def plan_ft(costs: Sequence[SiteCost], *, budget_frac: float = 0.05,
            base: FTConfig = ONLINE_BLOCK) -> FTPlan:
    """Assign each site the strongest FT rung fitting under a global
    predicted-overhead budget (``budget_frac`` of the un-protected roofline
    step time).

    Greedy by predicted overhead-per-protected-FLOP, in two prefix-stopped
    phases: (1) COVERAGE — sites gain the cheapest rung (detect/final) in
    ascending cost-per-FLOP order until the first unaffordable site, then
    stop; (2) STRENGTH — covered sites upgrade rung-by-rung (correct/final,
    then correct/step), cheapest upgrade first, stopping at the first
    unaffordable upgrade. Prefix-stopping (never skip-and-continue) makes
    the plan monotone in the budget: a larger budget always yields a
    superset of coverage and, per site, an equal-or-stronger rung.

    The returned policy carries one exact-label rule per protected site
    (``base`` with the planned action/verify) over an "off" default, so an
    unplanned site label falls through to unprotected — the budget stays
    honest at runtime."""
    by_site = _aggregate(costs)
    if not by_site:
        return FTPlan(FTPolicy(rules=(), default=base.replace(action="off")),
                      budget_frac, 0.0, 0.0, 0.0, ())

    flops = {s: sum(c.flops for c in recs) for s, recs in by_site.items()}
    base_t = {s: sum(c.times("off", "final")[0] for c in recs)
              for s, recs in by_site.items()}
    over = {s: {rung: sum(c.times(*rung)[1] for c in recs)
                for rung in LADDER}
            for s, recs in by_site.items()}
    total_flops = sum(flops.values())
    total_base = sum(base_t.values())
    budget_s = budget_frac * total_base

    level: Dict[str, int] = {}         # site -> index into LADDER
    spent = 0.0

    # Phase 1 — coverage (prefix-stop on the first unaffordable site).
    first = LADDER[0]
    order = sorted(by_site, key=lambda s: (over[s][first] / max(flops[s], 1.0),
                                           s))
    for s in order:
        cost = over[s][first]
        if spent + cost > budget_s:
            break
        level[s] = 0
        spent += cost

    # Phase 2 — strength upgrades (prefix-stop on the first unaffordable).
    while True:
        candidates = []
        for s, li in level.items():
            if li + 1 < len(LADDER):
                delta = over[s][LADDER[li + 1]] - over[s][LADDER[li]]
                candidates.append((max(delta, 0.0) / max(flops[s], 1.0),
                                   s, delta))
        if not candidates:
            break
        _, s, delta = min(candidates)
        if spent + delta > budget_s:
            break
        level[s] += 1
        spent += delta

    plans = []
    for s in sorted(by_site):
        if s in level:
            action, verify = LADDER[level[s]]
            ovh = over[s][LADDER[level[s]]]
        else:
            action, verify, ovh = "off", base.verify, 0.0
        plans.append(SitePlan(s, flops[s], base_t[s], action, verify, ovh))

    rules = tuple((p.site, base.replace(action=p.action, verify=p.verify))
                  for p in plans if p.action != "off")
    policy = FTPolicy(rules=rules, default=base.replace(action="off"))
    covered = sum(p.flops for p in plans if p.action != "off")
    return FTPlan(policy, budget_frac, total_base, spent,
                  covered / total_flops if total_flops else 0.0,
                  tuple(plans))


def uniform_overhead_s(costs: Sequence[SiteCost], *,
                       action: str = "correct",
                       verify: str = "step") -> float:
    """Predicted overhead of protecting EVERY site at one rung — the
    uniform-`correct` bar the planned policy must beat at equal coverage."""
    return sum(c.times(action, verify)[1] for c in costs)


def pareto_curve(costs: Sequence[SiteCost],
                 budgets: Sequence[float] = (0.005, 0.01, 0.02, 0.03, 0.05,
                                             0.08, 0.12, 0.2),
                 *, base: FTConfig = ONLINE_BLOCK) -> List[FTPlan]:
    """Coverage-vs-overhead Pareto sweep: one `plan_ft` per budget point
    (monotone by construction — see `plan_ft`)."""
    return [plan_ft(costs, budget_frac=b, base=base) for b in budgets]
