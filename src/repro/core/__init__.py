"""repro.core — the paper's contribution as a composable JAX module.

Online algorithm-based fault tolerance (ABFT) for GEMM: detection AND
correction of compute-unit soft errors, fused with the GEMM itself
(ICS'23: "Anatomy of High-Performance GEMM with Online Fault Tolerance").
"""
from .policy import (FTConfig, FTPolicy, InjectionSpec, ONLINE_BLOCK,
                     OFFLINE_DETECT, NONFUSED_BASELINE, FT_OFF,
                     resolve_ft, promote, EscalationController,
                     plan_ft, FTPlan, SiteCost, note_site,
                     record_site_costs, pareto_curve, uniform_overhead_s)
from .ft_gemm import (ft_dot, ft_dot_fused, ft_batched_dot,
                      ft_grouped_matmul, ft_grouped_matmul_buffer,
                      ft_verdict_dot, grouped_row_tile)
from .telemetry import FTReport, ft_scope, current_scope
from . import abft
from .fault_injection import Injector

__all__ = [
    "FTConfig", "FTPolicy", "InjectionSpec", "ONLINE_BLOCK", "OFFLINE_DETECT",
    "NONFUSED_BASELINE", "FT_OFF", "resolve_ft", "promote",
    "EscalationController", "plan_ft", "FTPlan", "SiteCost", "note_site",
    "record_site_costs", "pareto_curve", "uniform_overhead_s",
    "ft_dot", "ft_dot_fused",
    "ft_batched_dot", "ft_grouped_matmul", "ft_grouped_matmul_buffer",
    "grouped_row_tile",
    "ft_verdict_dot", "FTReport", "ft_scope", "current_scope", "abft",
    "Injector",
]
