"""ABFT error telemetry — per-site attribution, SDC-storm detection.

Every `ft_dot`/`ft_einsum` call site contributes a (detected, corrected)
counter pair. Inside jit we cannot mutate Python state, so call sites return
their verdicts and the step function aggregates them into an `FTReport`
pytree that crosses the jit boundary once per step — at 1000+ node scale
this is the signal SREs alert on (SDC storms on a failing part are a real
phenomenon).

Since PR 8 the report is *attributed*: every protected call site carries a
structured label (``"w_gate"``, ``"attn_qk"``, ``"moe_down"`` …) that a
trace-time **site registry** maps to a stable small-integer id, and the
report carries fixed-width site-indexed counter vectors next to the scalar
totals. The width is ``site_capacity()`` — a static constant, NOT the
current registry size — so the pytree structure is identical everywhere in
a trace (scan carries, remat bodies, custom_vjp aux outputs) regardless of
registration order. Scanned layer stacks place each layer's site vector at
its own row (``merge_at``), so the per-step report resolves ``(layer,
site)`` pairs: row 0 is the un-layered residue (lm-head, embeddings), row
``1 + i`` is layer ``i``.

Scalar totals are computed by exactly the same reduction sequence as
before the attribution work, so the global triple stays bit-identical —
the conformance suite asserts ``sum(site_detected) == detected``.

The host side of the pipeline lives in `repro.tools.metrics` (step-boundary
sink, JSONL/stdout/in-memory emitters); the `StormDetector` here is the
sliding-window per-site rate alarm it feeds — the runtime signal the
adaptive-FT policy (`core.policy`, ROADMAP direction 3) subscribes to.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from collections import deque
from typing import (Any, Callable, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# site registry
# ---------------------------------------------------------------------------

#: Fixed width of the site axis of every report. A *static* constant: the
#: report pytree must have identical structure at every point of a trace
#: (scan carry init happens before the body registers its sites), so the
#: width cannot follow the registry size. Slot 0 is reserved for
#: unattributed records; the last slot aliases every registration past
#: capacity (the "_overflow" bucket) instead of growing the vector.
_SITE_CAPACITY = 64

#: Trace-time switch: with attribution off the site axis collapses to
#: width 1 (every record lands in the unattributed slot) — the
#: "global-triple" baseline `benchmarks/telemetry_overhead.py` compares
#: against. Toggle via `site_attribution(False)`.
_ATTRIBUTION = True

UNATTRIBUTED = "_unattributed"
OVERFLOW = "_overflow"


class SiteRegistry:
    """Label ↔ id map for protected call sites. Ids are assigned in first-
    registration order and stay stable for the process lifetime (they are
    baked into traced programs). The JSONL sink writes *labels*, so
    cross-process stability comes from labels, not ids."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._labels: List[str] = [UNATTRIBUTED]
        self._ids: Dict[str, int] = {UNATTRIBUTED: 0}

    def site(self, label: str) -> int:
        sid = self._ids.get(label)
        if sid is not None:
            return sid
        if len(self._labels) >= self.capacity - 1:
            # capacity-1 real slots + the overflow alias at capacity-1
            if OVERFLOW not in self._ids:
                self._ids[OVERFLOW] = self.capacity - 1
            return self.capacity - 1
        sid = len(self._labels)
        self._labels.append(label)
        self._ids[label] = sid
        return sid

    def label(self, sid: int) -> str:
        if sid < len(self._labels):
            return self._labels[sid]
        if sid == self.capacity - 1 and OVERFLOW in self._ids:
            return OVERFLOW
        return f"_site{sid}"

    def labels(self) -> List[str]:
        return list(self._labels)


_REGISTRY = SiteRegistry(_SITE_CAPACITY)


def registry() -> SiteRegistry:
    return _REGISTRY


def site_capacity() -> int:
    return _SITE_CAPACITY


def site_width() -> int:
    """Static width of the report's site axis under the current attribution
    mode (capacity, or 1 for the global-triple baseline)."""
    return _SITE_CAPACITY if _ATTRIBUTION else 1


def site_id(label: Optional[str]) -> int:
    """Register-or-look-up a site label → stable id (trace time only)."""
    if label is None or not _ATTRIBUTION:
        return 0
    return _REGISTRY.site(label)


def site_label(sid: int) -> str:
    return _REGISTRY.label(sid) if _ATTRIBUTION else UNATTRIBUTED


def site_labels() -> List[str]:
    """Currently registered labels, index-aligned with site ids."""
    return _REGISTRY.labels() if _ATTRIBUTION else [UNATTRIBUTED]


def reset_sites(capacity: Optional[int] = None) -> None:
    """Reset the registry (tests). Changing capacity invalidates any report
    produced under the old width — do not mix across a single trace, and
    re-trace (fresh jit) anything that recorded sites before the reset."""
    global _REGISTRY, _SITE_CAPACITY
    if capacity is not None:
        _SITE_CAPACITY = capacity
    _REGISTRY = SiteRegistry(_SITE_CAPACITY)


@contextlib.contextmanager
def site_attribution(enabled: bool = True):
    """Trace-time context: disable per-site attribution (width-1 site axis,
    the pre-PR-8 global-triple behaviour) for A/B overhead measurement."""
    global _ATTRIBUTION
    prev = _ATTRIBUTION
    _ATTRIBUTION = enabled
    try:
        yield
    finally:
        _ATTRIBUTION = prev


# ---------------------------------------------------------------------------
# report pytree
# ---------------------------------------------------------------------------


class FTReport(NamedTuple):
    # Counters are carried as f32, not int32: reports thread through
    # scan carries and jax.checkpoint regions inside differentiated step
    # functions, and integer leaves there get `float0` tangents that remat's
    # jvp instantiates and then cannot add. Float counters have ordinary
    # zero tangents; consumers `int(...)`-cast at the edge.
    detected: jax.Array    # f32 count — call sites that flagged an error
    corrected: jax.Array   # f32 count — corrections applied
    max_residual: jax.Array  # f32 — worst |δ| observed (0 when clean)
    # Per-site attribution (PR 8): (rows, site_width()) f32 matrices. Row 0
    # is unlayered; row 1+i is layer i (see `merge_at`). Column j is the
    # site with id j in the registry. Totals above remain the single source
    # of truth for the global counts (bit-identical to the pre-attribution
    # reduction); the site matrices decompose them.
    site_detected: jax.Array
    site_corrected: jax.Array
    site_max_residual: jax.Array

    @staticmethod
    def empty(rows: int = 1) -> "FTReport":
        z = jnp.zeros((), jnp.float32)
        zs = jnp.zeros((rows, site_width()), jnp.float32)
        return FTReport(z, z, jnp.zeros((), jnp.float32), zs, zs, zs)

    @property
    def n_rows(self) -> int:
        return self.site_detected.shape[-2]

    def expand_rows(self, rows: int) -> "FTReport":
        """Zero-pad the site matrices to `rows` rows (row semantics are
        absolute, so padding at the bottom preserves alignment)."""
        have = self.n_rows
        if have == rows:
            return self
        if have > rows:
            raise ValueError(f"cannot shrink report rows {have} -> {rows}")
        pad = [(0, 0)] * (self.site_detected.ndim - 2) + [(0, rows - have),
                                                          (0, 0)]
        return self._replace(
            site_detected=jnp.pad(self.site_detected, pad),
            site_corrected=jnp.pad(self.site_corrected, pad),
            site_max_residual=jnp.pad(self.site_max_residual, pad))

    def merge(self, other: "FTReport") -> "FTReport":
        rows = max(self.n_rows, other.n_rows)
        a, b = self.expand_rows(rows), other.expand_rows(rows)
        return FTReport(
            detected=a.detected + b.detected,
            corrected=a.corrected + b.corrected,
            max_residual=jnp.maximum(a.max_residual, b.max_residual),
            site_detected=a.site_detected + b.site_detected,
            site_corrected=a.site_corrected + b.site_corrected,
            site_max_residual=jnp.maximum(a.site_max_residual,
                                          b.site_max_residual))

    def merge_at(self, other: "FTReport", row) -> "FTReport":
        """Merge `other` (a single-row report, e.g. one scanned layer's
        `scoped` result) with its site row placed at row `row` of self —
        `row` may be traced (the scan's layer index): this is how a scanned
        stack contributes (layer, site)-resolved rows through the carry."""
        if other.n_rows != 1:
            raise ValueError("merge_at expects a single-row report "
                             f"(got {other.n_rows} rows)")
        row = jnp.asarray(row, jnp.int32)
        return FTReport(
            detected=self.detected + other.detected,
            corrected=self.corrected + other.corrected,
            max_residual=jnp.maximum(self.max_residual, other.max_residual),
            site_detected=self.site_detected.at[row].add(
                other.site_detected[0]),
            site_corrected=self.site_corrected.at[row].add(
                other.site_corrected[0]),
            site_max_residual=self.site_max_residual.at[row].max(
                other.site_max_residual[0]))


def reduce_microbatch(stacked: FTReport) -> FTReport:
    """Collapse a leading microbatch/stack axis (e.g. the metrics pytree a
    gradient-accumulation `scan` returns): counters SUM across microbatches
    — they are event counts, not rates — and residuals take the max.
    (The old dtype-keyed sum-vs-mean branch silently *averaged* the f32
    counters; see train_loop.)"""
    return FTReport(
        detected=jnp.sum(stacked.detected, axis=0),
        corrected=jnp.sum(stacked.corrected, axis=0),
        max_residual=jnp.max(stacked.max_residual, axis=0),
        site_detected=jnp.sum(stacked.site_detected, axis=0),
        site_corrected=jnp.sum(stacked.site_corrected, axis=0),
        site_max_residual=jnp.max(stacked.site_max_residual, axis=0))


def site_rows(report: FTReport, *, include_zero: bool = False
              ) -> List[Dict[str, Any]]:
    """Host-side decode of a materialized report's site matrices into
    [{site, layer, detected, corrected, max_residual}] rows. `layer` is
    None for row 0 (unlayered) and i for row 1+i. Zero rows are dropped
    unless `include_zero`."""
    import numpy as np
    det = np.asarray(report.site_detected, np.float64)
    cor = np.asarray(report.site_corrected, np.float64)
    mr = np.asarray(report.site_max_residual, np.float64)
    det = det.reshape(-1, det.shape[-1]) if det.ndim > 2 else det
    labels = site_labels()
    rows: List[Dict[str, Any]] = []
    for r in range(det.shape[0]):
        for s in range(det.shape[1]):
            if not include_zero and det[r, s] == 0 and cor[r, s] == 0 \
                    and mr[r, s] == 0:
                continue
            rows.append({
                "site": labels[s] if s < len(labels) else site_label(s),
                "layer": None if r == 0 else r - 1,
                "detected": float(det[r, s]),
                "corrected": float(cor[r, s]),
                "max_residual": float(mr[r, s]),
            })
    return rows


# ---------------------------------------------------------------------------
# trace-time collection
# ---------------------------------------------------------------------------

# One recorded item: (site_id, detected_f32, corrected_f32, maxres_f32) —
# assembled into the report's scalar totals with exactly the pre-attribution
# reduction sequence, plus a scatter into the site matrices.
_Item = Tuple[int, jax.Array, jax.Array, jax.Array]


class FTScope:
    """Trace-time collector. Model code calls `scope.record(verdict,
    corrected=..., site=...)`; the step function materializes
    `scope.report()`.

    Thread-compatible with jit tracing: a fresh scope is created per trace.
    """

    def __init__(self) -> None:
        self._items: List[Union[_Item, FTReport]] = []

    def record(self, detected: jax.Array, magnitude: jax.Array,
               corrected: bool, site: Optional[str] = None) -> None:
        # Telemetry is diagnostics, not a differentiable quantity:
        # stop_gradient here so reports threading scan carries / remat
        # regions never send (even materialized-zero) cotangents back into
        # the FT custom_vjps — whose bwd rules fail loudly on real ones.
        detected = jax.lax.stop_gradient(detected)
        magnitude = jax.lax.stop_gradient(magnitude)
        det_any = jnp.any(detected)
        d = det_any.astype(jnp.float32)
        c = d if corrected else jnp.zeros((), jnp.float32)
        mr = jnp.max(jnp.abs(magnitude)).astype(jnp.float32)
        self._items.append((site_id(site), d, c, mr))

    def record_summary(self, det_count: jax.Array, max_residual: jax.Array,
                       corrected: bool, site: Optional[str] = None) -> None:
        """Record a pre-reduced (count, max|δ|) summary (the form returned
        across the custom_vjp boundary by ft_dot). stop_gradient'ed like
        `record` — see the comment there."""
        d = jax.lax.stop_gradient(det_count).astype(jnp.float32)
        c = d if corrected else jnp.zeros((), jnp.float32)
        mr = jax.lax.stop_gradient(max_residual).astype(jnp.float32)
        self._items.append((site_id(site), d, c, mr))

    def report(self) -> FTReport:
        rep = FTReport.empty()
        w = site_width()
        for item in self._items:
            if isinstance(item, FTReport):
                rep = rep.merge(item)
                continue
            sid, d, c, mr = item
            z = jnp.zeros((1, w), jnp.float32)
            rep = rep.merge(FTReport(
                detected=d, corrected=c, max_residual=mr,
                site_detected=z.at[0, sid].add(d),
                site_corrected=z.at[0, sid].add(c),
                site_max_residual=z.at[0, sid].max(mr)))
        return rep


# A module-level "ambient" scope stack so model code doesn't need to thread
# the scope through every layer. jit-trace-safe: push/pop happen at trace time.
_SCOPES: List[FTScope] = []


def push_scope() -> FTScope:
    s = FTScope()
    _SCOPES.append(s)
    return s


def pop_scope() -> FTScope:
    return _SCOPES.pop()


def current_scope() -> FTScope | None:
    return _SCOPES[-1] if _SCOPES else None


class ft_scope:
    """Context manager: `with ft_scope() as s: ...; rep = s.report()`."""

    def __enter__(self) -> FTScope:
        return push_scope()

    def __exit__(self, *exc: Any) -> None:
        pop_scope()


def record_report(rep: FTReport) -> None:
    """Merge an already-materialized FTReport into the ambient scope (used
    after a scan/remat region returns its scoped report)."""
    s = current_scope()
    if s is not None:
        s._items.append(rep)


def scoped(fn):
    """Run `fn()` under a fresh FTScope and return (result, FTReport).

    This is how telemetry crosses scan/remat boundaries: the scope lives and
    dies *inside* the traced body (no tracers escape); the materialized
    FTReport is threaded through the scan carry by the caller. Model layer
    scans use this so a 94-layer model still reports per-step SDC counts —
    and, with per-site attribution, place each layer's single-row report at
    its own row via `FTReport.merge_at(rep_l, 1 + layer_idx)`.
    """
    s = push_scope()
    try:
        out = fn()
    finally:
        pop_scope()
    return out, s.report()


# ---------------------------------------------------------------------------
# SDC-storm detection (host side)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StormAlert:
    """One fired alarm: `site`'s detection rate over the trailing window
    spiked above the cross-site background — the "SDC storm on a failing
    part" signal. Delivered to every registered callback and recorded by
    the metrics sink."""
    site: str
    step: int
    window_steps: int
    detections: float          # detections at `site` over the window
    rate: float                # detections / window step
    background_rate: float     # median per-site rate of the OTHER sites
    threshold_rate: float      # the rate that tripped the alarm


class StormDetector:
    """Sliding-window per-site SDC rate alarm.

    Feed it per-step per-site detection counts (`observe`); it fires a
    `StormAlert` when one site's windowed rate stands out against the
    cross-site background:

        fire iff  window_sum >= min_detections
              and rate >= max(spike_factor * median(other sites' rates),
                              min_detections / window)

    A uniform elevated background (every site detecting at the same rate —
    e.g. a global tau mis-calibration) therefore stays quiet: that is a
    threshold problem, not a failing part. After firing, a site is re-armed
    only after `window` further observed steps, so a sustained storm alerts
    once per window rather than every step.

    Host-side and pure-Python by design — it consumes materialized per-step
    reports at the step boundary (via `tools.metrics.MetricsSink`), never
    traced values. `on_alert` registers a callback: the runtime entry point
    a future adaptive-FT policy subscribes to (promote a storming site's FT
    level; see ROADMAP direction 3).
    """

    def __init__(self, window: int = 16, spike_factor: float = 8.0,
                 min_detections: float = 3.0):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self.spike_factor = spike_factor
        self.min_detections = min_detections
        self._hist: deque = deque(maxlen=window)   # (step, {site: count})
        self._rearm_at: Dict[str, int] = {}        # site -> #obs when re-armed
        self._n_observed = 0
        self._callbacks: List[Callable[[StormAlert], None]] = []
        self.alerts: List[StormAlert] = []

    def on_alert(self, cb: Callable[[StormAlert], None]) -> None:
        self._callbacks.append(cb)

    def observe(self, step: int, site_counts: Mapping[str, float]
                ) -> List[StormAlert]:
        """Push one step's per-site detection counts; returns alerts fired
        by this observation (also delivered to callbacks)."""
        self._hist.append((int(step), dict(site_counts)))
        self._n_observed += 1
        n = len(self._hist)
        sums: Dict[str, float] = {}
        for _, counts in self._hist:
            for site, c in counts.items():
                sums[site] = sums.get(site, 0.0) + float(c)
        if not sums:
            return []
        rates = {site: s / n for site, s in sums.items()}
        fired: List[StormAlert] = []
        for site, total in sums.items():
            if total < self.min_detections:
                continue
            if self._n_observed < self._rearm_at.get(site, 0):
                continue
            others = [r for s, r in rates.items() if s != site]
            bg = _median(others) if others else 0.0
            threshold = max(self.spike_factor * bg,
                            self.min_detections / self.window)
            if rates[site] >= threshold:
                alert = StormAlert(site=site, step=int(step), window_steps=n,
                                   detections=total, rate=rates[site],
                                   background_rate=bg,
                                   threshold_rate=threshold)
                self._rearm_at[site] = self._n_observed + self.window
                self.alerts.append(alert)
                fired.append(alert)
                for cb in self._callbacks:
                    cb(alert)
        return fired


def _median(xs: Sequence[float]) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    m = len(s) // 2
    return s[m] if len(s) % 2 else 0.5 * (s[m - 1] + s[m])
