"""ABFT error telemetry.

Every `ft_dot`/`ft_einsum` call site contributes a (detected, corrected)
counter pair. Inside jit we cannot mutate Python state, so call sites return
their verdicts and the step function aggregates them into an `FTReport` pytree
that crosses the jit boundary once per step — at 1000+ node scale this is the
signal SREs alert on (SDC storms on a failing part are a real phenomenon).
"""
from __future__ import annotations

from typing import Any, List, NamedTuple

import jax
import jax.numpy as jnp


class FTReport(NamedTuple):
    # Counters are carried as f32, not int32: reports thread through
    # scan carries and jax.checkpoint regions inside differentiated step
    # functions, and integer leaves there get `float0` tangents that remat's
    # jvp instantiates and then cannot add. Float counters have ordinary
    # zero tangents; consumers `int(...)`-cast at the edge.
    detected: jax.Array    # f32 count — call sites that flagged an error
    corrected: jax.Array   # f32 count — corrections applied
    max_residual: jax.Array  # f32 — worst |δ| observed (0 when clean)

    @staticmethod
    def empty() -> "FTReport":
        z = jnp.zeros((), jnp.float32)
        return FTReport(z, z, jnp.zeros((), jnp.float32))

    def merge(self, other: "FTReport") -> "FTReport":
        return FTReport(
            detected=self.detected + other.detected,
            corrected=self.corrected + other.corrected,
            max_residual=jnp.maximum(self.max_residual, other.max_residual),
        )


class FTScope:
    """Trace-time collector. Model code calls `scope.record(verdict,
    corrected=...)`; the step function materializes `scope.report()`.

    Thread-compatible with jit tracing: a fresh scope is created per trace.
    """

    def __init__(self) -> None:
        self._items: List[FTReport] = []

    def record(self, detected: jax.Array, magnitude: jax.Array,
               corrected: bool) -> None:
        # Telemetry is diagnostics, not a differentiable quantity:
        # stop_gradient here so reports threading scan carries / remat
        # regions never send (even materialized-zero) cotangents back into
        # the FT custom_vjps — whose bwd rules fail loudly on real ones.
        detected = jax.lax.stop_gradient(detected)
        magnitude = jax.lax.stop_gradient(magnitude)
        det_any = jnp.any(detected)
        d = det_any.astype(jnp.float32)
        self._items.append(FTReport(
            detected=d,
            corrected=d if corrected else jnp.zeros((), jnp.float32),
            max_residual=jnp.max(jnp.abs(magnitude)).astype(jnp.float32),
        ))

    def record_summary(self, det_count: jax.Array, max_residual: jax.Array,
                       corrected: bool) -> None:
        """Record a pre-reduced (count, max|δ|) summary (the form returned
        across the custom_vjp boundary by ft_dot). stop_gradient'ed like
        `record` — see the comment there."""
        d = jax.lax.stop_gradient(det_count).astype(jnp.float32)
        self._items.append(FTReport(
            detected=d,
            corrected=d if corrected else jnp.zeros((), jnp.float32),
            max_residual=jax.lax.stop_gradient(max_residual)
            .astype(jnp.float32),
        ))

    def report(self) -> FTReport:
        rep = FTReport.empty()
        for item in self._items:
            rep = rep.merge(item)
        return rep


# A module-level "ambient" scope stack so model code doesn't need to thread
# the scope through every layer. jit-trace-safe: push/pop happen at trace time.
_SCOPES: List[FTScope] = []


def push_scope() -> FTScope:
    s = FTScope()
    _SCOPES.append(s)
    return s


def pop_scope() -> FTScope:
    return _SCOPES.pop()


def current_scope() -> FTScope | None:
    return _SCOPES[-1] if _SCOPES else None


class ft_scope:
    """Context manager: `with ft_scope() as s: ...; rep = s.report()`."""

    def __enter__(self) -> FTScope:
        return push_scope()

    def __exit__(self, *exc: Any) -> None:
        pop_scope()


def record_report(rep: FTReport) -> None:
    """Merge an already-materialized FTReport into the ambient scope (used
    after a scan/remat region returns its scoped report)."""
    s = current_scope()
    if s is not None:
        s._items.append(rep)


def scoped(fn):
    """Run `fn()` under a fresh FTScope and return (result, FTReport).

    This is how telemetry crosses scan/remat boundaries: the scope lives and
    dies *inside* the traced body (no tracers escape); the materialized
    FTReport is threaded through the scan carry by the caller. Model layer
    scans use this so a 94-layer model still reports per-step SDC counts.
    """
    s = push_scope()
    try:
        out = fn()
    finally:
        pop_scope()
    return out, s.report()
