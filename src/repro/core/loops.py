"""Scan wrapper with an ambient unroll switch.

XLA's cost_analysis (and the HLO text) count a while-loop body ONCE, not
× trip count. The dry-run therefore compiles shallow depth probes with every
model scan *unrolled* (straight-line HLO) so per-layer FLOPs/bytes/collective
deltas are exact; production lowering keeps rolled scans (O(1) HLO size in
depth). Models call `loops.scan` instead of `jax.lax.scan`.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


def _unroll() -> bool:
    return getattr(_state, "unroll", False)


@contextlib.contextmanager
def unrolled_scans(enable: bool = True):
    prev = _unroll()
    _state.unroll = enable
    try:
        yield
    finally:
        _state.unroll = prev


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if _unroll() else 1)
