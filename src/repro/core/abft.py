"""Checksum algebra for algorithm-based fault tolerance (Huang–Abraham 1984,
as used by the paper).

All functions are pure jnp and shape-polymorphic; they are used by
  * the distributed jnp ABFT path (core/ft_gemm.py),
  * the Pallas kernel oracles (kernels/ref.py),
  * tests (hypothesis property tests of the checksum invariants).

Conventions (paper Eq. 1–3):
    A : (M, K)        A^c = [A ; e^T A]   — column checksum, shape (1, K)·... → (1, N) after multiply
    B : (K, N)        B^r = [B , B e]     — row checksum
    C = A @ B         C^c = e^T C = (e^T A) @ B   (1, N)
                      C^r = C e   = A @ (B e)     (M, 1)

Detection compares colsum(C) against C^c and rowsum(C) against C^r.
Under the SEU model a single corrupted element (r, c, δ) shifts exactly
C^c[c] by δ and C^r[r] by δ, so the error is located by the argmax of the
two residuals and corrected by subtracting δ.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def encode_col(a: jax.Array, dtype=jnp.float32) -> jax.Array:
    """e^T A — column-checksum encoding of the left operand. (…, M, K) → (…, 1, K)."""
    return jnp.sum(a.astype(dtype), axis=-2, keepdims=True)


def encode_row(b: jax.Array, dtype=jnp.float32) -> jax.Array:
    """B e — row-checksum encoding of the right operand. (…, K, N) → (…, K, 1)."""
    return jnp.sum(b.astype(dtype), axis=-1, keepdims=True)


class Checksums(NamedTuple):
    col: jax.Array   # (…, 1, N)  = (e^T A) @ B
    row: jax.Array   # (…, M, 1)  = A @ (B e)


def product_checksums(a: jax.Array, b: jax.Array, dtype=jnp.float32) -> Checksums:
    """Reference checksums of C = A @ B computed from the *operands*
    (never touching C) — this is what the fused kernel maintains online."""
    col = jnp.matmul(encode_col(a, dtype), b.astype(dtype))
    row = jnp.matmul(a.astype(dtype), encode_row(b, dtype))
    return Checksums(col=col, row=row)


def residuals(c: jax.Array, ck: Checksums, dtype=jnp.float32) -> Checksums:
    """δ_col = colsum(C) − C^c   (…, 1, N);   δ_row = rowsum(C) − C^r   (…, M, 1)."""
    cf = c.astype(dtype)
    d_col = jnp.sum(cf, axis=-2, keepdims=True) - ck.col.astype(dtype)
    d_row = jnp.sum(cf, axis=-1, keepdims=True) - ck.row.astype(dtype)
    return Checksums(col=d_col, row=d_row)


def threshold(a: jax.Array, b: jax.Array, rel_tau: float) -> jax.Array:
    """Rounding-aware detection threshold (scalar per batch element):
    tau = rel_tau · eps(f32) · K · max|A| · max|B|.

    eps is that of the *accumulator/checksum* dtype (f32), not the input
    dtype: bf16×bf16 products are exactly representable in f32 and both the
    GEMM and its checksums accumulate in f32 (MXU semantics), so the residual
    between colsum(C) and (e^T A)·B is pure f32 accumulation rounding.
    Errors smaller than tau are numerically indistinguishable from rounding
    and therefore harmless by construction (standard ABFT argument).
    """
    k = a.shape[-1]
    eps = float(jnp.finfo(jnp.float32).eps)
    amax = jnp.max(jnp.abs(a.astype(jnp.float32)), axis=(-2, -1), keepdims=True)
    bmax = jnp.max(jnp.abs(b.astype(jnp.float32)), axis=(-2, -1), keepdims=True)
    tau = rel_tau * eps * k * amax * bmax
    # Floor: absolute epsilon for all-zero operands.
    return jnp.maximum(tau[..., 0, 0], 1e-30)


class Verdict(NamedTuple):
    detected: jax.Array      # bool (…,) — any checksum residual above tau
    row: jax.Array           # int32 (…,) — located row of the (single) error
    col: jax.Array           # int32 (…,)
    magnitude: jax.Array     # f32 (…,) — error offset δ (0 where not detected)


def locate(res: Checksums, tau: jax.Array) -> Verdict:
    """Locate a single error from the residuals (paper Fig. 3(e): 'fault
    location is determined by relative positions in two checksums; the
    correction value by the offset')."""
    d_col = res.col[..., 0, :]          # (…, N)
    d_row = res.row[..., :, 0]          # (…, M)
    col = jnp.argmax(jnp.abs(d_col), axis=-1).astype(jnp.int32)
    row = jnp.argmax(jnp.abs(d_row), axis=-1).astype(jnp.int32)
    mag_c = jnp.take_along_axis(d_col, col[..., None], axis=-1)[..., 0]
    mag_r = jnp.take_along_axis(d_row, row[..., None], axis=-1)[..., 0]
    detected = jnp.maximum(jnp.abs(mag_c), jnp.abs(mag_r)) > tau
    # Use the column residual as the canonical magnitude (both agree under SEU).
    magnitude = jnp.where(detected, mag_c, 0.0)
    return Verdict(detected=detected, row=row, col=col, magnitude=magnitude)


def correct(c: jax.Array, v: Verdict) -> jax.Array:
    """Branchless online correction: subtract δ at the located element.
    δ = 0 when nothing was detected, so this is a no-op in the common case —
    no lax.cond, SPMD-safe, constant cost."""
    rows = jax.lax.broadcasted_iota(jnp.int32, c.shape, c.ndim - 2)
    cols = jax.lax.broadcasted_iota(jnp.int32, c.shape, c.ndim - 1)
    hit = (rows == v.row[..., None, None]) & (cols == v.col[..., None, None])
    delta = v.magnitude[..., None, None].astype(c.dtype)
    return c - jnp.where(hit, delta, jnp.zeros_like(delta))


def detect_and_correct(
    c: jax.Array,
    ck: Checksums,
    tau: jax.Array,
    corrects: bool = True,
) -> Tuple[jax.Array, Verdict]:
    """Full online-ABFT decode step: residuals → locate → (optionally) correct."""
    res = residuals(c, ck)
    v = locate(res, tau)
    if corrects:
        c = correct(c, v)
    return c, v
