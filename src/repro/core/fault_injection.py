"""Fault injection — emulated SEUs for validating online ABFT.

The paper (§5.3) injects errors 'at the source code level … in the register of
the accumulated result by adding a numerical offset to emulate register bit
flipping'. We do the same: an injector perturbs the GEMM *output accumulator*
between compute and verification, which is exactly where a compute-unit SDC
would land. Memory errors are out of scope (ECC-covered, per the fault model).

Two injectors:
  * `inject_spec`  — deterministic single-error injection (tests, kernel path).
  * `Injector`     — seeded stochastic injector with a per-matmul Bernoulli
                     rate, used by the framework-level error-injection
                     campaigns (benchmarks/error_injection.py) and the
                     trainer's `--inject-rate` flag.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .policy import InjectionSpec


def inject_spec(c: jax.Array, spec: Optional[InjectionSpec]) -> jax.Array:
    """Apply a single deterministic SEU to a (…, M, N) accumulator."""
    if spec is None:
        return c
    rows = jax.lax.broadcasted_iota(jnp.int32, c.shape, c.ndim - 2)
    cols = jax.lax.broadcasted_iota(jnp.int32, c.shape, c.ndim - 1)
    hit = (rows == spec.row) & (cols == spec.col)
    return c + jnp.where(hit, jnp.asarray(spec.magnitude, c.dtype),
                         jnp.zeros((), c.dtype))


@dataclasses.dataclass(frozen=True)
class Injector:
    """Stochastic SEU source. `rate` is the probability that a given matmul's
    accumulator suffers one flipped element this step. Magnitude emulates a
    high-order mantissa/exponent bit flip: the hit element is scaled by
    2**bit_shift (default: +2^8, a large, detectable corruption)."""
    rate: float = 0.0
    bit_shift: int = 8

    def __call__(self, key: jax.Array, c: jax.Array) -> jax.Array:
        if self.rate <= 0.0:
            return c
        k_hit, k_row, k_col = jax.random.split(key, 3)
        m, n = c.shape[-2], c.shape[-1]
        hit_p = jax.random.bernoulli(k_hit, self.rate)
        r = jax.random.randint(k_row, (), 0, m)
        cc = jax.random.randint(k_col, (), 0, n)
        rows = jax.lax.broadcasted_iota(jnp.int32, c.shape, c.ndim - 2)
        cols = jax.lax.broadcasted_iota(jnp.int32, c.shape, c.ndim - 1)
        sel = (rows == r) & (cols == cc) & hit_p
        # value -> value * 2^bit_shift  ==  += value*(2^shift - 1); if the
        # element is ~0 use an absolute offset so the flip is observable.
        delta = c * (2.0 ** self.bit_shift - 1.0)
        delta = jnp.where(jnp.abs(delta) > 1e-6, delta,
                          jnp.full_like(delta, 2.0 ** self.bit_shift))
        return jnp.where(sel, c + delta, c)


def split_for(key: Optional[jax.Array], tag: int) -> Optional[jax.Array]:
    """Derive a per-callsite injection key (None passes through)."""
    if key is None:
        return None
    return jax.random.fold_in(key, tag)
